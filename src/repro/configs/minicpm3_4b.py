"""minicpm3-4b [dense] — Multi-head Latent Attention (MLA).

[hf:openbmb/MiniCPM3-4B]
62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA: q_lora_rank=768, kv_lora_rank=256, qk dims 64 nope + 32 rope,
v_head_dim=64. Decode caches the COMPRESSED c_kv + shared k_rope
(the MLA memory advantage), with the absorbed-matmul decode path.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3_4b",
    arch_type="dense",
    source="hf:openbmb/MiniCPM3-4B",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=64,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_dim=32,
    qk_nope_dim=64,
    v_head_dim=64,
    act="swiglu",
)
