"""Config system: architecture + run configs and the input-shape pool.

Every assigned architecture registers a ``ModelConfig`` here via its
``src/repro/configs/<arch>.py`` module; ``get_config(name)`` resolves it.
``reduced(cfg)`` derives the CPU smoke-test variant (2 layers,
d_model <= 512, <= 4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

# ----------------------------------------------------------------- configs


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""               # citation (paper / model card)
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab_size: int = 32000
    # attention flavour
    attention: str = "gqa"         # gqa | mla | none
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # >0: local-attention window size
    local_global_ratio: int = 0    # gemma3: N local layers per 1 global
    logit_softcap: float = 0.0
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0         # leading dense layers (deepseek-moe)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4
    expand: int = 2
    # hybrid (zamba2): one SHARED attention block applied every k layers
    shared_attn_every: int = 0
    # encoder-decoder / multimodal stubs
    encoder_layers: int = 0
    encoder_frames: int = 0        # whisper: stub frame-embedding count
    vision_tokens: int = 0         # vlm: stub patch-embedding count
    cross_attention: bool = False
    act: str = "swiglu"            # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def attn_layers(self) -> int:
        return self.n_layers if self.attention != "none" else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a shardable multiple (table/unembed use this;
        padded logit columns are masked to -1e9)."""
        m = 512 if self.vocab_size >= 512 else 16
        return -(-self.vocab_size // m) * m

    @property
    def padded_experts(self) -> int:
        """Expert bank padded to the model-axis multiple (16); padded
        experts get -inf router logits and are never dispatched to."""
        return -(-self.n_experts // 16) * 16 if self.n_experts else 0

    def param_count(self) -> int:
        """Total parameters (approximate, used for MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        n = 0
        n += v * d                                     # embed
        if not self.tie_embeddings:
            n += v * d                                 # unembed
        per_layer = 0
        if self.arch_type in ("dense", "moe", "vlm", "audio"):
            per_layer += self._attn_params() + 2 * d   # attn + norms
            if self.arch_type == "moe":
                moe_f = self.moe_d_ff
                routed = self.n_experts * 3 * d * moe_f
                shared = self.n_shared_experts * 3 * d * moe_f
                router = d * self.n_experts
                per_layer += routed + shared + router
            else:
                per_layer += 3 * d * f if self.act == "swiglu" else 2 * d * f
            n += per_layer * self.n_layers
            if self.arch_type == "moe" and self.first_k_dense:
                n += self.first_k_dense * (3 * d * f - (
                    self.n_experts + self.n_shared_experts) * 3 * d *
                    self.moe_d_ff - d * self.n_experts)
            if self.arch_type == "audio":   # encoder stack + cross attn
                enc = self.encoder_layers * (4 * d * d + 3 * d * f
                                             if self.act == "swiglu"
                                             else 4 * d * d + 2 * d * f)
                n += enc + self.n_layers * 4 * d * d   # cross-attn per layer
        elif self.arch_type == "ssm":
            n += self.n_layers * self._ssm_params()
        elif self.arch_type == "hybrid":
            n += self.n_layers * self._ssm_params()
            n += self._attn_params() + 3 * d * f       # ONE shared block
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attention == "mla":
            r_q, r_kv = self.q_lora_rank, self.kv_lora_rank
            h = self.n_heads
            qd = self.qk_rope_dim + self.qk_nope_dim
            return (d * r_q + r_q * h * qd + d * (r_kv + self.qk_rope_dim)
                    + r_kv * h * (self.qk_nope_dim + self.v_head_dim)
                    + h * self.v_head_dim * d)
        hd, kvd = self.n_heads * self.d_head, self.n_kv_heads * self.d_head
        return d * hd + 2 * d * kvd + hd * d

    def _ssm_params(self) -> int:
        d = self.d_model
        d_in = self.expand * d
        ng = max(1, self.ssm_heads // 8)
        conv_dim = d_in + 2 * ng * self.ssm_state
        return (d * (2 * d_in + 2 * ng * self.ssm_state + self.ssm_heads)
                + conv_dim * self.conv_kernel + 3 * self.ssm_heads
                + d_in * d)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.arch_type != "moe":
            return self.param_count()
        d, moe_f = self.d_model, self.moe_d_ff
        total = self.param_count()
        routed_all = self.n_layers * self.n_experts * 3 * d * moe_f
        routed_active = self.n_layers * self.top_k * 3 * d * moe_f
        return total - routed_all + routed_active


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_NAMES = [
    "phi3_vision_4p2b", "mamba2_780m", "phi4_mini_3p8b", "gemma3_12b",
    "deepseek_moe_16b", "minicpm3_4b", "whisper_medium", "zamba2_1p2b",
    "qwen2_moe_a2p7b", "deepseek_67b",
]

# archs able to run long_500k (sub-quadratic path) — see DESIGN.md §6
LONG_CONTEXT_ARCHS = {"mamba2_780m", "zamba2_1p2b", "gemma3_12b"}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def list_configs() -> list[str]:
    return list(ARCH_NAMES)


def supports_shape(cfg: ModelConfig, shape: InputShape) -> bool:
    if shape.name == "long_500k":
        return cfg.name in LONG_CONTEXT_ARCHS
    return True


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family, tiny dims (CPU-runnable)."""
    kw = dict(
        n_layers=2, d_model=min(cfg.d_model, 128),
        n_heads=min(cfg.n_heads, 4),
        d_head=32, d_ff=min(cfg.d_ff, 256) or 0,
        vocab_size=min(cfg.vocab_size, 512),
        q_lora_rank=min(cfg.q_lora_rank, 64),
        kv_lora_rank=min(cfg.kv_lora_rank, 32),
        qk_rope_dim=min(cfg.qk_rope_dim, 16),
        qk_nope_dim=min(cfg.qk_nope_dim, 16),
        v_head_dim=min(cfg.v_head_dim, 32),
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=min(cfg.moe_d_ff, 128),
        first_k_dense=min(cfg.first_k_dense, 1),
        ssm_state=min(cfg.ssm_state, 16),
        ssm_heads=min(cfg.ssm_heads, 4),
        ssm_head_dim=min(cfg.ssm_head_dim, 32),
        ssm_chunk=32,
        sliding_window=min(cfg.sliding_window, 64),
        shared_attn_every=min(cfg.shared_attn_every, 2) or 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_frames=min(cfg.encoder_frames, 16),
        vision_tokens=min(cfg.vision_tokens, 8),
        name=cfg.name + "_reduced",
    )
    kv = min(cfg.n_kv_heads, 4)
    kw["n_kv_heads"] = min(kv, kw["n_heads"])
    if cfg.local_global_ratio:
        kw["local_global_ratio"] = 1
        kw["n_layers"] = 2  # 1 local + 1 global group
    return dataclasses.replace(cfg, **kw)
