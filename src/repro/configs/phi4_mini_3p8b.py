"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2412.08905]

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4_mini_3p8b",
    arch_type="dense",
    source="arXiv:2412.08905",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=200064,
    attention="gqa",
    rope_theta=10_000.0,
    act="swiglu",
)
