"""whisper-medium [audio] — encoder-decoder. [arXiv:2212.04356]

24L (x2: encoder + decoder) d_model=1024 16H d_ff=4096 vocab=51865.
The mel-spectrogram + conv frontend is a STUB per the assignment
carve-out: ``input_specs()`` supplies precomputed frame embeddings
(encoder_frames, d_model). rope_theta=0 -> absolute sinusoidal positions
(whisper uses learned/sinusoidal, not RoPE).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper_medium",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51865,
    attention="gqa",
    rope_theta=0.0,           # sinusoidal absolute positions
    encoder_layers=24,
    encoder_frames=1500,
    cross_attention=True,
    act="gelu",
)
