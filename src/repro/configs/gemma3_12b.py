"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family, 12B point]
48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
Local layers use a 1024-token sliding window; every 6th layer is global.
The sliding window is what qualifies gemma3 for the long_500k decode
shape (local layers keep O(window) caches; the 8 global layers hold the
full 500k KV, O(seq) per decoded token).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_12b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt (12b)",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab_size=262144,
    attention="gqa",
    rope_theta=1_000_000.0,
    sliding_window=1024,
    local_global_ratio=5,     # 5 local : 1 global
    act="gelu",
    tie_embeddings=True,
)
