"""phi-3-vision-4.2b [vlm] — phi3-mini decoder + CLIP vision frontend.

[hf:microsoft/Phi-3-vision-128k-instruct]
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
The ViT/projector frontend is a STUB per the assignment carve-out:
``input_specs()`` supplies precomputed patch embeddings (vision_tokens,
d_model) that are prepended to the text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3_vision_4p2b",
    arch_type="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab_size=32064,
    attention="gqa",
    rope_theta=10_000.0,
    vision_tokens=576,       # one 336px CLIP-L crop worth of patch embeds
    act="swiglu",
)
