"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B]
24L d_model=2048 16H (kv=16) vocab=151936, expert d_ff=1408.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_moe_a2p7b",
    arch_type="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=5632,                # (unused: no dense layers)
    vocab_size=151936,
    attention="gqa",
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    first_k_dense=0,
    act="swiglu",
)
