"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6.

[arXiv:2401.06066]
28L d_model=2048 16H (kv=16) vocab=102400, expert d_ff=1408.
Layer 0 is a dense SwiGLU layer (d_ff=10944), layers 1..27 are MoE —
the paper's "first k dense" stabilization.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_moe_16b",
    arch_type="moe",
    source="arXiv:2401.06066",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,               # dense layers (first_k_dense)
    vocab_size=102400,
    attention="gqa",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_k_dense=1,
    act="swiglu",
)
