"""zamba2-1.2b [hybrid] — Mamba2 backbone + SHARED attention block.

[arXiv:2411.15242]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
One weight-tied attention+FFN block is invoked every 6th layer (7
invocations share a single parameter set) — the Zamba trick that buys
attention quality at near-zero parameter cost. SSM path qualifies the
arch for long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2_1p2b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=8192,
    vocab_size=32000,
    attention="gqa",
    ssm_state=64,
    ssm_heads=64,             # expand*d_model / ssm_head_dim
    ssm_head_dim=64,
    ssm_chunk=256,
    shared_attn_every=6,
    tie_embeddings=True,
)
