"""deepseek-67b [dense] — llama-arch at depth. [arXiv:2401.02954]

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_67b",
    arch_type="dense",
    source="arXiv:2401.02954",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22016,
    vocab_size=102400,
    attention="gqa",
    rope_theta=10_000.0,
    act="swiglu",
)
