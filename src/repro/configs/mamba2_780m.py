"""mamba2-780m [ssm] — SSD (state-space duality). [arXiv:2405.21060]

48L d_model=1536, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*d_model = 3072, head_dim 64 -> 48 SSD heads, 1 group.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_780m",
    arch_type="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_heads=48,            # expand*d_model / ssm_head_dim
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_kernel=4,
    expand=2,
    tie_embeddings=True,
)
