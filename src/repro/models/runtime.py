"""Runtime switches for model tracing.

``UNROLL_SCANS``: when True, every layer/chunk scan lowers as an
unrolled python loop instead of ``lax.scan``. XLA's cost_analysis counts
a while-loop body ONCE (verified experimentally — a scan of 8 matmuls
reports 1 matmul of FLOPs), so the roofline differential probe unrolls
shallow-depth models to recover true per-layer costs. Production
lowering keeps scans (compile time / HLO size), so this is only ever set
by ``repro.roofline.differential``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

UNROLL_SCANS = False

# ---- §Perf hillclimb knobs (EXPERIMENTS.md) -------------------------------
# Each defaults to the paper-faithful / naive-XLA baseline; the hillclimb
# driver (repro.roofline.hillclimb) toggles them per variant.
SCORES_BF16 = False        # store attention score tensors in bf16
REMAT_POLICY = "full"      # full | dots (save matmul outputs) | none
CHUNKED_THRESHOLD = 8192   # min seq for online-softmax chunked attention
EMBED_ONEHOT = False       # vocab-parallel one-hot embedding lookup
MOE_GROUPED = False        # GShard-style grouped (dp-local) MoE dispatch
MICROBATCHES = 1           # gradient accumulation steps per train step
SERVE_PURE_TP = False      # prefill/decode: params TP-only (no fsdp dim)
WINDOW_CACHE_SP = False    # shard sliding-window KV caches on seq (model)
GATHER_WEIGHTS = False     # train: force weight all-gather over activation
                           # all-reduce for fsdp-sharded contractions
MOE_XE_SHARD = False       # shard MoE dispatch buffers (E->model, cap->dp)
                           # so expert compute splits over dp instead of
                           # replicating (all-to-all dispatch)
MLA_PAD_HEADS = False      # pad MLA head count to the model-axis multiple
                           # (16): non-divisible heads (minicpm3: 40) make
                           # XLA replicate the batch and all-reduce 86 GB
                           # score tensors; dummy heads have zero wo rows
                           # (function-identical at init, +20% attn flops)


def set_unroll(v: bool) -> None:
    global UNROLL_SCANS
    UNROLL_SCANS = v


def set_flags(**kw) -> None:
    g = globals()
    for k, v in kw.items():
        key = k.upper()
        assert key in g, key
        g[key] = v


def checkpoint_wrap(body):
    import jax as _jax
    if REMAT_POLICY == "none":
        return body
    if REMAT_POLICY == "dots":
        return _jax.checkpoint(
            body,
            policy=_jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return _jax.checkpoint(body)


def scan(body, carry, xs):
    """lax.scan, or an unrolled equivalent under UNROLL_SCANS."""
    if not UNROLL_SCANS:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda v: v[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *v: jnp.stack(v), *ys)
    return carry, stacked
