"""Mamba2 / SSD (state-space duality) block. [arXiv:2405.21060]

Chunked SSD algorithm for train/prefill (quadratic within Q-token
chunks, linear recurrence across chunks — the paper's tensor-core
formulation maps straight onto the TPU MXU), and the O(1)-state
recurrent step for decode.

Discretization (per head h, state n, channel p):
    h_t = exp(A_h dt_t) * h_{t-1} + dt_t * B_t[n] * x_t[p]
    y_t = sum_n C_t[n] h_t[n, p] + D_h x_t[p]

The projections are SPLIT (w_z/w_x/w_B/w_C/w_dt instead of one packed
in_proj) so each piece gets a clean tensor-parallel sharding: head-space
(d_inner, dt) over "tp", the group-shared B/C projections replicated.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import runtime as RT
from repro.models.layers import ACT_DTYPE, dense_init, rmsnorm, rmsnorm_init

Params = dict
Specs = dict


def _d_inner(cfg: ModelConfig) -> int:
    return cfg.expand * cfg.d_model


def _n_groups(cfg: ModelConfig) -> int:
    return 1


def mamba2_init(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    d = cfg.d_model
    di = _d_inner(cfg)
    h, n, kk = cfg.ssm_heads, cfg.ssm_state, cfg.conv_kernel
    g = _n_groups(cfg)
    ks = jax.random.split(key, 10)
    dt = jnp.exp(jax.random.uniform(ks[5], (h,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    params = {
        "w_z": dense_init(ks[0], d, di),
        "w_x": dense_init(ks[1], d, di),
        "w_B": dense_init(ks[2], d, g * n),
        "w_C": dense_init(ks[3], d, g * n),
        "w_dt": dense_init(ks[4], d, h),
        "dt_bias": dt + jnp.log(-jnp.expm1(-dt)),       # inv-softplus
        "A_log": jnp.log(jax.random.uniform(ks[6], (h,), jnp.float32,
                                            1.0, 16.0)),
        "D": jnp.ones((h,), jnp.float32),
        "conv_x": 0.1 * jax.random.normal(ks[7], (kk, di), jnp.float32),
        "conv_B": 0.1 * jax.random.normal(ks[8], (kk, g * n), jnp.float32),
        "conv_C": 0.1 * jax.random.normal(ks[9], (kk, g * n), jnp.float32),
        "norm": rmsnorm_init(di),
        "w_out": dense_init(ks[0], di, d,
                            scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    specs = {
        "w_z": ("fsdp", "tp"), "w_x": ("fsdp", "tp"),
        "w_B": ("fsdp", None), "w_C": ("fsdp", None),
        "w_dt": ("fsdp", "tp"), "dt_bias": ("tp",), "A_log": ("tp",),
        "D": ("tp",), "conv_x": (None, "tp"), "conv_B": (None, None),
        "conv_C": (None, None), "norm": ("tp",), "w_out": ("tp", "fsdp"),
    }
    return params, specs


def _causal_conv(x, w, *, state: Optional[jax.Array] = None):
    """Depthwise causal conv. x (B,S,C), w (K,C). state: (B,K-1,C) left
    context (decode); returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return y, new_state


def ssd_chunked(x, dt, a, bmat, cmat, *, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x (B,S,H,P), dt (B,S,H) >0, a (H,) <0, bmat/cmat (B,S,G,N).
    Returns y (B,S,H,P), final_state (B,H,N,P).
    """
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    xr = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtr = dt.reshape(b, nc, chunk, h).astype(f32)
    br = bmat.reshape(b, nc, chunk, g, n).astype(f32)
    cr = cmat.reshape(b, nc, chunk, g, n).astype(f32)

    da = dtr * a                                     # (B,NC,Q,H) negative
    cs = jnp.cumsum(da, axis=2)                      # inclusive cumsum
    total = cs[:, :, -1:, :]                         # (B,NC,1,H)

    # ---- intra-chunk (quadratic within the chunk, MXU-friendly)
    # L[q, kk] = exp(cs_q - cs_kk) for q >= kk
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]     # (B,NC,Q,K,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    l_mat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqgn,bckgn->bcqkg", cr, br)         # (B,NC,Q,K,G)
    cb = jnp.repeat(cb, hpg, axis=-1)                     # G -> H
    w_intra = cb * l_mat * dtr[:, :, None, :, :]          # (B,NC,Q,K,H)
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", w_intra, xr)

    # ---- chunk states: S_c = sum_k B_k (decay_out*dt)_k x_k -> (B,NC,H,N,P)
    decay_out = jnp.exp(total - cs)                       # (B,NC,Q,H)
    wk = decay_out * dtr                                  # (B,NC,Q,H)
    if g == 1:
        states = jnp.einsum("bckn,bckh,bckhp->bchnp", br[:, :, :, 0, :],
                            wk, xr)
    else:
        brh = jnp.repeat(br, hpg, axis=3)                 # (B,NC,Q,H,N)
        states = jnp.einsum("bckhn,bckh,bckhp->bchnp", brh, wk, xr)

    # ---- inter-chunk recurrence over NC chunks
    chunk_decay = jnp.exp(total[:, :, 0, :])              # (B,NC,H)
    s0 = (jnp.zeros((b, h, n, p), f32) if init_state is None
          else init_state.astype(f32))

    def scan_fn(carry, xs):
        st, dec = xs                                      # (B,H,N,P),(B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                 # emit state BEFORE chunk

    final, prev_states = RT.scan(
        scan_fn, s0, (states.transpose(1, 0, 2, 3, 4),
                      chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,NC,H,N,P)

    # ---- inter-chunk contribution
    decay_in = jnp.exp(cs)                                # (B,NC,Q,H)
    if g == 1:
        y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", cr[:, :, :, 0, :],
                           decay_in, prev_states)
    else:
        crh = jnp.repeat(cr, hpg, axis=3)
        y_off = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp", crh, decay_in,
                           prev_states)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(x, dt, a, bvec, cvec, state):
    """One recurrent step. x (B,H,P), dt (B,H), bvec/cvec (B,G,N),
    state (B,H,N,P) -> (y (B,H,P), new_state)."""
    f32 = jnp.float32
    x, dt = x.astype(f32), dt.astype(f32)
    h, g = x.shape[1], bvec.shape[1]
    bh = jnp.repeat(bvec.astype(f32), h // g, axis=1)      # (B,H,N)
    ch = jnp.repeat(cvec.astype(f32), h // g, axis=1)
    dec = jnp.exp(dt * a)                                  # (B,H)
    bx = jnp.einsum("bhn,bhp->bhnp", bh, dt[..., None] * x)
    new_state = state * dec[:, :, None, None] + bx
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_state)
    return y, new_state


def mamba2_apply(p: Params, x, cfg: ModelConfig, *,
                 cache: Optional[dict] = None, update_cache=False):
    """x (B,S,D) -> (out, new_cache). cache = {"conv_x","conv_B","conv_C",
    "ssm"} for decode; S==1 takes the recurrent path."""
    b, s, d = x.shape
    di = _d_inner(cfg)
    h, n = cfg.ssm_heads, cfg.ssm_state
    pdim = di // h
    g = _n_groups(cfg)
    xb = x.astype(ACT_DTYPE)

    z = xb @ p["w_z"].astype(ACT_DTYPE)                   # (B,S,di)
    xs = xb @ p["w_x"].astype(ACT_DTYPE)
    bs = xb @ p["w_B"].astype(ACT_DTYPE)                  # (B,S,G*N)
    cs_ = xb @ p["w_C"].astype(ACT_DTYPE)
    dt_raw = (xb @ p["w_dt"].astype(ACT_DTYPE)).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])           # (B,S,H)
    a = -jnp.exp(p["A_log"])                              # (H,)

    decode = cache is not None and s == 1
    cx = cache["conv_x"] if decode else None
    cb = cache["conv_B"] if decode else None
    cc = cache["conv_C"] if decode else None
    xs, ncx = _causal_conv(xs, p["conv_x"].astype(ACT_DTYPE), state=cx)
    bs, ncb = _causal_conv(bs, p["conv_B"].astype(ACT_DTYPE), state=cb)
    cs_, ncc = _causal_conv(cs_, p["conv_C"].astype(ACT_DTYPE), state=cc)
    xs, bs, cs_ = jax.nn.silu(xs), jax.nn.silu(bs), jax.nn.silu(cs_)

    xh = xs.reshape(b, s, h, pdim)
    bmat = bs.reshape(b, s, g, n)
    cmat = cs_.reshape(b, s, g, n)

    if decode:
        y, new_ssm = ssd_decode_step(xh[:, 0], dt[:, 0], a, bmat[:, 0],
                                     cmat[:, 0], cache["ssm"])
        y = y[:, None]                                    # (B,1,H,P)
        new_cache = {"conv_x": ncx, "conv_B": ncb, "conv_C": ncc,
                     "ssm": new_ssm}
    else:
        init = cache["ssm"] if cache is not None else None
        y, final = ssd_chunked(xh, dt, a, bmat, cmat,
                               chunk=min(cfg.ssm_chunk, s),
                               init_state=init)
        new_cache = cache
        if update_cache and cache is not None:
            new_cache = {"conv_x": ncx, "conv_B": ncb, "conv_C": ncc,
                         "ssm": final}

    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, s, di).astype(ACT_DTYPE)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["w_out"].astype(ACT_DTYPE)
    return out.astype(x.dtype), new_cache


def mamba2_cache_init(cfg: ModelConfig, batch: int) -> dict:
    di = _d_inner(cfg)
    h, n = cfg.ssm_heads, cfg.ssm_state
    g = _n_groups(cfg)
    k = cfg.conv_kernel
    return {
        "conv_x": jnp.zeros((batch, k - 1, di), ACT_DTYPE),
        "conv_B": jnp.zeros((batch, k - 1, g * n), ACT_DTYPE),
        "conv_C": jnp.zeros((batch, k - 1, g * n), ACT_DTYPE),
        "ssm": jnp.zeros((batch, h, n, di // h), jnp.float32),
    }


def mamba2_cache_specs() -> dict:
    return {"conv_x": ("dp", None, "tp"), "conv_B": ("dp", None, None),
            "conv_C": ("dp", None, None), "ssm": ("dp", "tp", None, None)}
