"""Mixture-of-Experts layer: shared + routed experts, expert-parallel.

Routing is the sort-based capacity dispatch (dropless up to
``capacity_factor``): tokens are argsorted by expert id, packed into a
dense (E, capacity, d) buffer via gather, processed with a grouped
einsum whose expert axis is sharded over the mesh "expert"(=model) axis,
and combined back with the router weights. Over-capacity tokens fall
back to the shared-experts-only path (standard GShard-style dropping).

This formulation has only static shapes (jit/vmap/scan-safe), and under
pjit the pack/unpack gathers lower to the expected expert-parallel
collectives (the all-to-all pattern of the dispatch).

deepseek-moe: 2 shared + 64 routed top-6 (fine-grained experts).
qwen2-moe:    4 shared + 60 routed top-4.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import runtime as RT
from repro.models.layers import ACT_DTYPE, dense_init

Params = dict
Specs = dict


def moe_init(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    """Expert banks are padded to ``cfg.padded_experts`` (model-axis
    multiple); the router only produces logits for the real experts, so
    padded experts receive zero tokens (they exist purely for sharding)."""
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.padded_experts
    ks = jax.random.split(key, 7)
    out_scale = 0.02 / (2 * cfg.n_layers) ** 0.5

    def expert_bank(k, n, fan_scale=0.02):
        kk = jax.random.split(k, 3)
        return {
            "w_gate": 0.02 * jax.random.normal(kk[0], (n, d, f), jnp.float32),
            "w_up": 0.02 * jax.random.normal(kk[1], (n, d, f), jnp.float32),
            "w_down": out_scale * jax.random.normal(kk[2], (n, f, d),
                                                    jnp.float32),
        }

    params = {
        "router": dense_init(ks[0], d, cfg.n_experts, scale=0.006),
        "experts": expert_bank(ks[1], e),
    }
    specs = {
        "router": ("fsdp", None),
        "experts": {"w_gate": ("expert", "fsdp", None),
                    "w_up": ("expert", "fsdp", None),
                    "w_down": ("expert", None, "fsdp")},
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        kk = jax.random.split(ks[2], 3)
        params["shared"] = {
            "w_gate": dense_init(kk[0], d, fs),
            "w_up": dense_init(kk[1], d, fs),
            "w_down": dense_init(kk[2], fs, d, scale=out_scale),
        }
        specs["shared"] = {"w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
                           "w_down": ("tp", "fsdp")}
    return params, specs


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, min(cap, n_tokens))


def _routed(xt, gate_w, gate_i, we, e: int, k: int, cap: int):
    """Sort-based dispatch -> grouped expert matmul -> weighted combine.

    xt (T, d); gate_w/gate_i (T, K). Returns (T, d). Static shapes only;
    over-capacity routes drop to zero (shared experts still cover them).
    """
    t, d = xt.shape
    flat_e = gate_i.reshape(-1)                            # (T*K,)
    order = jnp.argsort(flat_e)                            # stable
    sorted_e = flat_e[order]
    token_of = order // k
    # position within expert = rank in sorted order - expert start offset
    counts = jnp.zeros((e,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # sentinel

    # pack: buffer row -> source token index (T = zero-row sentinel)
    buf_src = jnp.full((e * cap + 1,), t, jnp.int32).at[dest].set(
        jnp.where(keep, token_of, t))[:e * cap]
    x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    xe = x_pad[buf_src].reshape(e, cap, d).astype(ACT_DTYPE)   # (E, C, d)
    if RT.MOE_XE_SHARD:
        # split the capacity rows over the data axes so expert compute
        # parallelizes over dp too (dispatch becomes all-to-all-shaped
        # redistribution instead of replicated compute)
        from jax.sharding import PartitionSpec as P
        xe = jax.lax.with_sharding_constraint(
            xe, P("model", ("data",), None))

    # ---- expert computation (E sharded over the mesh "expert" axis)
    from repro.models.layers import wgather
    wg = lambda w: wgather(w, ("expert", None, None)).astype(ACT_DTYPE)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg(we["w_gate"])))
    h = h * jnp.einsum("ecd,edf->ecf", xe, wg(we["w_up"]))
    ye = jnp.einsum("ecf,efd->ecd", h, wg(we["w_down"]))
    ye = ye.reshape(e * cap, d)

    # ---- combine: scatter back through the same mapping
    dest_unsorted = jnp.zeros((t * k,), jnp.int32).at[order].set(
        jnp.where(keep, dest, e * cap))
    ye_pad = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], 0)
    routed = ye_pad[dest_unsorted].reshape(t, k, d)        # dropped -> 0
    return jnp.sum(routed * gate_w[..., None].astype(ye.dtype), axis=1)


def moe_apply(p: Params, x, cfg: ModelConfig):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.padded_experts, cfg.top_k
    e_real = cfg.n_experts
    xt = x.reshape(t, d)

    # ---- router (f32 for numerics; only the REAL experts get logits)
    logits = xt.astype(jnp.float32) @ p["router"]          # (T, E_real)
    probs = jax.nn.softmax(logits, -1)
    if e != e_real:  # pad prob columns with 0 so top_k never picks them
        probs = jnp.pad(probs, ((0, 0), (0, e - e_real)))
    gate_w, gate_i = jax.lax.top_k(probs, k)               # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style)
    me = probs.mean(0)                                     # (E,)
    assign = jnp.zeros((e,), jnp.float32).at[gate_i.reshape(-1)].add(
        1.0 / (t * k))
    aux = e_real * jnp.sum(me * assign) * cfg.router_aux_coef

    # ---- sort-based dispatch + expert compute + combine
    if RT.MOE_GROUPED:
        # GShard-style GROUPS: route within each batch row (dp-local), so
        # pack/unpack gathers never cross the data axis — cross-mesh comm
        # collapses to the expert-axis redistribution (all-to-all) instead
        # of full-buffer all-reduces. Capacity is per group.
        cap = _capacity(cfg, s)
        xg = xt.reshape(b, s, d)
        gw = gate_w.reshape(b, s, k)
        gi = gate_i.reshape(b, s, k)
        out = jax.vmap(
            lambda xx, ww, ii: _routed(xx, ww, ii, p["experts"], e, k,
                                       cap))(xg, gw, gi)
        out = out.reshape(t, d)
    else:
        cap = _capacity(cfg, t)
        out = _routed(xt, gate_w, gate_i, p["experts"], e, k, cap)

    # ---- shared experts (always-on dense path)
    if "shared" in p:
        from repro.models.layers import wgather
        sp = p["shared"]
        xb = xt.astype(ACT_DTYPE)
        wg = lambda w: wgather(w, ("fsdp", "tp")).astype(ACT_DTYPE)
        hs = jax.nn.silu(xb @ wg(sp["w_gate"])) * (xb @ wg(sp["w_up"]))
        out = out + hs @ wgather(sp["w_down"],
                                 ("tp", "fsdp")).astype(ACT_DTYPE)

    return out.reshape(b, s, d).astype(x.dtype), aux
