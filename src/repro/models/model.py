"""Unified model: every assigned architecture behind one interface.

    model = Model(cfg)                       # cfg from repro.configs
    params, specs = model.init(key)          # specs: logical-axis tree
    logits, aux   = model.forward(params, batch)           # train
    logits, cache = model.prefill(params, batch, cache)    # prefill
    logits, cache = model.decode_step(params, token, cache)

Families
--------
dense / vlm     pre-norm attn+FFN stack, scanned; gemma3's 5:1
                local:global pattern is a scan over GROUPS of
                (ratio x local + 1 global) so cache shapes stay uniform.
moe             attn + (shared+routed experts); aux load-balance loss.
ssm             mamba2 (SSD) stack.
hybrid          mamba2 stack + ONE weight-tied attention block invoked
                every `shared_attn_every` layers (zamba2).
audio           whisper enc-dec: bidirectional encoder over stubbed frame
                embeddings; causal decoder w/ cross-attention.
vlm             dense decoder consuming [patch embeds | token embeds].

All stacks scan over a stacked layer axis (HLO depth-independent);
``remat=True`` wraps layer bodies in jax.checkpoint.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models import runtime as RT

Params = Any


def abstract_init(model: "Model", key=None):
    """(ShapeDtypeStruct params, logical spec tree) with ZERO allocation.
    Specs are static python data, captured by closure around eval_shape."""
    key = jax.random.PRNGKey(0) if key is None else key
    captured = {}

    def only_params(k):
        p, s = model.init(k)
        captured["specs"] = s
        return p

    shapes = jax.eval_shape(only_params, key)
    return shapes, captured["specs"]


def _stack_init(init_fn, key, n: int):
    """vmap an init over layer keys -> params stacked on axis 0, and the
    per-layer spec tree lifted with a leading None (layer) axis."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, spec = init_fn(key)
    lifted = jax.tree.map(lambda lg: (None,) + lg, spec,
                          is_leaf=lambda x: isinstance(x, tuple))
    return params, lifted


class Model:
    def __init__(self, cfg: ModelConfig, *, remat: bool = False):
        self.cfg = cfg
        self.remat = remat

    # ================================================================ init
    def init(self, key) -> tuple[Params, Any]:
        cfg = self.cfg
        ks = iter(jax.random.split(key, 16))
        params: dict = {}
        specs: dict = {}

        params["embed"], specs["embed"] = L.embed_init(next(ks), cfg)
        params["final_norm"] = L.rmsnorm_init(cfg.d_model)
        specs["final_norm"] = (None,)

        t = cfg.arch_type
        if t in ("dense", "vlm"):
            if cfg.local_global_ratio:
                r = cfg.local_global_ratio
                gsize = r + 1
                assert cfg.n_layers % gsize == 0
                ng = cfg.n_layers // gsize

                def group_init(k):
                    k1, k2 = jax.random.split(k)
                    loc, ls = _stack_init(
                        lambda kk: self._dense_layer_init(kk), k1, r)
                    glo, gs = self._dense_layer_init(k2)
                    return {"local": loc, "global": glo}, \
                           {"local": ls, "global": gs}
                params["groups"], specs["groups"] = _stack_init(
                    lambda k: group_init(k), next(ks), ng)
            else:
                params["layers"], specs["layers"] = _stack_init(
                    lambda k: self._dense_layer_init(k), next(ks),
                    cfg.n_layers)
        elif t == "moe":
            nd = cfg.first_k_dense
            if nd:
                params["dense_layers"], specs["dense_layers"] = _stack_init(
                    lambda k: self._dense_layer_init(k), next(ks), nd)
            params["layers"], specs["layers"] = _stack_init(
                lambda k: self._moe_layer_init(k), next(ks),
                cfg.n_layers - nd)
        elif t == "ssm":
            params["layers"], specs["layers"] = _stack_init(
                lambda k: self._ssm_layer_init(k), next(ks), cfg.n_layers)
        elif t == "hybrid":
            params["layers"], specs["layers"] = _stack_init(
                lambda k: self._ssm_layer_init(k), next(ks), cfg.n_layers)
            params["shared_attn"], specs["shared_attn"] = \
                self._dense_layer_init(next(ks))
        elif t == "audio":
            params["encoder"], specs["encoder"] = _stack_init(
                lambda k: self._dense_layer_init(k, causal=False),
                next(ks), cfg.encoder_layers)
            params["layers"], specs["layers"] = _stack_init(
                lambda k: self._dec_xattn_layer_init(k), next(ks),
                cfg.n_layers)
            params["enc_norm"] = L.rmsnorm_init(cfg.d_model)
            specs["enc_norm"] = (None,)
        else:
            raise ValueError(t)
        return params, specs

    # ---- per-layer inits
    def _dense_layer_init(self, key, causal=True):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        if cfg.attention == "mla":
            attn, aspec = L.mla_init(k1, cfg)
        else:
            attn, aspec = L.gqa_init(k1, cfg)
        ffn, fspec = L.ffn_init(k2, cfg)
        p = {"attn": attn, "ffn": ffn,
             "ln1": L.rmsnorm_init(cfg.d_model),
             "ln2": L.rmsnorm_init(cfg.d_model)}
        s = {"attn": aspec, "ffn": fspec, "ln1": (None,), "ln2": (None,)}
        return p, s

    def _moe_layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        attn, aspec = L.gqa_init(k1, cfg)
        moe, mspec = MOE.moe_init(k2, cfg)
        p = {"attn": attn, "moe": moe,
             "ln1": L.rmsnorm_init(cfg.d_model),
             "ln2": L.rmsnorm_init(cfg.d_model)}
        s = {"attn": aspec, "moe": mspec, "ln1": (None,), "ln2": (None,)}
        return p, s

    def _ssm_layer_init(self, key):
        cfg = self.cfg
        p, s = M.mamba2_init(key, cfg)
        return {"mamba": p, "ln": L.rmsnorm_init(cfg.d_model)}, \
               {"mamba": s, "ln": (None,)}

    def _dec_xattn_layer_init(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        attn, aspec = L.gqa_init(k1, cfg)
        xattn, xspec = L.gqa_init(k2, cfg)
        ffn, fspec = L.ffn_init(k3, cfg)
        p = {"attn": attn, "xattn": xattn, "ffn": ffn,
             "ln1": L.rmsnorm_init(cfg.d_model),
             "lnx": L.rmsnorm_init(cfg.d_model),
             "ln2": L.rmsnorm_init(cfg.d_model)}
        s = {"attn": aspec, "xattn": xspec, "ffn": fspec,
             "ln1": (None,), "lnx": (None,), "ln2": (None,)}
        return p, s

    # ============================================================ forward
    def forward(self, params: Params, batch: dict):
        """Training forward: returns (logits (B,S,V), aux_loss scalar)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s_text = tokens.shape
        h = L.embed_apply(params["embed"], tokens)
        if cfg.arch_type == "vlm" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(h.dtype)
            h = jnp.concatenate([ve, h], axis=1)
        if cfg.rope_theta <= 0 and cfg.arch_type != "ssm":
            h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model
                                           ).astype(h.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(h.shape[1]),
                                     (b, h.shape[1]))

        enc_out = None
        if cfg.arch_type == "audio":
            enc_out = self._encode(params, batch["frames"])

        h, aux = self._backbone(params, h, positions, enc_out=enc_out)
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = L.unembed_apply(params["embed"], h, cfg)
        if cfg.arch_type == "vlm" and "vision_embeds" in batch:
            logits = logits[:, -s_text:]     # loss only on text positions
        return logits, aux

    def _encode(self, params, frames):
        cfg = self.cfg
        h = frames.astype(L.ACT_DTYPE)
        h = h + L.sinusoidal_positions(h.shape[1],
                                       cfg.d_model).astype(h.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(h.shape[1]),
                                     (h.shape[0], h.shape[1]))

        def body(carry, lp):
            x = carry
            a, _ = L.gqa_apply(lp["attn"], L.rmsnorm(x, lp["ln1"]),
                               cfg, positions=positions, causal=False)
            x = x + a
            x = x + L.ffn_apply(lp["ffn"], L.rmsnorm(x, lp["ln2"]), cfg)
            return x, None
        if self.remat:
            body = RT.checkpoint_wrap(body)
        h, _ = RT.scan(body, h, params["encoder"])
        return L.rmsnorm(h, params["enc_norm"], cfg.norm_eps)

    # -------------------------------------------------------- backbones
    def _backbone(self, params, h, positions, *, enc_out=None,
                  caches=None, update_cache=False, decode=False):
        """Dispatch per family. Returns (h, aux) in train mode, or
        (h, aux, new_caches) when caches is not None."""
        cfg = self.cfg
        t = cfg.arch_type
        if t in ("dense", "vlm"):
            if cfg.local_global_ratio:
                out = self._dense_lg(params, h, positions, caches,
                                     update_cache, decode)
            else:
                out = self._dense_stack(params, h, positions, caches,
                                        update_cache, decode)
        elif t == "moe":
            out = self._moe_stack(params, h, positions, caches,
                                  update_cache, decode)
        elif t == "ssm":
            out = self._ssm_stack(params, h, positions, caches,
                                  update_cache, decode)
        elif t == "hybrid":
            out = self._hybrid_stack(params, h, positions, caches,
                                     update_cache, decode)
        elif t == "audio":
            out = self._audio_stack(params, h, positions, enc_out, caches,
                                    update_cache, decode)
        else:
            raise ValueError(t)
        if caches is None:
            h, aux = out
            return h, aux
        return out

    def _attn_apply(self, lp, x, positions, *, window=0, cache=None,
                    update_cache=False, causal=True):
        cfg = self.cfg
        xn = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if cfg.attention == "mla":
            return L.mla_apply(lp["attn"], xn, cfg, positions=positions,
                               cache=cache, update_cache=update_cache)
        cache_pos = None
        if cache is not None and window:
            cache_pos = cache["len"] % window
        return L.gqa_apply(lp["attn"], xn, cfg, positions=positions,
                           causal=causal, window=window, cache=cache,
                           cache_pos=cache_pos, update_cache=update_cache)

    def _dense_layer(self, lp, x, positions, *, window=0, cache=None,
                     update_cache=False):
        a, new_cache = self._attn_apply(lp, x, positions, window=window,
                                        cache=cache,
                                        update_cache=update_cache)
        x = x + a
        x = x + L.ffn_apply(lp["ffn"], L.rmsnorm(x, lp["ln2"],
                                                 self.cfg.norm_eps),
                            self.cfg)
        return x, new_cache

    def _dense_stack(self, params, h, positions, caches, update_cache,
                     decode):
        def body(carry, xs):
            x = carry
            if caches is None:
                x, _ = self._dense_layer(xs, x, positions)
                return x, None
            lp, cache = xs
            x, nc = self._dense_layer(lp, x, positions, cache=cache,
                                      update_cache=update_cache)
            return x, nc
        if self.remat:
            body = RT.checkpoint_wrap(body)
        if caches is None:
            h, _ = RT.scan(body, h, params["layers"])
            return h, jnp.zeros((), jnp.float32)
        h, new_caches = RT.scan(body, h, (params["layers"], caches))
        return h, jnp.zeros((), jnp.float32), new_caches

    def _dense_lg(self, params, h, positions, caches, update_cache,
                  decode):
        """gemma3: groups of (ratio local + 1 global), scanned."""
        cfg = self.cfg
        r = cfg.local_global_ratio
        w = cfg.sliding_window

        def body(carry, xs):
            x = carry
            if caches is None:
                gp = xs
                for i in range(r):
                    lp = jax.tree.map(lambda v: v[i], gp["local"])
                    x, _ = self._dense_layer(lp, x, positions, window=w)
                x, _ = self._dense_layer(gp["global"], x, positions)
                return x, None
            gp, gc = xs
            new_loc = []
            for i in range(r):
                lp = jax.tree.map(lambda v: v[i], gp["local"])
                lc = jax.tree.map(lambda v: v[i], gc["local"])
                x, nc = self._dense_layer(lp, x, positions, window=w,
                                          cache=lc,
                                          update_cache=update_cache)
                new_loc.append(nc)
            x, ngc = self._dense_layer(gp["global"], x, positions,
                                       cache=gc["global"],
                                       update_cache=update_cache)
            stacked = jax.tree.map(lambda *vs: jnp.stack(vs), *new_loc)
            return x, {"local": stacked, "global": ngc}
        if self.remat:
            body = RT.checkpoint_wrap(body)
        if caches is None:
            h, _ = RT.scan(body, h, params["groups"])
            return h, jnp.zeros((), jnp.float32)
        h, new_caches = RT.scan(body, h, (params["groups"], caches))
        return h, jnp.zeros((), jnp.float32), new_caches

    def _moe_stack(self, params, h, positions, caches, update_cache,
                   decode):
        cfg = self.cfg
        nd = cfg.first_k_dense
        aux_total = jnp.zeros((), jnp.float32)

        # leading dense layers (unrolled; nd is 0 or 1 in our configs)
        if nd:
            dcaches = caches["dense"] if caches is not None else [None] * nd
            new_dense = []
            for i in range(nd):
                lp = jax.tree.map(lambda v: v[i], params["dense_layers"])
                c = jax.tree.map(lambda v: v[i], dcaches) \
                    if caches is not None else None
                h, nc = self._dense_layer(lp, h, positions, cache=c,
                                          update_cache=update_cache)
                new_dense.append(nc)

        def body(carry, xs):
            x, aux = carry
            if caches is None:
                lp, cache = xs, None
            else:
                lp, cache = xs
            a, nc = self._attn_apply(lp, x, positions, cache=cache,
                                     update_cache=update_cache)
            x = x + a
            mo, a_loss = MOE.moe_apply(
                lp["moe"], L.rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
            x = x + mo
            return (x, aux + a_loss), nc
        if self.remat:
            body = RT.checkpoint_wrap(body)
        if caches is None:
            (h, aux_total), _ = RT.scan(body, (h, aux_total),
                                             params["layers"])
            return h, aux_total
        (h, aux_total), new_caches = RT.scan(
            body, (h, aux_total), (params["layers"], caches["moe"]))
        out_caches = {"moe": new_caches}
        if nd:
            out_caches["dense"] = jax.tree.map(lambda *vs: jnp.stack(vs),
                                               *new_dense)
        return h, aux_total, out_caches

    def _ssm_layer(self, lp, x, *, cache=None, update_cache=False):
        y, nc = M.mamba2_apply(lp["mamba"],
                               L.rmsnorm(x, lp["ln"], self.cfg.norm_eps),
                               self.cfg, cache=cache,
                               update_cache=update_cache)
        return x + y, nc

    def _ssm_stack(self, params, h, positions, caches, update_cache,
                   decode):
        def body(carry, xs):
            x = carry
            if caches is None:
                x, _ = self._ssm_layer(xs, x)
                return x, None
            lp, cache = xs
            x, nc = self._ssm_layer(lp, x, cache=cache,
                                    update_cache=update_cache)
            return x, nc
        if self.remat:
            body = RT.checkpoint_wrap(body)
        if caches is None:
            h, _ = RT.scan(body, h, params["layers"])
            return h, jnp.zeros((), jnp.float32)
        h, new_caches = RT.scan(body, h, (params["layers"], caches))
        return h, jnp.zeros((), jnp.float32), new_caches

    def _hybrid_stack(self, params, h, positions, caches, update_cache,
                      decode):
        """zamba2: mamba stack; ONE shared attn block every k layers."""
        cfg = self.cfg
        k = cfg.shared_attn_every
        nl = cfg.n_layers
        is_attn = jnp.array([(i % k) == 0 for i in range(nl)])
        attn_slot = jnp.array([i // k for i in range(nl)], jnp.int32)
        shared = params["shared_attn"]

        def apply_shared(x, attn_cache, slot):
            if attn_cache is None:
                y, _ = self._dense_layer(shared, x, positions)
                return y, None
            cache_l = jax.tree.map(
                lambda v: jax.lax.dynamic_index_in_dim(v, slot, 0,
                                                       keepdims=False),
                attn_cache)
            y, nc = self._dense_layer(shared, x, positions, cache=cache_l,
                                      update_cache=update_cache)
            new = jax.tree.map(
                lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                    full, upd.astype(full.dtype), slot, 0),
                attn_cache, nc)
            return y, new

        def body(carry, xs):
            if caches is None:
                x, _ = carry
                lp, flag, slot = xs
                x = jax.lax.cond(flag,
                                 lambda v: apply_shared(v, None, slot)[0],
                                 lambda v: v, x)
                x, _ = self._ssm_layer(lp, x)
                return (x, jnp.zeros((), jnp.int32)), None
            x, attn_cache = carry
            (lp, mcache), flag, slot = xs

            def with_attn(args):
                v, ac = args
                return apply_shared(v, ac, slot)

            x, attn_cache = jax.lax.cond(
                flag, with_attn, lambda args: args, (x, attn_cache))
            x, nmc = self._ssm_layer(lp, x, cache=mcache,
                                     update_cache=update_cache)
            return (x, attn_cache), nmc

        if self.remat:
            body = RT.checkpoint_wrap(body)
        if caches is None:
            (h, _), _ = RT.scan(
                body, (h, jnp.zeros((), jnp.int32)),
                (params["layers"], is_attn, attn_slot))
            return h, jnp.zeros((), jnp.float32)
        (h, new_attn), new_m = RT.scan(
            body, (h, caches["attn"]),
            ((params["layers"], caches["mamba"]), is_attn, attn_slot))
        return h, jnp.zeros((), jnp.float32), \
            {"attn": new_attn, "mamba": new_m}

    def _audio_stack(self, params, h, positions, enc_out, caches,
                     update_cache, decode):
        cfg = self.cfg
        enc_pos = None
        if enc_out is not None:
            enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                                       (enc_out.shape[0],
                                        enc_out.shape[1]))

        def xattn(lp, x, kv_src, cache):
            """Cross-attention; at decode, K/V come from the cache."""
            xn = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
            b, sq, _ = xn.shape
            hh, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
            p = lp["xattn"]
            q = (xn.astype(L.ACT_DTYPE) @ p["wq"].astype(L.ACT_DTYPE)
                 ).reshape(b, sq, hh, dh)
            if kv_src is None:          # decode: K/V from the prefill cache
                ck, cv = cache["k"], cache["v"]
            else:                       # train/prefill: from encoder output
                src = kv_src.astype(L.ACT_DTYPE)
                ck = (src @ p["wk"].astype(L.ACT_DTYPE)).reshape(
                    b, src.shape[1], hkv, dh)
                cv = (src @ p["wv"].astype(L.ACT_DTYPE)).reshape(
                    b, src.shape[1], hkv, dh)
            out = L.full_attention(q, ck, cv, causal=False)
            out = out.reshape(b, sq, hh * dh) @ p["wo"].astype(L.ACT_DTYPE)
            return out.astype(x.dtype), {"k": ck, "v": cv}

        def body(carry, xs):
            x = carry
            if caches is None:
                lp, self_c, cross_c = xs, None, None
            else:
                lp, self_c, cross_c = xs
            a, nsc = self._attn_apply(lp, x, positions, cache=self_c,
                                      update_cache=update_cache)
            x = x + a
            xa, ncc = xattn(lp, x, enc_out, cross_c)
            x = x + xa
            x = x + L.ffn_apply(lp["ffn"],
                                L.rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
            if caches is None:
                return x, None
            return x, (nsc, ncc)
        if self.remat:
            body = RT.checkpoint_wrap(body)
        if caches is None:
            h, _ = RT.scan(body, h, params["layers"])
            return h, jnp.zeros((), jnp.float32)
        h, (new_self, new_cross) = RT.scan(
            body, h, (params["layers"], caches["self"], caches["cross"]))
        return h, jnp.zeros((), jnp.float32), \
            {"self": new_self, "cross": new_cross}

    # ========================================================== serving
    def cache_init(self, batch: int, max_len: int) -> Any:
        """Stacked (per-layer) cache pytrees for prefill/decode."""
        cfg = self.cfg
        t = cfg.arch_type

        def stack(make, n):
            return jax.tree.map(lambda *vs: jnp.stack(vs),
                                *[make() for _ in range(n)])

        if t in ("dense", "vlm"):
            if cfg.attention == "mla":
                return stack(lambda: L.mla_cache_init(cfg, batch, max_len),
                             cfg.n_layers)
            if cfg.local_global_ratio:
                r = cfg.local_global_ratio
                ng = cfg.n_layers // (r + 1)
                return stack(
                    lambda: {
                        "local": stack(
                            lambda: L.gqa_cache_init(
                                cfg, batch, max_len,
                                window=cfg.sliding_window), r),
                        "global": L.gqa_cache_init(cfg, batch, max_len),
                    }, ng)
            return stack(lambda: L.gqa_cache_init(cfg, batch, max_len),
                         cfg.n_layers)
        if t == "moe":
            nd = cfg.first_k_dense
            out = {"moe": stack(
                lambda: L.gqa_cache_init(cfg, batch, max_len),
                cfg.n_layers - nd)}
            if nd:
                out["dense"] = stack(
                    lambda: L.gqa_cache_init(cfg, batch, max_len), nd)
            return out
        if t == "ssm":
            return stack(lambda: M.mamba2_cache_init(cfg, batch),
                         cfg.n_layers)
        if t == "hybrid":
            n_attn = -(-cfg.n_layers // cfg.shared_attn_every)
            return {
                "mamba": stack(lambda: M.mamba2_cache_init(cfg, batch),
                               cfg.n_layers),
                "attn": stack(lambda: L.gqa_cache_init(cfg, batch,
                                                       max_len), n_attn),
            }
        if t == "audio":
            return {
                "self": stack(lambda: L.gqa_cache_init(cfg, batch,
                                                       max_len),
                              cfg.n_layers),
                "cross": stack(
                    lambda: {"k": jnp.zeros((batch, cfg.encoder_frames,
                                             cfg.n_kv_heads, cfg.d_head),
                                            L.ACT_DTYPE),
                             "v": jnp.zeros((batch, cfg.encoder_frames,
                                             cfg.n_kv_heads, cfg.d_head),
                                            L.ACT_DTYPE)},
                    cfg.n_layers),
            }
        raise ValueError(t)

    def cache_specs(self) -> Any:
        """Logical-axis tree matching cache_init (leading layer axis)."""
        cfg = self.cfg
        t = cfg.arch_type
        lift = lambda tree: jax.tree.map(
            lambda lg: (None,) + lg, tree,
            is_leaf=lambda x: isinstance(x, tuple))
        if t in ("dense", "vlm"):
            if cfg.attention == "mla":
                return lift(L.mla_cache_specs())
            if cfg.local_global_ratio:
                return lift({"local": lift(L.gqa_cache_specs(window=True)),
                             "global": L.gqa_cache_specs()})
            return lift(L.gqa_cache_specs())
        if t == "moe":
            out = {"moe": lift(L.gqa_cache_specs())}
            if cfg.first_k_dense:
                out["dense"] = lift(L.gqa_cache_specs())
            return out
        if t == "ssm":
            return lift(M.mamba2_cache_specs())
        if t == "hybrid":
            return {"mamba": lift(M.mamba2_cache_specs()),
                    "attn": lift(L.gqa_cache_specs())}
        if t == "audio":
            return {"self": lift(L.gqa_cache_specs()),
                    "cross": lift({"k": ("dp", None, None, None),
                                   "v": ("dp", None, None, None)})}
        raise ValueError(t)

    def prefill(self, params, batch: dict, caches):
        """Full-sequence forward writing caches; returns (last-position
        logits, caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b = tokens.shape[0]
        h = L.embed_apply(params["embed"], tokens)
        if cfg.arch_type == "vlm" and "vision_embeds" in batch:
            h = jnp.concatenate(
                [batch["vision_embeds"].astype(h.dtype), h], axis=1)
        if cfg.rope_theta <= 0 and cfg.arch_type != "ssm":
            h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model
                                           ).astype(h.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(h.shape[1]),
                                     (b, h.shape[1]))
        enc_out = None
        if cfg.arch_type == "audio":
            enc_out = self._encode(params, batch["frames"])
        h, _, caches = self._backbone(params, h, positions,
                                      enc_out=enc_out, caches=caches,
                                      update_cache=True)
        h = L.rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
        return L.unembed_apply(params["embed"], h, cfg)[:, 0], caches

    def decode_step(self, params, token, caches):
        """One token (B,) + caches -> (logits (B,V), new caches)."""
        cfg = self.cfg
        b = token.shape[0]
        h = L.embed_apply(params["embed"], token[:, None])
        pos_scalar = self._cache_len(caches)
        positions = jnp.broadcast_to(pos_scalar[None, None], (b, 1))
        if cfg.rope_theta <= 0 and cfg.arch_type != "ssm":
            sin = L.sinusoidal_positions(1, cfg.d_model, offset=pos_scalar)
            h = h + sin.astype(h.dtype)[None]
        h, _, caches = self._backbone(params, h, positions, caches=caches,
                                      update_cache=True, decode=True)
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return L.unembed_apply(params["embed"], h, cfg)[:, 0], caches

    def _cache_len(self, caches) -> jax.Array:
        cfg = self.cfg
        t = cfg.arch_type
        if t in ("dense", "vlm"):
            if cfg.local_global_ratio:
                return caches["global"]["len"][0]
            return caches["len"][0]
        if t == "moe":
            return caches["moe"]["len"][0]
        if t == "hybrid":
            return caches["attn"]["len"][0]
        if t == "audio":
            return caches["self"]["len"][0]
        # pure ssm: track via a dedicated counter in conv cache? use zero
        return jnp.zeros((), jnp.int32)
