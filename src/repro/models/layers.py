"""Shared transformer building blocks (pure-functional, pjit-friendly).

Conventions
-----------
* Params are nested dicts of f32 arrays; a parallel "logical spec" tree
  (same structure, leaves = tuples of logical axis names from
  ``repro.sharding.rules``) describes the production sharding.
* Compute runs in bf16 with f32 softmax/norm accumulators.
* Every block comes in three modes: ``train/prefill`` (full sequence,
  optionally writing a KV cache) and ``decode`` (one token + cache).
* Layer stacks are scanned (``jax.lax.scan``) over a leading layer axis
  so HLO size is depth-independent (95-layer models compile in seconds).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import runtime as RT

Params = dict
Specs = dict

ACT_DTYPE = jnp.bfloat16


# ------------------------------------------------------------------- init

def _normal(key, shape, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32))


def dense_init(key, d_in, d_out, scale=0.02):
    return _normal(key, (d_in, d_out), scale)


# ------------------------------------------------------------------ norms

def wgather(w, logical):
    """Under GATHER_WEIGHTS, constrain an fsdp-sharded weight to TP-only
    sharding at its use site: XLA then all-gathers the (small) weight
    instead of all-reducing the (huge) activation partials that a
    contraction over an fsdp-sharded dim otherwise produces."""
    if not RT.GATHER_WEIGHTS:
        return w
    from jax.sharding import PartitionSpec as P
    spec = P(*[("model" if n == "tp" else None) for n in logical])
    return jax.lax.with_sharding_constraint(w, spec)


def rmsnorm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + w)).astype(x.dtype)


def rmsnorm_init(d):
    return jnp.zeros((d,), jnp.float32)


# ------------------------------------------------------------------- rope

def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    if x.ndim == 4:  # (B, S, H, D): broadcast over heads
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int, offset=0):
    pos = jnp.arange(seq_len) + offset
    half = d // 2
    freq = 10_000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)  # (S, d)


# -------------------------------------------------------------- attention

def _gqa_scores(q, k, scale):
    """q (B,Sq,H,D), k (B,Sk,Hkv,D) -> scores (B,Hkv,G,Sq,Sk) f32."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if RT.SCORES_BF16:
        # store the (..., Sq, Sk) tensor in bf16 (halves the dominant
        # HBM-traffic term); softmax still reduces in f32
        s = s.astype(jnp.bfloat16)
    return s


def _mask_bias(sq, sk, *, causal, window, q_offset, kv_valid_len=None):
    qpos = jnp.arange(sq)[:, None] + q_offset          # (Sq, 1)
    kpos = jnp.arange(sk)[None, :]                     # (1, Sk)
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    if kv_valid_len is not None:
        ok &= kpos < kv_valid_len
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def full_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                   kv_valid_len=None, softcap=0.0):
    """Materialized-scores attention (short sequences / decode)."""
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q, k, scale)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = scores + _mask_bias(q.shape[1], k.shape[1], causal=causal,
                                 window=window, q_offset=q_offset,
                                 kv_valid_len=kv_valid_len
                                 ).astype(scores.dtype)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    b, sq, hkv, g, d = out.shape
    return out.reshape(b, sq, hkv * g, d)


def chunked_attention(q, k, v, *, chunk=1024, causal=True, window=0,
                      q_offset=0):
    """Flash-style online-softmax over KV chunks — O(Sq * chunk) score
    memory instead of O(Sq * Sk). Used for 32k+ prefill.

    (This is the XLA-lowered path used by the dry-run; a Pallas flash
    kernel with the same oracle lives in repro/kernels/flash_attn.py.)
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                       # may differ from d (MLA)
    chunk = min(chunk, sk)
    assert sk % chunk == 0, (sk, chunk)
    g = h // hkv
    scale = d ** -0.5
    n_chunks = sk // chunk

    kc = k.reshape(b, n_chunks, chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, dv).transpose(1, 0, 2, 3, 4)

    qpos = jnp.arange(sq)[:, None] + q_offset

    def step(carry, xs):
        m, l, acc = carry
        c_idx, k_blk, v_blk = xs
        scores = _gqa_scores(q, k_blk, scale)          # (B,Hkv,G,Sq,chunk)
        kpos = c_idx * chunk + jnp.arange(chunk)[None, :]
        ok = jnp.ones((sq, chunk), bool)
        if causal:
            ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
        scores = scores + jnp.where(ok, 0.0, -jnp.inf).astype(scores.dtype)
        m_new = jnp.maximum(m, jnp.max(scores, -1))
        # guard: fully-masked rows keep m = -inf -> exp(0)=1 but l stays 0
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        corr = jnp.exp(jnp.where(jnp.isinf(m), m, m - m_safe))
        p = jnp.exp(scores - m_safe[..., None])
        l_new = l * corr + jnp.sum(p, -1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v_blk)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, dv), v.dtype)
    (m, l, acc), _ = RT.scan(
        step, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-20)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)


def attention_any(q, k, v, **kw):
    if (q.shape[1] >= RT.CHUNKED_THRESHOLD
            and q.shape[1] == k.shape[1]):
        kw.pop("kv_valid_len", None)
        kw.pop("softcap", None)
        return chunked_attention(q, k, v, **kw)
    return full_attention(q, k, v, **kw)


# ------------------------------------------------------------ GQA block

def gqa_init(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], d, h * dh),
        "wk": dense_init(ks[1], d, hkv * dh),
        "wv": dense_init(ks[2], d, hkv * dh),
        "wo": dense_init(ks[3], h * dh, d, scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    specs = {"wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"),
             "wv": ("fsdp", "tp"), "wo": ("tp", "fsdp")}
    return params, specs


def gqa_apply(p: Params, x, cfg: ModelConfig, *, positions, causal=True,
              window=0, cache: Optional[dict] = None,
              cache_pos=None, update_cache=False):
    """Returns (out, new_cache). Modes:
       * train: cache=None
       * prefill: update_cache=True, cache dict of zeros provided
       * decode: x has Sq=1, cache holds Sk past keys; cache_pos = scalar
         write offset (ring position for windowed layers).
    """
    b, sq, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    xb = x.astype(ACT_DTYPE)
    wg = lambda w: wgather(w, ("fsdp", "tp")).astype(ACT_DTYPE)
    q = (xb @ wg(p["wq"])).reshape(b, sq, h, dh)
    k = (xb @ wg(p["wk"])).reshape(b, sq, hkv, dh)
    v = (xb @ wg(p["wv"])).reshape(b, sq, hkv, dh)
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = cache
    if cache is not None and sq == 1:           # decode
        slot = cache_pos if window else cache["len"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        valid = jnp.minimum(cache["len"] + 1, ck.shape[1])
        out = full_attention(q, ck, cv, causal=False, kv_valid_len=valid,
                             softcap=cfg.logit_softcap)
        new_cache = {"k": ck, "v": cv, "len": cache["len"] + 1}
    else:                                        # train / prefill
        out = attention_any(q, k, v, causal=causal, window=window,
                            q_offset=0)
        if update_cache and cache is not None:
            cap = cache["k"].shape[1]
            if sq >= cap:
                # ring buffer: position p lives at slot p % cap; the last
                # `cap` keys land rolled by sq % cap so decode writes at
                # slot len % cap stay consistent
                shift = sq % cap
                nk = jnp.roll(k[:, -cap:], shift, axis=1)
                nv = jnp.roll(v[:, -cap:], shift, axis=1)
            else:
                nk = jax.lax.dynamic_update_slice(cache["k"], k,
                                                  (0, 0, 0, 0))
                nv = jax.lax.dynamic_update_slice(cache["v"], v,
                                                  (0, 0, 0, 0))
            new_cache = {"k": nk, "v": nv, "len": cache["len"] + sq}
    out = out.reshape(b, sq, h * dh) @ wgather(
        p["wo"], ("tp", "fsdp")).astype(ACT_DTYPE)
    return out.astype(x.dtype), new_cache


def gqa_cache_init(cfg: ModelConfig, batch: int, max_len: int, *,
                   window: int = 0) -> dict:
    s = min(window, max_len) if window else max_len
    shape = (batch, s, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, ACT_DTYPE),
            "v": jnp.zeros(shape, ACT_DTYPE),
            "len": jnp.zeros((), jnp.int32)}


def gqa_cache_specs(window: bool = False) -> dict:
    # batch over dp; sequence over tp ("sp") for the huge flat caches.
    # Window (ring) caches also shard seq under WINDOW_CACHE_SP: a
    # model-replicated window cache forces a full-cache all-gather per
    # decode step (measured 2x335 MB/group on gemma3), because the new
    # K/V rows arrive model-sharded from the TP projections.
    seq_ax = ("sp" if RT.WINDOW_CACHE_SP else None) if window else "sp"
    return {"k": ("dp", seq_ax, None, None),
            "v": ("dp", seq_ax, None, None), "len": ()}


# ------------------------------------------------------------- MLA block

def _mla_heads(cfg: ModelConfig) -> int:
    if RT.MLA_PAD_HEADS:
        return -(-cfg.n_heads // 16) * 16
    return cfg.n_heads


def mla_init(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    d, h = cfg.d_model, _mla_heads(cfg)
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    params = {
        "wq_a": dense_init(ks[0], d, rq),
        "q_norm": rmsnorm_init(rq),
        "wq_b": dense_init(ks[1], rq, h * (dn + dr)),
        "wkv_a": dense_init(ks[2], d, rkv + dr),
        "kv_norm": rmsnorm_init(rkv),
        "wkv_b": dense_init(ks[3], rkv, h * (dn + dv)),
        "wo": dense_init(ks[4], h * dv, d,
                         scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    if h != cfg.n_heads:  # zero the dummy heads' output rows: the padded
        # heads are then function-inert at init (pure sharding padding)
        wo = params["wo"].reshape(h, dv, d)
        wo = wo.at[cfg.n_heads:].set(0.0)
        params["wo"] = wo.reshape(h * dv, d)
    specs = {"wq_a": ("fsdp", None), "q_norm": (None,),
             "wq_b": ("fsdp", "tp"), "wkv_a": ("fsdp", None),
             "kv_norm": (None,), "wkv_b": ("fsdp", "tp"),
             "wo": ("tp", "fsdp")}
    return params, specs


def _mla_qkr(p, x, cfg, positions):
    """Shared q / compressed-kv projections. Returns q_nope (B,S,H,dn),
    q_rope (B,S,H,dr), c_kv (B,S,rkv), k_rope (B,S,1,dr)."""
    b, s, _ = x.shape
    h = _mla_heads(cfg)
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    xb = x.astype(ACT_DTYPE)
    q = rmsnorm(xb @ p["wq_a"].astype(ACT_DTYPE), p["q_norm"], cfg.norm_eps)
    q = (q @ p["wq_b"].astype(ACT_DTYPE)).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = xb @ p["wkv_a"].astype(ACT_DTYPE)                  # (B,S,rkv+dr)
    c_kv = rmsnorm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][:, :, None, :]      # (B,S,1,dr)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    k_rope = rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(p: Params, x, cfg: ModelConfig, *, positions,
              cache: Optional[dict] = None, update_cache=False):
    """MLA attention. Prefill/train expands per-head K/V; decode uses the
    ABSORBED path against the compressed cache (the MLA trick)."""
    b, sq, d = x.shape
    h = _mla_heads(cfg)
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(p, x, cfg, positions)
    wkv_b = p["wkv_b"].astype(ACT_DTYPE).reshape(rkv, h, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]           # (rkv,H,dn/(dv))

    new_cache = cache
    if cache is not None and sq == 1:  # ---- absorbed decode
        slot = cache["len"]
        ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_kv, (0, slot, 0))
        krp = jax.lax.dynamic_update_slice(cache["krope"],
                                           k_rope[:, :, 0, :], (0, slot, 0))
        # absorb W_uk into q:  q_c (B,1,H,rkv)
        q_c = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
        s_c = jnp.einsum("bqhr,bkr->bhqk", q_c, ckv,
                         preferred_element_type=jnp.float32)
        s_r = jnp.einsum("bqhd,bkd->bhqk", q_rope, krp,
                         preferred_element_type=jnp.float32)
        scores = (s_c + s_r) * scale
        valid = jnp.arange(ckv.shape[1])[None, None, None, :] < (slot + 1)
        scores = jnp.where(valid, scores, -jnp.inf)
        w = jax.nn.softmax(scores, -1).astype(ACT_DTYPE)
        ctx = jnp.einsum("bhqk,bkr->bqhr", w, ckv)          # (B,1,H,rkv)
        out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)       # absorb W_uv
        new_cache = {"ckv": ckv, "krope": krp, "len": cache["len"] + 1}
    else:  # ---- train / prefill: expand per-head K and V
        kv = jnp.einsum("bkr,rhe->bkhe", c_kv, wkv_b)       # (B,S,H,dn+dv)
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, sq, h, dr))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = attention_any(q, k, v, causal=True)
        if update_cache and cache is not None:
            new_cache = {
                "ckv": jax.lax.dynamic_update_slice(cache["ckv"], c_kv,
                                                    (0, 0, 0)),
                "krope": jax.lax.dynamic_update_slice(
                    cache["krope"], k_rope[:, :, 0, :], (0, 0, 0)),
                "len": cache["len"] + sq,
            }
    out = out.reshape(b, sq, h * dv) @ p["wo"].astype(ACT_DTYPE)
    return out.astype(x.dtype), new_cache


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), ACT_DTYPE),
            "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), ACT_DTYPE),
            "len": jnp.zeros((), jnp.int32)}


def mla_cache_specs() -> dict:
    return {"ckv": ("dp", "sp", None), "krope": ("dp", "sp", None),
            "len": ()}


# -------------------------------------------------------------------- FFN

def ffn_init(key, cfg: ModelConfig, d_ff: Optional[int] = None
             ) -> tuple[Params, Specs]:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    ks = jax.random.split(key, 3)
    out_scale = 0.02 / (2 * cfg.n_layers) ** 0.5
    if cfg.act == "swiglu":
        params = {"w_gate": dense_init(ks[0], d, f),
                  "w_up": dense_init(ks[1], d, f),
                  "w_down": dense_init(ks[2], f, d, scale=out_scale)}
        specs = {"w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
                 "w_down": ("tp", "fsdp")}
    else:
        params = {"w_in": dense_init(ks[0], d, f),
                  "w_down": dense_init(ks[2], f, d, scale=out_scale)}
        specs = {"w_in": ("fsdp", "tp"), "w_down": ("tp", "fsdp")}
    return params, specs


def ffn_apply(p: Params, x, cfg: ModelConfig):
    xb = x.astype(ACT_DTYPE)
    wg = lambda w: wgather(w, ("fsdp", "tp")).astype(ACT_DTYPE)
    wd = wgather(p["w_down"], ("tp", "fsdp")).astype(ACT_DTYPE)
    if cfg.act == "swiglu":
        h = jax.nn.silu(xb @ wg(p["w_gate"])) * (xb @ wg(p["w_up"]))
    else:
        h = jax.nn.gelu(xb @ wg(p["w_in"]))
    return (h @ wd).astype(x.dtype)


# -------------------------------------------------------- embed / unembed

def embed_init(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    ks = jax.random.split(key, 2)
    v = cfg.padded_vocab
    params = {"table": _normal(ks[0], (v, cfg.d_model))}
    specs = {"table": ("tp", "fsdp")}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[1], cfg.d_model, v)
        specs["unembed"] = ("fsdp", "tp")
    return params, specs


def embed_apply(p: Params, tokens):
    if RT.EMBED_ONEHOT:
        # vocab-parallel lookup: one-hot matmul against the vocab-sharded
        # table lowers to a local matmul + psum instead of all-gathering
        # the table (gemma3's 4 GB table made decode collective-bound)
        v = p["table"].shape[0]
        oh = jax.nn.one_hot(tokens, v, dtype=ACT_DTYPE)
        return oh @ p["table"].astype(ACT_DTYPE)
    return jnp.take(p["table"].astype(ACT_DTYPE), tokens, axis=0)


def unembed_apply(p: Params, x, cfg: ModelConfig):
    """Logits over the PADDED vocab; padded columns masked to -1e9 so
    they are inert in both softmax-CE and greedy/sampled decode."""
    xb = x.astype(ACT_DTYPE)
    if "unembed" in p:
        logits = xb @ p["unembed"].astype(ACT_DTYPE)
    else:
        logits = xb @ p["table"].astype(ACT_DTYPE).T
    if cfg.padded_vocab != cfg.vocab_size:
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.asarray(-1e9, logits.dtype))
    return logits
