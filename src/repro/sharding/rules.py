"""Logical-axis sharding rules -> PartitionSpec.

Params and activations are annotated with LOGICAL axis names; a rules
table maps them onto physical mesh axes at launch time. Production
layout is 2-D: "fsdp" (ZeRO-3-style weight sharding over the data axes,
gathered on use) x "tp" (Megatron-style tensor parallelism over the
model axis). MoE experts ride the model axis ("expert").

  fsdp   -> ("pod", "data")  [multi-pod]  /  ("data",)  [single pod]
  tp     -> ("model",)
  expert -> ("model",)
  dp     -> batch axis of activations, ("pod", "data")
  sp     -> sequence sharding for giant decode KV caches
  None   -> replicated
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def rules_for(mesh: Optional[Mesh], *, serve_pure_tp: bool = False) -> dict:
    if mesh is None:  # smoke tests: single device, everything replicated
        return {"fsdp": None, "tp": None, "expert": None, "dp": None,
                "sp": None, None: None}
    dp = dp_axes(mesh)
    return {
        # inference: weights stay TP-resident; an fsdp(-sharded) weight
        # contraction makes XLA all-reduce full activations (measured:
        # 5.7 GB/layer on deepseek-moe prefill) instead of gathering the
        # 90 MB weight — pure TP removes that entire class of traffic
        "fsdp": None if serve_pure_tp else (dp if dp else None),
        "tp": "model" if "model" in mesh.axis_names else None,
        "expert": "model" if "model" in mesh.axis_names else None,
        "dp": dp if dp else None,
        "sp": "model" if "model" in mesh.axis_names else None,
        None: None,
    }


def spec(logical: tuple, mesh: Optional[Mesh], *,
         serve_pure_tp: bool = False) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    r = rules_for(mesh, serve_pure_tp=serve_pure_tp)
    return P(*[r[name] for name in logical])


def tree_specs(logical_tree: Any, mesh: Optional[Mesh]) -> Any:
    """Map a pytree of logical tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(lambda lg: spec(lg, mesh), logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings(logical_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(logical_tree, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def divisible(n: int, mesh: Optional[Mesh], axis: str) -> bool:
    if mesh is None or axis not in mesh.axis_names:
        return True
    return n % mesh.shape[axis] == 0
