"""Dataset utilities: normalization, splits, and sharded batching."""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def normalize(x: np.ndarray, *, kind: str = "standard") -> np.ndarray:
    """standard: zero-mean unit-variance per feature; minmax: [0, 1]."""
    x = np.asarray(x, np.float32)
    if kind == "standard":
        mu = x.mean(0, keepdims=True)
        sd = x.std(0, keepdims=True)
        return (x - mu) / np.maximum(sd, 1e-8)
    if kind == "minmax":
        lo = x.min(0, keepdims=True)
        hi = x.max(0, keepdims=True)
        return (x - lo) / np.maximum(hi - lo, 1e-8)
    raise ValueError(kind)


def train_test_split(x: np.ndarray, y: np.ndarray, *, test_frac: float = 0.2,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    n = len(y)
    perm = rng.permutation(n)
    n_test = int(round(n * test_frac))
    te, tr = perm[:n_test], perm[n_test:]
    return x[tr], y[tr], x[te], y[te]


def subsample_per_class(x: np.ndarray, y: np.ndarray, n_per_class: int,
                        *, classes: Optional[list] = None, seed: int = 0):
    """The paper's protocol: N sample points *per class*."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y) if classes is None else np.asarray(classes)
    idx = []
    for c in classes:
        members = np.where(y == c)[0]
        take = min(n_per_class, len(members))
        idx.append(rng.choice(members, take, replace=False))
    idx = np.concatenate(idx)
    return x[idx], y[idx]


def batch_iterator(x: np.ndarray, y: np.ndarray, batch_size: int, *,
                   seed: int = 0, mesh: Optional[Mesh] = None,
                   data_axes: tuple[str, ...] = ("data",),
                   drop_remainder: bool = True
                   ) -> Iterator[tuple[jax.Array, jax.Array]]:
    """Shuffled epoch iterator; with a mesh, batches are device_put with
    the batch dimension sharded over ``data_axes``."""
    rng = np.random.default_rng(seed)
    n = len(y)
    perm = rng.permutation(n)
    sharding = None
    if mesh is not None:
        sharding = NamedSharding(mesh, P(data_axes))
    for start in range(0, n - (batch_size - 1 if drop_remainder else 0),
                       batch_size):
        sel = perm[start:start + batch_size]
        bx, by = jnp.asarray(x[sel]), jnp.asarray(y[sel])
        if sharding is not None:
            bx = jax.device_put(bx, sharding)
            by = jax.device_put(by, sharding)
        yield bx, by
