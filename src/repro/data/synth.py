"""Synthetic stand-ins for the paper's non-redistributable datasets.

The Pavia Centre hyperspectral scene and the Breast Cancer Wisconsin
tables cannot ship inside this offline container, so we synthesize
datasets with the SAME shape statistics (features, classes, sizes) and a
controlled degree of class separation. The benchmarks only measure
training TIME vs sample count (the paper's axis is speedup, not
accuracy), so matched shapes + a realistic conditioning of the Gram
matrix are what matters.

* ``load_pavia_like``  — 102 spectral bands, 9 classes; per-class spectra
  are smooth correlated curves (random Fourier mixtures) + band noise,
  mimicking hyperspectral pixel statistics.
* ``load_breast_cancer_like`` — 569 samples, 32 features (30 informative
  + id-like noise), 2 classes with partial overlap.
* ``make_blobs`` — generic Gaussian clusters.
* ``make_synth_regression`` — smooth nonlinear regression targets for
  the epsilon-SVR subsystem.
"""
from __future__ import annotations

import numpy as np


def make_blobs(n_per_class: int, n_classes: int, n_features: int, *,
               sep: float = 3.0, seed: int = 0,
               cov_scale: float = 1.0) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    centers = rng.normal(scale=sep, size=(n_classes, n_features))
    xs, ys = [], []
    for c in range(n_classes):
        xs.append(centers[c] +
                  cov_scale * rng.normal(size=(n_per_class, n_features)))
        ys.append(np.full(n_per_class, c, np.int64))
    x = np.concatenate(xs, 0).astype(np.float32)
    y = np.concatenate(ys, 0)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def make_imbalanced_blobs(class_sizes: "list[int] | tuple[int, ...]",
                          n_features: int, *, sep: float = 3.0,
                          seed: int = 0, cov_scale: float = 1.0
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Gaussian clusters with a DIFFERENT sample count per class — the
    load-imbalance regime the size-bucketed multiclass scheduler targets
    (one-vs-one task lengths then span sum-of-two-class sizes)."""
    rng = np.random.default_rng(seed)
    n_classes = len(class_sizes)
    centers = rng.normal(scale=sep, size=(n_classes, n_features))
    xs, ys = [], []
    for c, n in enumerate(class_sizes):
        xs.append(centers[c] +
                  cov_scale * rng.normal(size=(n, n_features)))
        ys.append(np.full(n, c, np.int64))
    x = np.concatenate(xs, 0).astype(np.float32)
    y = np.concatenate(ys, 0)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def make_synth_regression(n_samples: int, n_features: int = 6, *,
                          kind: str = "sinc", noise: float = 0.1,
                          seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Regression fixture for the epsilon-SVR subsystem: a smooth
    nonlinear (or exactly linear) function of a random 1-D projection of
    x, plus Gaussian noise of scale ``noise``.

    * ``kind="sinc"``   — sinc(2t) + 0.5 sin(t): the classic smooth
      RBF-SVR target (bounded, infinitely differentiable, non-monotone).
    * ``kind="linear"`` — t itself: the analytic case a linear-kernel
      SVR must recover exactly.
    """
    rng = np.random.default_rng(seed)
    x = rng.uniform(-2.0, 2.0, size=(n_samples, n_features))
    w = rng.normal(size=(n_features,))
    w /= np.linalg.norm(w)
    t = x @ w
    if kind == "sinc":
        y = np.sinc(2.0 * t) + 0.5 * np.sin(t)
    elif kind == "linear":
        y = t
    else:
        raise ValueError(f"unknown regression target {kind!r}; "
                         "expected 'sinc' or 'linear'")
    y = y + noise * rng.normal(size=n_samples)
    return x.astype(np.float32), y.astype(np.float32)


def load_pavia_like(n_per_class: int = 800, *, n_classes: int = 9,
                    n_bands: int = 102, seed: int = 7,
                    noise: float = 0.15) -> tuple[np.ndarray, np.ndarray]:
    """Hyperspectral-like: each class is a smooth spectral signature."""
    rng = np.random.default_rng(seed)
    wav = np.linspace(0.0, 1.0, n_bands)
    xs, ys = [], []
    for c in range(n_classes):
        # smooth class signature: low-order Fourier mixture
        coef = rng.normal(size=(6,))
        phase = rng.uniform(0, 2 * np.pi, size=(6,))
        sig = sum(coef[k] * np.sin(2 * np.pi * (k + 1) * wav + phase[k])
                  for k in range(6))
        sig = sig + rng.uniform(1.0, 3.0)  # reflectance offset
        # per-pixel: signature * illumination + correlated band noise
        illum = rng.uniform(0.7, 1.3, size=(n_per_class, 1))
        band_noise = rng.normal(scale=noise, size=(n_per_class, n_bands))
        # correlate the noise along the band axis (moving average)
        kern = np.ones(7) / 7.0
        band_noise = np.apply_along_axis(
            lambda v: np.convolve(v, kern, mode="same"), 1, band_noise)
        xs.append((sig[None, :] * illum + band_noise).astype(np.float32))
        ys.append(np.full(n_per_class, c, np.int64))
    x = np.concatenate(xs, 0)
    y = np.concatenate(ys, 0)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


def load_breast_cancer_like(n_samples: int = 569, *, n_features: int = 32,
                            seed: int = 13) -> tuple[np.ndarray, np.ndarray]:
    """Two overlapping classes, 30 informative + 2 noise features,
    class prior ~ (357 benign, 212 malignant) like the original."""
    rng = np.random.default_rng(seed)
    n_pos = int(round(n_samples * 357 / 569))
    n_neg = n_samples - n_pos
    mean_shift = rng.normal(scale=1.2, size=(n_features,))
    mean_shift[-2:] = 0.0  # uninformative tail features
    x_pos = rng.normal(size=(n_pos, n_features))
    x_neg = rng.normal(size=(n_neg, n_features)) + mean_shift
    x = np.concatenate([x_pos, x_neg], 0).astype(np.float32)
    y = np.concatenate([np.zeros(n_pos, np.int64), np.ones(n_neg, np.int64)])
    perm = rng.permutation(len(y))
    return x[perm], y[perm]
