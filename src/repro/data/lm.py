"""Synthetic language-model token pipeline (for the train-LM examples).

Offline container -> no corpus; we generate a deterministic, structured
token stream a transformer can actually learn (so loss curves are
meaningful): a Markov-ish "grammar" over the vocab with local n-gram
structure plus copy spans — losses drop well below uniform as the model
learns, which the end-to-end driver asserts.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


def _markov_stream(rng: np.random.Generator, vocab: int, length: int,
                   table: np.ndarray) -> np.ndarray:
    """Tokens from a sparse random transition table + copy spans."""
    order_states = table.shape[0]
    out = np.empty(length, np.int32)
    s = 0
    i = 0
    while i < length:
        if rng.random() < 0.05 and i > 32:
            # copy span: repeat a recent window (in-context structure)
            span = int(rng.integers(8, 32))
            start = int(rng.integers(max(0, i - 256), i - span)) if i - span > 0 else 0
            take = min(span, length - i)
            out[i:i + take] = out[start:start + take]
            i += take
            continue
        tok = int(table[s, int(rng.integers(0, 8))])
        out[i] = tok
        s = tok % order_states
        i += 1
    return out


def token_batches(*, vocab_size: int, batch: int, seq_len: int,
                  n_batches: int, seed: int = 0) -> Iterator[dict]:
    """Yields {tokens: (batch, seq_len) int32, labels: same (shift-by-1)}."""
    rng = np.random.default_rng(seed)
    # ONE fixed transition table for the whole stream — the learnable
    # structure must be stable across batches
    table = rng.integers(0, vocab_size, size=(257, 8))
    for _ in range(n_batches):
        stream = _markov_stream(rng, vocab_size, batch * (seq_len + 1),
                                table)
        chunk = stream.reshape(batch, seq_len + 1)
        yield {"tokens": chunk[:, :-1].astype(np.int32),
               "labels": chunk[:, 1:].astype(np.int32)}
