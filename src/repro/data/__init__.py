from repro.data.iris import load_iris
from repro.data.synth import (load_breast_cancer_like, load_pavia_like,
                              make_blobs, make_imbalanced_blobs,
                              make_synth_regression)
from repro.data.pipeline import normalize, train_test_split

__all__ = ["load_iris", "load_breast_cancer_like", "load_pavia_like",
           "make_blobs", "make_imbalanced_blobs", "make_synth_regression",
           "normalize", "train_test_split"]
