"""R005 trapped-kwargs: config fields accepted but never consumed.

The PR 8 ``max_iter`` bug class: a constructor accepts a tuning kwarg,
stores it on ``self`` — and no code ever reads it back, so the user's
setting silently does nothing. Statically visible in two shapes:

* ``self.X = kwarg`` in ``__init__`` where the attribute ``X`` is
  loaded NOWHERE in the analyzed tree (checked against the
  project-wide attribute-load index, including ``getattr(obj, "X")``
  string literals) — the kwarg reaches a shelf, not a solver config;
* a parameter of ``__init__`` or a public module-level function that
  is never referenced in the body at all.

Exemptions: trivial bodies (interface stubs), underscore-prefixed
params (documented-unused), and ``*args``/``**kwargs`` catch-alls
(pass-through by construction). Cross-file consumption is what the
project index is for — ``SVC.__init__`` storing ``self.C`` is consumed
because the fit path loads ``.C``, even from another module.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import (Finding, Project, Rule, SourceFile,
                                      is_trivial_body, own_nodes,
                                      param_names, register, walk_functions)


def _explicit_params(fn) -> set[str]:
    """Named params only — vararg/kwarg catch-alls are pass-through."""
    a = fn.args
    return {p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)
            if p.arg not in ("self", "cls")
            and not p.arg.startswith("_")}


@register
class TrappedKwargs(Rule):
    name = "R005"
    summary = ("config kwarg accepted but never consumed: stored on self "
               "with no attribute load anywhere in the tree, or a "
               "parameter the body never reads")

    def check(self, src: SourceFile, project: Project) -> list[Finding]:
        out: list[Finding] = []
        module_level = {n for n in src.tree.body
                        if isinstance(n, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))}
        for fn in walk_functions(src.tree):
            is_init = fn.name == "__init__"
            is_public_fn = fn in module_level and not fn.name.startswith("_")
            if not (is_init or is_public_fn):
                continue
            if is_trivial_body(fn):
                continue
            params = _explicit_params(fn)
            if not params:
                continue
            # all loads of each param (nested closures/lambdas COUNT as
            # consumption — factory functions capture their configs),
            # and the self.X = param stores
            uses: dict[str, list[ast.Name]] = {p: [] for p in params}
            stores: dict[str, list[tuple[str, ast.Assign]]] = \
                {p: [] for p in params}
            for node in ast.walk(fn):
                if node is fn:
                    continue
                if isinstance(node, ast.Name) and node.id in params:
                    uses[node.id].append(node)
            for node in own_nodes(fn):
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Name)
                        and node.value.id in params):
                    for tgt in node.targets:
                        if (isinstance(tgt, ast.Attribute)
                                and isinstance(tgt.value, ast.Name)
                                and tgt.value.id == "self"):
                            stores[node.value.id].append((tgt.attr, node))
            for p in sorted(params):
                if not uses[p]:
                    out.append(Finding(
                        rule=self.name, path=src.path, line=fn.lineno,
                        col=fn.col_offset,
                        message=(f"`{fn.name}` accepts `{p}` but the body "
                                 f"never reads it — the setting silently "
                                 f"does nothing (the max_iter bug class); "
                                 f"plumb it into a config or drop the "
                                 f"parameter")))
                    continue
                if not is_init or not stores[p]:
                    continue
                # stored on self and used nowhere else in the body?
                if len(uses[p]) != len(stores[p]):
                    continue
                dead = [(attr, node) for attr, node in stores[p]
                        if attr not in project.attr_loads]
                for attr, node in dead:
                    out.append(Finding(
                        rule=self.name, path=src.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"`self.{attr} = {p}` but `.{attr}` is "
                                 f"never loaded anywhere in the analyzed "
                                 f"tree — the kwarg is accepted and "
                                 f"shelved, never reaching a solver "
                                 f"config")))
        return out
