"""Runtime compile-guard: fail tests that recompile beyond budget.

The static pass (R001) catches shape-keyed recompile LEAKS it can see
in the source; this is the runtime backstop that catches the ones it
cannot. ``CompileGuard`` snapshots XLA compile activity over a code
region and raises once the number of fresh compilations exceeds the
declared budget — so a serving test that should replay through two
cached programs fails loudly the day someone's change starts minting a
program per request width again (the PR 9 decode leak was exactly
this: ~400 ms per new width, invisible to assertions on results).

Mechanism: ``jax_log_compiles`` makes jax emit one WARNING-level
"Compiling <name> ..." log record per actual XLA compilation (cache
hits are silent). The guard attaches a recording handler to the jax
loggers for the duration of the ``with`` block and counts those
records — no private jit internals, stable across jax versions that
keep the logging contract (verified on 0.4.37).

    with CompileGuard(budget=2, note="decode replay"):
        svc.submit(...)   # > 2 compiles inside -> CompileBudgetExceeded

The pytest fixture (tests/conftest.py) exposes the class so suites can
declare per-test budgets.
"""
from __future__ import annotations

import logging
import re
from typing import Optional

# one record per XLA compilation under jax_log_compiles
_COMPILE_RE = re.compile(r"^(?:Compiling ([^\s]+)|Finished XLA compilation"
                         r" of ([^\s]+))")
# jax emits compile logs from these module loggers (0.4.x); attaching to
# the "jax" parent would also work but pulls in unrelated records.
_JAX_LOGGERS = ("jax._src.interpreters.pxla", "jax._src.dispatch")


class CompileBudgetExceeded(AssertionError):
    """More XLA compilations than the declared budget."""


class _Recorder(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.names: list[str] = []
        self._seen: set[str] = set()

    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILE_RE.match(record.getMessage())
        if not m:
            return
        name = m.group(1) or m.group(2)
        # normalize: "Compiling <f>" vs "Finished XLA compilation of
        # jit(<f>)" name the same program differently
        name = re.sub(r"^jit\((.*)\)$", r"\1", name)
        # "Compiling X" and "Finished XLA compilation of X" both fire
        # for one compile on some versions; count each program once per
        # occurrence of the *Compiling* form, falling back to the
        # Finished form when only it is emitted.
        if m.group(1) is not None:
            self.names.append(name)
            self._seen.add(name)
        elif name not in self._seen:
            self.names.append(name)


class CompileGuard:
    """Context manager bounding XLA compilations in its dynamic extent.

    ``budget``: max number of fresh compilations allowed (cache hits
    are free). ``note`` names the guarded region in the failure
    message. The count (and the compiled-program names) stay readable
    after exit via ``.count`` / ``.compiled`` for assertions on the
    exact number.
    """

    def __init__(self, budget: int, note: str = ""):
        if budget < 0:
            raise ValueError(f"budget must be >= 0, got {budget}")
        self.budget = budget
        self.note = note
        self._recorder: Optional[_Recorder] = None
        self._prev_flag: Optional[bool] = None

    @property
    def count(self) -> int:
        return len(self._recorder.names) if self._recorder else 0

    @property
    def compiled(self) -> list[str]:
        return list(self._recorder.names) if self._recorder else []

    def __enter__(self) -> "CompileGuard":
        import jax
        self._prev_flag = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        self._recorder = _Recorder()
        for name in _JAX_LOGGERS:
            logging.getLogger(name).addHandler(self._recorder)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        for name in _JAX_LOGGERS:
            logging.getLogger(name).removeHandler(self._recorder)
        import jax
        jax.config.update("jax_log_compiles", self._prev_flag)
        if exc_type is None and self.count > self.budget:
            names = ", ".join(self.compiled)
            raise CompileBudgetExceeded(
                f"compile budget exceeded"
                f"{f' ({self.note})' if self.note else ''}: "
                f"{self.count} XLA compilations > budget {self.budget} "
                f"[{names}] — a shape-keyed cache leak (see R001) or an "
                f"undeclared new program; pad onto the pow2 ladder or "
                f"raise the declared budget with justification")
        return False
