"""Lint framework: source model, rule registry, suppressions, findings.

The analysis pass is a custom AST linter for the failure modes THIS
repo has actually shipped (recompile leaks, dtype drift, lock-window
races, dropped config kwargs) — bug classes that are statically visible
in the source but invisible to generic linters. The framework layer is
rule-agnostic:

* ``SourceFile`` parses one file once and pre-extracts the inline
  directives every rule shares;
* ``Rule`` subclasses register themselves by ``name`` (R001..) via
  ``register``; ``run_rules`` drives them over a ``Project``;
* ``Project`` holds every analyzed file plus the cross-file indexes
  rules need (e.g. R005's attribute-load index: an ``__init__`` kwarg
  stored on ``self`` counts as consumed if ANY analyzed file loads an
  attribute of that name);
* findings on a line carrying a matching suppression directive are
  demoted to ``suppressed`` — but a suppression without a reason is
  itself reported (rule ``R000``), so every waiver in the tree is
  explained.

Inline directives (comments)::

    # repro: noqa[R002] -- host-side diagnostic, never enters jit
    # repro: noqa[R001,R004] -- <reason>
    # repro: holds[_lock]        (on a `def` line: caller holds _lock)

``noqa`` suppresses the named rules on that line; the ``-- reason`` text
is REQUIRED (an unexplained suppression is an R000 finding). ``holds``
is the lock-discipline annotation R004 trusts for internal helpers that
are documented to run under a caller-held lock.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Iterable, Optional

META_RULE = "R000"

_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\[([A-Z0-9_,\s]+)\]\s*(?:--\s*(\S.*))?")
_HOLDS_RE = re.compile(r"#\s*repro:\s*holds\[([A-Za-z0-9_,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding, anchored to a source line."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


@dataclasses.dataclass(frozen=True)
class Suppression:
    """A finding waived by an inline ``noqa`` directive."""

    finding: Finding
    reason: Optional[str]

    def to_json(self) -> dict:
        d = self.finding.to_json()
        d["reason"] = self.reason
        return d


class SourceFile:
    """One parsed source file + its inline directives.

    ``path`` is the path as reported in findings (relative when the
    caller passed a relative root). Files that fail to parse raise
    ``SyntaxError`` to the caller — a tree that does not parse cannot
    be certified clean.
    """

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        # line -> (frozenset of rule names, reason or None)
        self.noqa: dict[int, tuple[frozenset, Optional[str]]] = {}
        # line -> frozenset of lock attribute names (R004 `holds`)
        self.holds: dict[int, frozenset] = {}
        for i, raw in enumerate(self.lines, start=1):
            m = _NOQA_RE.search(raw)
            if m:
                rules = frozenset(r.strip() for r in m.group(1).split(",")
                                  if r.strip())
                self.noqa[i] = (rules, m.group(2))
            h = _HOLDS_RE.search(raw)
            if h:
                self.holds[i] = frozenset(l.strip()
                                          for l in h.group(1).split(",")
                                          if l.strip())

    def suppression_for(self, finding: Finding
                        ) -> Optional[tuple[frozenset, Optional[str]]]:
        entry = self.noqa.get(finding.line)
        if entry and finding.rule in entry[0]:
            return entry
        return None


class Project:
    """Every analyzed file + lazily-built cross-file indexes."""

    def __init__(self, files: Iterable[SourceFile]):
        self.files = list(files)
        self._attr_loads: Optional[frozenset] = None

    @property
    def attr_loads(self) -> frozenset:
        """Attribute names loaded anywhere in the analyzed set — the
        consumption index R005 checks ``self.<attr> = kwarg`` stores
        against. ``getattr(obj, "name")`` string literals count too."""
        if self._attr_loads is None:
            names: set[str] = set()
            for f in self.files:
                for node in ast.walk(f.tree):
                    if (isinstance(node, ast.Attribute)
                            and isinstance(node.ctx, ast.Load)):
                        names.add(node.attr)
                    elif (isinstance(node, ast.Call)
                          and isinstance(node.func, ast.Name)
                          and node.func.id == "getattr"
                          and len(node.args) >= 2
                          and isinstance(node.args[1], ast.Constant)
                          and isinstance(node.args[1].value, str)):
                        names.add(node.args[1].value)
            self._attr_loads = frozenset(names)
        return self._attr_loads


class Rule:
    """Base class; subclasses set ``name``/``summary`` and implement
    ``check``. Register with ``@register``."""

    name: str = ""
    summary: str = ""

    def check(self, src: SourceFile, project: Project) -> list[Finding]:
        raise NotImplementedError


RULES: dict[str, Rule] = {}


def register(cls):
    """Class decorator: instantiate + add to the rule registry."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"rule class {cls.__name__} has no name")
    RULES[inst.name] = inst
    return cls


# ------------------------------------------------------------- AST helpers
def dotted_name(node: ast.AST) -> str:
    """'jnp.asarray' for Attribute/Name chains, '' when not a plain
    dotted path (calls, subscripts)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(node: ast.Call) -> str:
    return dotted_name(node.func)


def walk_functions(tree: ast.AST):
    """Yield every (possibly nested) function/method definition."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def own_nodes(fn: ast.AST):
    """Walk a function body WITHOUT descending into nested function or
    class definitions (those are analyzed as their own scopes)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def param_names(fn) -> set[str]:
    a = fn.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return {n for n in names if n not in ("self", "cls")}


def is_trivial_body(fn) -> bool:
    """Docstring-only / pass / raise / Ellipsis bodies — interface
    stubs whose parameters are legitimately unread."""
    body = fn.body
    if body and isinstance(body[0], ast.Expr) and isinstance(
            body[0].value, ast.Constant):
        body = body[1:]
    return all(isinstance(s, (ast.Pass, ast.Raise)) or
               (isinstance(s, ast.Expr)
                and isinstance(s.value, ast.Constant)
                and s.value.value is Ellipsis)
               for s in body) or not body


# --------------------------------------------------------------- driver
@dataclasses.dataclass
class LintResult:
    findings: list[Finding]
    suppressed: list[Suppression]
    n_files: int


def run_rules(project: Project,
              rule_names: Optional[Iterable[str]] = None) -> LintResult:
    """Run the (selected) registered rules over every file; split raw
    findings into active vs suppressed; emit R000 for suppressions
    without a reason and for noqa directives naming unknown rules."""
    # import for side effects: rule modules self-register on import
    from repro.analysis import (rules_config, rules_jax,  # noqa: F401
                                rules_pallas, rules_threads)
    selected = (list(RULES.values()) if rule_names is None
                else [RULES[r] for r in rule_names])
    findings: list[Finding] = []
    suppressed: list[Suppression] = []
    for src in project.files:
        raw: list[Finding] = []
        for rule in selected:
            raw.extend(rule.check(src, project))
        for f in raw:
            entry = src.suppression_for(f)
            if entry is None:
                findings.append(f)
                continue
            _, reason = entry
            suppressed.append(Suppression(finding=f, reason=reason))
            if not reason:
                findings.append(Finding(
                    rule=META_RULE, path=src.path, line=f.line, col=0,
                    message=(f"unexplained suppression of {f.rule}: add "
                             f"`-- <reason>` to the noqa directive")))
        for line, (rules, _) in src.noqa.items():
            unknown = rules - set(RULES) - {META_RULE}
            if unknown:
                findings.append(Finding(
                    rule=META_RULE, path=src.path, line=line, col=0,
                    message=(f"noqa names unknown rule(s) "
                             f"{sorted(unknown)}; known: {sorted(RULES)}")))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return LintResult(findings=findings, suppressed=suppressed,
                      n_files=len(project.files))
