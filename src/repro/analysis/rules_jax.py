"""R001 shape-keyed-jit and R002 dtype-discipline.

R001 targets the PR 9 decode leak: XLA keys its compile cache on
argument SHAPES, so a serving-path function that feeds request-derived
data into ``jnp`` ops (or mints a fresh ``jax.jit`` per call) compiles
one program per DISTINCT request width — an unbounded compile-cache
leak that stalls open-loop tails by hundreds of ms per new width. The
repo's discipline is pow2 padding-bucketing (``serve.Predictor``): any
hot-path function that touches jnp with request-shaped operands must
show ladder discipline (a ``bit_length``/pow2/bucket/pad computation)
in its body.

R002 targets dtype drift in both directions:

* float64 introduction outside the certified sites — the KKT
  certificate (``smo.kkt_violation``, ``core/cascade.py``) is the ONE
  place the repo deliberately recomputes in f64; anywhere else an f64
  constant/cast silently doubles memory traffic or (under jax's x64
  flag) forks the compiled dtype lattice. Non-certified f64 needs a
  ``noqa`` with a reason (host-side diagnostics are the usual one).
* Pallas kernel matmuls without ``preferred_element_type`` — a bf16
  tile fed to the MXU without an explicit f32 accumulation type
  accumulates at bf16 and silently loses the mixed-precision parity
  the KKT gates certify. Applies to ``*_kernel`` functions (the repo's
  Pallas kernel-body naming convention).
"""
from __future__ import annotations

import ast

from repro.analysis.framework import (Finding, Project, Rule, SourceFile,
                                      call_name, dotted_name, own_nodes,
                                      param_names, register, walk_functions)

# functions that legitimately touch jnp without ladder discipline:
# construction-time uploads and pre-compilation entry points
_R001_EXEMPT_FUNCS = ("__init__", "warmup")
# body markers that show pow2-ladder / padding discipline
_R001_MARKERS = ("pow2", "pad", "bucket")


def _in_scope_r001(path: str) -> bool:
    p = path.replace("\\", "/")
    return "/serve/" in p or p.endswith("/dist.py") or p.endswith("dist.py") \
        and "/" not in p or p.startswith("serve/")


def _has_ladder_marker(fn: ast.AST) -> bool:
    """Does the function body (including nested helpers) show pow2 /
    padding discipline? Markers: a ``.bit_length()`` call (the pow2
    rounding idiom) or any identifier mentioning pow2/pad/bucket."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute):
            ident = node.attr.lower()
            if node.attr == "bit_length":
                return True
            if any(m in ident for m in _R001_MARKERS):
                return True
        elif isinstance(node, ast.Name):
            ident = node.id.lower()
            if any(m in ident for m in _R001_MARKERS):
                return True
    return False


def _references_param(node: ast.AST, params: set[str]) -> list[str]:
    return sorted({n.id for n in ast.walk(node)
                   if isinstance(n, ast.Name) and n.id in params})


@register
class ShapeKeyedJit(Rule):
    name = "R001"
    summary = ("serving/dist hot path feeds request-shaped data to jnp "
               "(or mints jax.jit per call) without pow2 padding-bucket "
               "discipline — one compiled program per distinct width")

    def check(self, src: SourceFile, project: Project) -> list[Finding]:
        if not _in_scope_r001(src.path):
            return []
        out: list[Finding] = []
        for fn in walk_functions(src.tree):
            if fn.name in _R001_EXEMPT_FUNCS:
                continue
            # an lru_cache'd factory builds its jit once per static
            # config — the callable identity (and so the trace cache)
            # is memoized, which is exactly the discipline R001 wants
            if any("cache" in dotted_name(d).lower()
                   or ("cache" in dotted_name(getattr(d, "func", d)).lower()
                       if isinstance(d, ast.Call) else False)
                   for d in fn.decorator_list):
                continue
            padded = _has_ladder_marker(fn)
            params = param_names(fn)
            for node in own_nodes(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if name == "jax.jit":
                    out.append(Finding(
                        rule=self.name, path=src.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"`jax.jit` constructed inside hot-path "
                                 f"function `{fn.name}` — every call mints "
                                 f"a fresh cache-keyed callable (retrace + "
                                 f"recompile per call); hoist it to "
                                 f"__init__ / module scope")))
                    continue
                if padded or not name.startswith(("jnp.", "jax.numpy.")):
                    continue
                hot_args = [a for arg in (*node.args,
                                          *(k.value for k in node.keywords))
                            for a in _references_param(arg, params)]
                if hot_args:
                    out.append(Finding(
                        rule=self.name, path=src.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"`{name}` on request-shaped argument(s) "
                                 f"{hot_args} in `{fn.name}` without pow2 "
                                 f"padding-bucket discipline — XLA compiles "
                                 f"one program per distinct shape (the PR 9 "
                                 f"decode-leak class); pad onto the pow2 "
                                 f"ladder first")))
        return out


# --------------------------------------------------------------- R002
# the certified f64 recompute sites: full-precision KKT certificates
_R002_CERTIFIED_FILES = ("core/cascade.py",)
_R002_CERTIFIED_FUNCS = ("kkt_violation",)
_MATMUL_CALLS = ("jax.lax.dot_general", "lax.dot_general", "jnp.dot",
                 "jnp.matmul", "jnp.einsum", "pl.dot", "pltpu.dot")


def _is_f64_marker(node: ast.AST) -> bool:
    if isinstance(node, (ast.Attribute, ast.Name)):
        from repro.analysis.framework import dotted_name
        d = dotted_name(node)
        return d in ("np.float64", "numpy.float64", "jnp.float64",
                     "jax.numpy.float64")
    if isinstance(node, ast.Constant) and node.value == "float64":  # repro: noqa[R002] -- the rule's own pattern literal, not a dtype use
        return True
    return False


def _certified(src: SourceFile, fn_name: str) -> bool:
    p = src.path.replace("\\", "/")
    return (any(p.endswith(c) for c in _R002_CERTIFIED_FILES)
            or fn_name in _R002_CERTIFIED_FUNCS)


@register
class DtypeDiscipline(Rule):
    name = "R002"
    summary = ("f64 introduced outside the certified KKT-certificate "
               "sites, or a Pallas kernel matmul without "
               "preferred_element_type (bf16 accumulation drift)")

    def check(self, src: SourceFile, project: Project) -> list[Finding]:
        out: list[Finding] = []
        # map every node to its enclosing function name (module level ok)
        enclosing: dict[int, str] = {}
        for fn in walk_functions(src.tree):
            for node in ast.walk(fn):
                enclosing.setdefault(id(node), fn.name)
        if not any(src.path.replace("\\", "/").endswith(c)
                   for c in _R002_CERTIFIED_FILES):
            for node in ast.walk(src.tree):
                if not _is_f64_marker(node):
                    continue
                fn_name = enclosing.get(id(node), "<module>")
                if fn_name in _R002_CERTIFIED_FUNCS:
                    continue
                out.append(Finding(
                    rule=self.name, path=src.path, line=node.lineno,
                    col=node.col_offset,
                    message=(f"float64 introduced in `{fn_name}` outside "
                             f"the certified KKT-certificate sites "
                             f"({', '.join(_R002_CERTIFIED_FUNCS)} / "
                             f"{', '.join(_R002_CERTIFIED_FILES)}); keep "
                             f"device dtypes f32/bf16, or suppress with a "
                             f"reason if this is host-side diagnostics")))
        # Pallas kernel bodies: matmuls must pin f32 accumulation
        for fn in walk_functions(src.tree):
            if not fn.name.endswith("_kernel"):
                continue
            for node in own_nodes(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if name not in _MATMUL_CALLS:
                    continue
                kws = {k.arg for k in node.keywords}
                if "preferred_element_type" not in kws:
                    out.append(Finding(
                        rule=self.name, path=src.path, line=node.lineno,
                        col=node.col_offset,
                        message=(f"`{name}` in Pallas kernel `{fn.name}` "
                                 f"without preferred_element_type — bf16 "
                                 f"tiles would accumulate at bf16 instead "
                                 f"of f32, breaking the mixed-precision "
                                 f"parity gates")))
        return out
