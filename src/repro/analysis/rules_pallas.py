"""R003 pallas-contracts: BlockSpec divisibility + static VMEM budget.

Every Pallas kernel wrapper in this repo makes two promises its
``pl.pallas_call`` cannot check for it:

1. **Divisibility** — grid = shape // block silently truncates when the
   shape is not a block multiple, dropping tail rows with no error. The
   repo's contract is ``check_block_divisibility`` (kernels/rbf_gram.py),
   which raises a ValueError naming the fix. A bare ``assert`` (or
   nothing) in a wrapper that takes ``block_*`` tile parameters is the
   bug class this rule flags — asserts vanish under ``python -O`` and
   produce unreadable tuples when they do fire.

2. **VMEM budget** — the TPU pipeline double-buffers every block, so
   the static working set is ``2 * sum(block elements) * 4B`` and must
   fit the ~16 MiB/core VMEM. This re-derives the feasibility filter
   ``kernels.autotune`` applies to its candidate tile sweeps
   (``2 * _vmem_bytes(...) <= VMEM_BUDGET_BYTES``), evaluated here on
   the DECLARED BlockSpec shapes: int literals, ``block_*`` parameter
   defaults, and module constants resolve exactly; runtime-shape dims
   (feature widths etc.) fall back to 128 — the repo's MXU lane width
   and the autotuner's own bucket floor.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.framework import (Finding, Project, Rule, SourceFile,
                                      call_name, own_nodes, register,
                                      walk_functions)

_FALLBACK_DIM = 128        # MXU lane width; autotune's bucket floor
_BYTES_PER_ELEM = 4        # budget at f32 accumulation width


def _vmem_budget_bytes() -> int:
    try:
        from repro.kernels.autotune import VMEM_BUDGET_BYTES
        return VMEM_BUDGET_BYTES
    except Exception:  # lint must run without jax importable
        return 16 * 2 ** 20


def _module_int_constants(tree: ast.Module) -> dict[str, int]:
    out: dict[str, int] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            try:
                val = ast.literal_eval(node.value)
            except (ValueError, SyntaxError):
                continue
            if isinstance(val, int) and not isinstance(val, bool):
                out[node.targets[0].id] = val
    return out


def _param_defaults(fn) -> dict[str, int]:
    out: dict[str, int] = {}
    a = fn.args
    pos = [*a.posonlyargs, *a.args]
    for param, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if isinstance(default, ast.Constant) and isinstance(default.value,
                                                            int):
            out[param.arg] = default.value
    for param, default in zip(a.kwonlyargs, a.kw_defaults):
        if (default is not None and isinstance(default, ast.Constant)
                and isinstance(default.value, int)):
            out[param.arg] = default.value
    return out


def _resolve_dim(node: ast.AST, defaults: dict[str, int],
                 constants: dict[str, int]) -> int:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in defaults:
            return defaults[node.id]
        if node.id in constants:
            return constants[node.id]
    return _FALLBACK_DIM


def _block_shape_elems(shape_node: Optional[ast.AST],
                       defaults: dict[str, int],
                       constants: dict[str, int]) -> int:
    """Element count of one declared block shape tuple; 0 if the node
    is not a literal tuple (e.g. computed specs)."""
    if not isinstance(shape_node, (ast.Tuple, ast.List)):
        return 0
    elems = 1
    for dim in shape_node.elts:
        elems *= _resolve_dim(dim, defaults, constants)
    return elems


def _iter_spec_calls(node: ast.AST, names: tuple[str, ...]):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and call_name(sub) in names:
            yield sub


@register
class PallasContracts(Rule):
    name = "R003"
    summary = ("pallas_call wrapper missing check_block_divisibility for "
               "its block_* tile params, or declared block shapes whose "
               "double-buffered working set exceeds the VMEM budget")

    def check(self, src: SourceFile, project: Project) -> list[Finding]:
        out: list[Finding] = []
        constants = _module_int_constants(src.tree)
        budget = _vmem_budget_bytes()
        for fn in walk_functions(src.tree):
            calls = [n for n in own_nodes(fn) if isinstance(n, ast.Call)
                     and call_name(n).endswith("pallas_call")]
            if not calls:
                continue
            defaults = _param_defaults(fn)
            from repro.analysis.framework import param_names
            block_params = sorted(p for p in param_names(fn)
                                  if p.startswith("block"))
            has_check = any(
                isinstance(n, ast.Call)
                and call_name(n).endswith("check_block_divisibility")
                for n in own_nodes(fn))
            if block_params and not has_check:
                out.append(Finding(
                    rule=self.name, path=src.path, line=fn.lineno,
                    col=fn.col_offset,
                    message=(f"`{fn.name}` takes tile params "
                             f"{block_params} but never calls "
                             f"check_block_divisibility — grid = shape "
                             f"// block silently drops the tail when a "
                             f"shape is not a block multiple (bare "
                             f"asserts do not count: they vanish under "
                             f"-O)")))
            for call in calls:
                elems = 0
                for kw in call.keywords:
                    if kw.arg in ("in_specs", "out_specs"):
                        for spec in _iter_spec_calls(kw.value,
                                                     ("pl.BlockSpec",
                                                      "BlockSpec")):
                            arg = spec.args[0] if spec.args else None
                            elems += _block_shape_elems(arg, defaults,
                                                        constants)
                    elif kw.arg == "scratch_shapes":
                        for scr in _iter_spec_calls(kw.value,
                                                    ("pltpu.VMEM",
                                                     "VMEM")):
                            arg = scr.args[0] if scr.args else None
                            elems += _block_shape_elems(arg, defaults,
                                                        constants)
                working = 2 * elems * _BYTES_PER_ELEM
                if working > budget:
                    out.append(Finding(
                        rule=self.name, path=src.path, line=call.lineno,
                        col=call.col_offset,
                        message=(f"declared block shapes in `{fn.name}` "
                                 f"need {working} B double-buffered VMEM "
                                 f"(> budget {budget} B) — shrink the "
                                 f"default tiles; autotune.candidates "
                                 f"would reject this configuration")))
        return out
