"""R004 lock-discipline: guarded attributes touched outside their lock.

``ServingService`` runs a worker thread and ``ModelRegistry`` is shared
across request threads; both coordinate through per-instance locks. The
bug class: an attribute the worker mutates under the lock is READ from
the submit path without it — a torn snapshot or a lost update that no
test catches deterministically. This is the Clang ``GUARDED_BY``
discipline, done lexically:

* a class opts in by declaring ``_GUARDED_BY = {"_attr": "_lock"}``
  (attribute name -> lock attribute name, a plain dict literal);
* every ``self._attr`` load/store in its methods must then sit
  lexically inside a ``with self._lock:`` block;
* ``__init__`` / ``__del__`` are exempt (no concurrent aliases exist);
* a helper documented to run under a caller-held lock annotates its
  ``def`` line with ``# repro: holds[_lock]``;
* nested functions (worker closures) do NOT inherit the enclosing
  ``with`` — they execute later, on another thread; they need their own
  acquisition or a ``holds`` annotation.

Lexical means conservative: lock-free reads that are genuinely safe
(immutable after construction) should either not be declared in
``_GUARDED_BY`` or carry a ``noqa`` with the reason.
"""
from __future__ import annotations

import ast

from repro.analysis.framework import (Finding, Project, Rule, SourceFile,
                                      dotted_name, register)


def _guarded_decl(cls: ast.ClassDef) -> dict[str, str]:
    """Extract the ``_GUARDED_BY`` dict literal, {} when absent."""
    for node in cls.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "_GUARDED_BY"):
            try:
                val = ast.literal_eval(node.value)
            except (ValueError, SyntaxError):
                return {}
            if isinstance(val, dict):
                return {str(k): str(v) for k, v in val.items()}
    return {}


_EXEMPT_METHODS = ("__init__", "__del__", "__repr__")


@register
class LockDiscipline(Rule):
    name = "R004"
    summary = ("attribute declared in _GUARDED_BY touched outside a "
               "`with self.<lock>:` block (and without a holds[...] "
               "annotation)")

    def check(self, src: SourceFile, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                guarded = _guarded_decl(node)
                if guarded:
                    self._check_class(src, node, guarded, out)
        return out

    def _check_class(self, src: SourceFile, cls: ast.ClassDef,
                     guarded: dict[str, str], out: list[Finding]) -> None:
        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if item.name in _EXEMPT_METHODS:
                continue
            held = set(src.holds.get(item.lineno, frozenset()))
            for stmt in item.body:
                self._visit(src, stmt, guarded, held, item.name, out)

    def _visit(self, src: SourceFile, node: ast.AST,
               guarded: dict[str, str], held: set, method: str,
               out: list[Finding]) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            acquired = set()
            for it in node.items:
                name = dotted_name(it.context_expr)
                if name.startswith("self."):
                    acquired.add(name[len("self."):])
            inner = held | acquired
            for stmt in node.body:
                self._visit(src, stmt, guarded, inner, method, out)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested closure runs later / on another thread: the
            # enclosing `with` gives it nothing. Own holds[] only.
            inner = set(src.holds.get(node.lineno, frozenset()))
            for stmt in node.body:
                self._visit(src, stmt, guarded, inner,
                            f"{method}.{node.name}", out)
            return
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in guarded):
            lock = guarded[node.attr]
            if lock not in held:
                kind = ("written" if isinstance(node.ctx,
                                                (ast.Store, ast.Del))
                        else "read")
                out.append(Finding(
                    rule=self.name, path=src.path, line=node.lineno,
                    col=node.col_offset,
                    message=(f"`self.{node.attr}` {kind} in `{method}` "
                             f"outside `with self.{lock}:` — declared "
                             f"guarded by {lock} in _GUARDED_BY; acquire "
                             f"the lock or annotate the helper with "
                             f"`# repro: holds[{lock}]`")))
        for child in ast.iter_child_nodes(node):
            self._visit(src, child, guarded, held, method, out)
