"""Static-analysis pass + runtime compile-guard for this repo's shipped
bug classes.

``python -m repro.analysis.lint src/`` runs rules R001-R005 (shape-
keyed jit, dtype discipline, Pallas contracts, lock discipline, trapped
kwargs); ``repro.analysis.compile_guard.CompileGuard`` is the runtime
recompile budget. See README "Static analysis & compile-guard".
"""
from repro.analysis.compile_guard import CompileBudgetExceeded, CompileGuard
from repro.analysis.framework import (Finding, Project, Rule, RULES,
                                      SourceFile, run_rules)

__all__ = ["CompileBudgetExceeded", "CompileGuard", "Finding", "Project",
           "Rule", "RULES", "SourceFile", "run_rules"]
