"""CLI driver: ``python -m repro.analysis.lint src/ [options]``.

Exit codes: 0 clean, 1 findings, 2 internal error (unparseable file,
bad arguments, broken baseline). Output is human-readable by default;
``--format json`` emits the pinned machine schema (``"schema": 1``)
that CI and the golden tests consume:

    {"schema": 1,
     "findings":        [{rule, path, line, col, message}, ...],
     "suppressed":      [{rule, path, line, col, message, reason}, ...],
     "baseline_waived": [{rule, path, line, col, message}, ...],
     "counts": {"findings": N, "suppressed": N,
                "baseline_waived": N, "files": N}}

``--baseline FILE`` points at a committed JSON waiver file so a future
rule can land warn-only: each entry ``{"rule": "R0xx", "path": "..."}``
waives that rule's findings under that path prefix (omit ``path`` to
waive repo-wide). Waived findings are reported but do not affect the
exit code. The shipped ``analysis-baseline.json`` is empty — every
current rule is enforced.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

from repro.analysis.framework import (Finding, LintResult, Project,
                                      SourceFile, run_rules)

JSON_SCHEMA_VERSION = 1


def collect_paths(roots: list[str]) -> list[str]:
    """Expand files/directories into a sorted list of .py files."""
    files: list[str] = []
    for root in roots:
        if os.path.isfile(root):
            files.append(root)
        elif os.path.isdir(root):
            for dirpath, dirnames, filenames in os.walk(root):
                dirnames[:] = [d for d in dirnames
                               if d != "__pycache__"
                               and not d.startswith(".")]
                files.extend(os.path.join(dirpath, f)
                             for f in filenames if f.endswith(".py"))
        else:
            raise FileNotFoundError(root)
    return sorted(set(files))


def load_project(paths: list[str]) -> Project:
    sources = []
    for path in paths:
        with open(path, "r", encoding="utf-8") as fh:
            sources.append(SourceFile(path, fh.read()))
    return Project(sources)


def load_baseline(path: Optional[str]) -> list[dict]:
    if path is None:
        return []
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    waivers = data["waive"] if isinstance(data, dict) else data
    if not isinstance(waivers, list):
        raise ValueError(f"baseline {path}: expected a list or "
                         f"{{'waive': [...]}} object")
    for w in waivers:
        if not isinstance(w, dict) or "rule" not in w:
            raise ValueError(f"baseline {path}: each waiver needs a "
                             f"'rule' key: {w!r}")
    return waivers


def _waived(f: Finding, waivers: list[dict]) -> bool:
    norm = f.path.replace("\\", "/")
    return any(w["rule"] == f.rule
               and norm.startswith(w.get("path", "").replace("\\", "/"))
               for w in waivers)


def apply_baseline(result: LintResult, waivers: list[dict]
                   ) -> tuple[list[Finding], list[Finding]]:
    active = [f for f in result.findings if not _waived(f, waivers)]
    waived = [f for f in result.findings if _waived(f, waivers)]
    return active, waived


def render_human(active: list[Finding], waived: list[Finding],
                 result: LintResult) -> str:
    lines = [f.render() for f in active]
    lines.extend(f"{f.render()}  [baseline]" for f in waived)
    lines.append(f"{len(active)} finding(s), {len(waived)} baseline-"
                 f"waived, {len(result.suppressed)} suppressed, "
                 f"{result.n_files} file(s) checked")
    return "\n".join(lines)


def render_json(active: list[Finding], waived: list[Finding],
                result: LintResult) -> str:
    return json.dumps({
        "schema": JSON_SCHEMA_VERSION,
        "findings": [f.to_json() for f in active],
        "suppressed": [s.to_json() for s in result.suppressed],
        "baseline_waived": [f.to_json() for f in waived],
        "counts": {"findings": len(active),
                   "suppressed": len(result.suppressed),
                   "baseline_waived": len(waived),
                   "files": result.n_files},
    }, indent=2, sort_keys=True)


def main(argv: Optional[list[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="JAX/Pallas static-analysis pass for this repo's "
                    "shipped bug classes (R001-R005).")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    parser.add_argument("--format", choices=("human", "json"),
                        default="human")
    parser.add_argument("--baseline", default=None,
                        help="JSON waiver file for warn-only rules")
    parser.add_argument("--rules", default=None,
                        help="comma-separated subset (e.g. R001,R004)")
    args = parser.parse_args(argv)
    try:
        paths = collect_paths(args.paths)
        project = load_project(paths)
        rule_names = (None if args.rules is None
                      else [r.strip() for r in args.rules.split(",")
                            if r.strip()])
        result = run_rules(project, rule_names)
        waivers = load_baseline(args.baseline)
        active, waived = apply_baseline(result, waivers)
    except (OSError, SyntaxError, ValueError, KeyError) as exc:
        print(f"repro.analysis.lint: error: {exc}", file=sys.stderr)
        return 2
    render = render_json if args.format == "json" else render_human
    print(render(active, waived, result))
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
