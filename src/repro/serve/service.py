"""Async serving service: request queue + dynamic-batching window.

``serve.Predictor`` made the decide *kernel* fast; this module makes it
fast **under open-loop traffic**, where requests arrive on their own
clock and mostly one row at a time. Dispatching each arrival alone
wastes the fused decide program — a 64-row bucket costs about the same
as 1 row — so the service batches the queue:

* ``submit`` enqueues a request (any row count) and returns a
  ``concurrent.futures.Future`` immediately — callers never block the
  batcher;
* a single worker thread collects arrivals for at most
  ``window_ms`` (measured from the FIRST request of the window) or
  until some model's collected rows reach its predictor's
  ``max_batch`` — whichever comes first — then flushes: per model, one
  fused ``decision_values`` over the concatenated rows, one vectorized
  decode, and the per-request slices scattered back through the
  futures;
* requests for different models share a window (the registry keeps
  their banks resident); an idle service burns no CPU (the worker
  blocks on the queue).

``window_ms=0`` disables the *wait* but not the batching: whatever is
already queued when the worker wakes is still fused into one decide —
the greedy-backlog batcher. The latency cost of a window is bounded by
``window_ms``; the throughput win at saturation is the batch width.

    svc = ServingService(serve.pack(clf), window_ms=2.0)
    fut = svc.submit(z_row, op="predict")     # non-blocking
    fut.result()                              # one label row
    svc.predict(Z)                            # blocking convenience
    svc.close()                               # flushes, then stops

Multi-model form: pass a ``ModelRegistry`` (or a ``{name: PackedModel}``
dict) and route with ``submit(x, model="name")``. ``stats`` reports the
request/batch/row counters the open-loop benchmark
(``benchmarks.bench_serving_load``) builds its p50/p99 story on.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import NamedTuple, Optional, Union

import numpy as np

from repro.serve.artifact import PackedModel
from repro.serve.predictor import Predictor, _pow2_floor
from repro.serve.registry import ModelRegistry

_OPS = ("predict", "decision_function", "values")
_SENTINEL = object()


class _Request(NamedTuple):
    model: str
    op: str
    x: np.ndarray          # (n, d) float32
    future: Future


class ServingService:
    """Dynamic-batching front end over one or many packed models."""

    # shared mutable state and its lock (enforced by analysis rule R004):
    # the worker thread mutates _stats; _closed coordinates submit/close
    _GUARDED_BY = {"_stats": "_stats_lock", "_closed": "_stats_lock"}

    def __init__(self, models, *, window_ms: float = 2.0,
                 engine="auto", max_batch: int = 1024,
                 max_resident: int = 4, warmup_sizes: tuple = (1,)):
        if window_ms < 0:
            raise ValueError(f"window_ms must be >= 0, got {window_ms}")
        self.window_s = float(window_ms) * 1e-3
        self._direct: dict[str, Predictor] = {}
        self.registry: Optional[ModelRegistry] = None
        if isinstance(models, Predictor):
            # serve an existing predictor as the single "default" model
            self._direct["default"] = models
        elif isinstance(models, ModelRegistry):
            self.registry = models
        else:
            self.registry = ModelRegistry(
                max_resident=max_resident, engine=engine,
                max_batch=max_batch, warmup_sizes=warmup_sizes)
            named = (models if isinstance(models, dict)
                     else {"default": models})
            for name, m in named.items():
                self.registry.register(name, m)
        self._q: queue.Queue = queue.Queue()
        self._closed = False
        self._stats_lock = threading.Lock()
        self._stats = {"n_requests": 0, "n_rows": 0, "n_batches": 0,
                       "n_window_flushes": 0, "n_full_flushes": 0,
                       "max_batch_rows": 0}
        self._worker = threading.Thread(target=self._run,
                                        name="repro-serving-batcher",
                                        daemon=True)
        self._worker.start()

    # ------------------------------------------------------------- submit
    def _packed(self, name: str) -> PackedModel:
        if name in self._direct:
            return self._direct[name].model
        if self.registry is None or name not in self.registry:
            known = sorted(self._direct) + (
                sorted(self.registry.names) if self.registry else [])
            raise KeyError(f"unknown model {name!r} (known: {known})")
        return self.registry.model(name)

    def submit(self, x, *, model: str = "default",
               op: str = "predict") -> Future:
        """Enqueue a request; returns a Future resolving to the decoded
        output for exactly the submitted rows. A 1-D ``x`` is treated
        as a single row (and resolves to a length-1 result)."""
        if op not in _OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {_OPS}")
        with self._stats_lock:
            if self._closed:
                raise RuntimeError("service is closed")
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None]
        d = self._packed(model).n_features
        if x.ndim != 2 or x.shape[1] != d or x.shape[0] == 0:
            raise ValueError(f"expected a non-empty (n, {d}) request "
                             f"for model {model!r}, got shape {x.shape}")
        fut: Future = Future()
        self._q.put(_Request(model, op, x, fut))
        return fut

    # ------------------------------------------------- blocking shortcuts
    def predict(self, x, *, model: str = "default"):
        return self.submit(x, model=model, op="predict").result()

    def decision_function(self, x, *, model: str = "default"):
        return self.submit(x, model=model,
                           op="decision_function").result()

    # -------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        with self._stats_lock:
            s = dict(self._stats)
        s["rows_per_batch"] = (s["n_rows"] / s["n_batches"]
                               if s["n_batches"] else 0.0)
        return s

    # ------------------------------------------------------------ teardown
    def close(self, timeout: Optional[float] = None) -> None:
        """Stop accepting requests, flush everything queued, join the
        worker. Idempotent."""
        with self._stats_lock:
            first = not self._closed
            self._closed = True
        if first:
            # exactly one closer enqueues the sentinel — two racing
            # close() calls used to both pass the unlocked check
            self._q.put(_SENTINEL)
        self._worker.join(timeout)
        # a submit that raced close() may have queued behind the
        # sentinel; fail those futures rather than hanging their callers
        while True:
            try:
                req = self._q.get_nowait()
            except queue.Empty:
                break
            if req is not _SENTINEL:
                req.future.set_exception(
                    RuntimeError("service closed before dispatch"))

    def __enter__(self) -> "ServingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- batcher
    def _predictor(self, name: str) -> Predictor:
        if name in self._direct:
            return self._direct[name]
        return self.registry.get(name)

    def _cap(self, name: str) -> int:
        """Rows at which a model's window is full (its predictor's
        max_batch — beyond that the predictor slices anyway)."""
        if name in self._direct:
            return self._direct[name].max_batch
        # host-side cap (don't force admission just to read it); the
        # predictor rounds its max_batch to the same pow2 ladder rung
        return _pow2_floor(self.registry.max_batch)

    def _run(self) -> None:
        while True:
            req = self._q.get()
            if req is _SENTINEL:
                return
            pending = [req]
            rows = {req.model: req.x.shape[0]}
            deadline = time.perf_counter() + self.window_s
            full = req.x.shape[0] >= self._cap(req.model)
            while not full:
                try:
                    # drain the backlog greedily first (this is all the
                    # batching window_ms=0 gets), then wait the window
                    nxt = self._q.get_nowait()
                except queue.Empty:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    try:
                        nxt = self._q.get(timeout=remaining)
                    except queue.Empty:
                        break
                if nxt is _SENTINEL:
                    self._flush(pending)
                    return
                pending.append(nxt)
                rows[nxt.model] = rows.get(nxt.model, 0) + nxt.x.shape[0]
                full = rows[nxt.model] >= self._cap(nxt.model)
            with self._stats_lock:
                self._stats["n_full_flushes" if full
                            else "n_window_flushes"] += 1
            self._flush(pending)

    def _flush(self, pending: list) -> None:
        """One fused decide + vectorized decode per model present, then
        scatter per-request slices back through the futures."""
        by_model: dict[str, list] = {}
        for r in pending:
            by_model.setdefault(r.model, []).append(r)
        for name, reqs in by_model.items():
            try:
                pred = self._predictor(name)
                xcat = (reqs[0].x if len(reqs) == 1
                        else np.concatenate([r.x for r in reqs], axis=0))
                df = pred.decision_values(xcat)
                # decode ONCE per op over the merged batch (every op is
                # columnwise), then slice per request
                decoded = {op: pred.decode(df, op)
                           for op in {r.op for r in reqs}}
            except Exception as e:                 # noqa: BLE001
                for r in reqs:
                    if not r.future.cancelled():
                        r.future.set_exception(e)
                continue
            with self._stats_lock:
                self._stats["n_requests"] += len(reqs)
                self._stats["n_rows"] += xcat.shape[0]
                self._stats["n_batches"] += 1
                self._stats["max_batch_rows"] = max(
                    self._stats["max_batch_rows"], xcat.shape[0])
            start = 0
            for r in reqs:
                stop = start + r.x.shape[0]
                out = decoded[r.op]
                sl = out[..., start:stop] if out.ndim > 1 else out[start:stop]
                start = stop
                if not r.future.cancelled():
                    r.future.set_result(sl)
