"""Packed model artifacts — the immutable, serving-side form of a fit.

Training-side model objects (``SVC`` / ``SVR``) carry solver state,
schedules and engine configs; none of that belongs on a serving host.
A ``PackedModel`` is the compacted essence of a fit: per serving bucket
a stacked, zero-padded SV bank (``sv_x``/``sv_coef``/``b``), plus the
kernel parameters, the class table and the vote-routing ``pairs`` —
everything ``serve.Predictor`` needs to answer requests and nothing
else. Buckets group tasks of similar SV count (the training-side pow2
compaction), so each bucket is one fused decide program at its own
width.

Low-rank fits (``engine="nystrom"|"rff"``) pack to a much smaller
artifact: instead of SV banks, the feature-map arrays (landmarks+proj
or omega+phase, as a ``LowRankMap``) plus the stacked linear weights
``linear_w (n_tasks, rank)`` / ``linear_b (n_tasks,)`` — serving is one
feature transform and a matmul, independent of the training-set size.

Artifacts serialize to a versioned ``.npz`` schema (``save``/``load``):
one JSON metadata entry (schema name + version, kind, kernel params,
strategy/decision) and flat numeric arrays ``b{i}_<field>`` per bucket
(or ``fm_a``/``fm_b``/``linear_w``/``linear_b`` for low-rank). Classic
SV-bank models still write version 1 — old readers keep working — and
low-rank models write version 2; ``load`` refuses unknown schema
names/versions instead of guessing.

Quantized SV banks (``pack(..., sv_dtype="fp16"|"bf16")`` or
``quantize`` on an existing pack) store ``sv_x``/``sv_coef`` at half
precision — half the artifact size and half the device-resident bank
HBM — while biases, counts and routing stay exact. Serving upcasts the
bank to f32 inside the decide program (f32 accumulation; see
``serve.predictor``), and the accuracy cost is gated in tests (decision
deltas <= 3e-2, label parity). Quantized packs write schema version 3
(``meta.sv_dtype``; bf16 serializes as its uint16 bit pattern since npz
has no bfloat16) — fp32 packs keep writing v1/v2 byte-identically, and
``load`` reads all of v1/v2/v3.

``pack`` accepts a fitted ``SVC`` (binary or multiclass) or ``SVR`` and
is duck-typed on the fitted attributes, so this module never imports
the training stack.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import NamedTuple, Optional

import ml_dtypes
import numpy as np

from repro.core import kernels as K

SCHEMA_NAME = "repro.svm-pack"
SCHEMA_VERSION = 2                  # current writer for low-rank packs
SCHEMA_VERSION_CLASSIC = 1          # SV-bank packs stay readable by old code
SCHEMA_VERSION_QUANT = 3            # quantized (fp16/bf16) SV-bank packs
SCHEMA_VERSIONS = (1, 2, 3)         # what load() accepts

# storage dtypes for the SV bank (sv_x / sv_coef); ml_dtypes registers
# bfloat16 as a numpy dtype (it ships with jax, no new dependency)
SV_DTYPES = {"fp32": np.float32, "fp16": np.float16,
             "bf16": ml_dtypes.bfloat16}


class TaskBucket(NamedTuple):
    """One serving bucket: tasks stacked at a common (padded) SV width.

    ``task_ids[j]`` is the global task index of stacked row j; padding
    rows beyond ``sv_counts[j]`` carry ``sv_coef == 0`` (and zero SVs),
    so they contribute exactly 0 to every decision value.
    """

    task_ids: np.ndarray   # (T,)   int64 global task index per stacked row
    sv_x: np.ndarray       # (T, w, d) float32 support vectors, zero-padded
    sv_coef: np.ndarray    # (T, w) float32 alpha_i * y_i (beta_i for SVR)
    b: np.ndarray          # (T,)   float32 biases
    sv_counts: np.ndarray  # (T,)   int64 real SV count per stacked task

    @property
    def width(self) -> int:
        return self.sv_x.shape[1]


class LowRankMap(NamedTuple):
    """Serialized feature map of a low-rank fit (``repro.core.approx``).

    kind "nystrom": ``a`` = landmarks (k, d), ``b`` = proj (k, rank).
    kind "rff":     ``a`` = omega (d, rank),  ``b`` = phase (rank,).
    Rebuild with ``approx.map_from_arrays(kind, kernel, a, b)``.
    """

    kind: str
    a: np.ndarray
    b: np.ndarray


@dataclasses.dataclass(frozen=True)
class PackedModel:
    """Immutable serving artifact; see module docstring.

    kind:     "svc" | "svr".
    strategy: "binary" | "ovo" | "ovr" (SVC) or "svr".
    pairs:    (n_tasks, 2) class-index credit table — column 0 credited
              on decision > 0, column 1 on decision < 0 (−1 = no credit;
              binary packs as [[1, 0]], the sklearn orientation).
    """

    kind: str
    kernel: K.KernelParams
    n_features: int
    n_tasks: int
    buckets: tuple[TaskBucket, ...]
    strategy: str = "binary"
    decision: str = "vote"
    classes: Optional[np.ndarray] = None
    pairs: Optional[np.ndarray] = None
    feature_map: Optional[LowRankMap] = None
    linear_w: Optional[np.ndarray] = None   # (n_tasks, rank)
    linear_b: Optional[np.ndarray] = None   # (n_tasks,)
    sv_dtype: str = "fp32"                  # sv_x/sv_coef storage dtype

    def __post_init__(self):
        if self.sv_dtype not in SV_DTYPES:
            raise ValueError(
                f"unknown sv_dtype {self.sv_dtype!r}; expected one of "
                f"{sorted(SV_DTYPES)}")
        if self.feature_map is not None:
            if self.sv_dtype != "fp32":
                raise ValueError(
                    "sv_dtype quantization applies to SV banks; a "
                    "low-rank pack has no SV bank (its artifact is "
                    "already O(rank))")
            if self.buckets:
                raise ValueError("a low-rank pack carries linear weights, "
                                 "not SV buckets; got both")
            if self.linear_w is None or self.linear_b is None:
                raise ValueError("a low-rank pack needs linear_w and "
                                 "linear_b alongside its feature_map")
            if (self.linear_w.shape[0] != self.n_tasks
                    or self.linear_b.shape != (self.n_tasks,)):
                raise ValueError(
                    f"linear weights must stack all {self.n_tasks} tasks: "
                    f"linear_w {self.linear_w.shape}, "
                    f"linear_b {self.linear_b.shape}")
            return
        ids = np.sort(np.concatenate([g.task_ids for g in self.buckets]))
        if not np.array_equal(ids, np.arange(self.n_tasks)):
            raise ValueError(
                f"buckets must cover task ids 0..{self.n_tasks - 1} "
                f"exactly once, got {ids.tolist()}")

    @property
    def n_classes(self) -> int:
        return 0 if self.classes is None else len(self.classes)

    @property
    def n_support(self) -> int:
        return int(sum(int(g.sv_counts.sum()) for g in self.buckets))


# ------------------------------------------------------------------- pack
def _single_task_bucket(sv_x: np.ndarray, sv_coef: np.ndarray,
                        b: float) -> TaskBucket:
    sv_x = np.asarray(sv_x, np.float32)
    return TaskBucket(task_ids=np.array([0], np.int64),
                      sv_x=sv_x[None],
                      sv_coef=np.asarray(sv_coef, np.float32)[None],
                      b=np.array([b], np.float32),
                      sv_counts=np.array([sv_x.shape[0]], np.int64))


def _pack_binary_svc(clf) -> PackedModel:
    return PackedModel(
        kind="svc", kernel=clf.kernel_params,
        n_features=clf.support_vectors_.shape[1], n_tasks=1,
        buckets=(_single_task_bucket(clf.support_vectors_, clf.dual_coef_,
                                     clf.b_),),
        strategy="binary", classes=np.asarray(clf.classes_),
        pairs=np.array([[1, 0]], np.int64))


def _pack_multiclass_svc(clf) -> PackedModel:
    taskset = clf._taskset
    buckets = []
    for g in clf._serving_buckets:
        buckets.append(TaskBucket(
            task_ids=np.asarray(g.task_ids, np.int64),
            sv_x=np.asarray(g.sv_x, np.float32),
            sv_coef=np.asarray(g.sv_coef, np.float32),
            b=np.asarray(g.b, np.float32),
            sv_counts=np.asarray(clf.n_support_[g.task_ids], np.int64)))
    return PackedModel(
        kind="svc", kernel=clf.kernel_params,
        n_features=taskset.tasks[0].x.shape[1], n_tasks=taskset.n_tasks,
        buckets=tuple(buckets), strategy=taskset.strategy,
        decision=clf.decision, classes=np.asarray(clf.classes_),
        pairs=np.asarray(taskset.pairs, np.int64))


def _pack_svr(reg) -> PackedModel:
    return PackedModel(
        kind="svr", kernel=reg.kernel_params,
        n_features=reg.support_vectors_.shape[1], n_tasks=1,
        buckets=(_single_task_bucket(reg.support_vectors_, reg.dual_coef_,
                                     reg.b_),),
        strategy="svr")


def _pack_lowrank(model) -> PackedModel:
    """Low-rank (Nyström/RFF) fits: feature-map arrays + stacked linear
    weights instead of SV banks — artifact size is O(rank), independent
    of the training-set size."""
    fmap = model._feature_map
    a, b = fmap.arrays
    fm = LowRankMap(kind=fmap.kind, a=np.asarray(a, np.float32),
                    b=np.asarray(b, np.float32))
    if hasattr(model, "beta_"):
        kind, strategy, decision = "svr", "svr", "vote"
        w, bias = model.w_[None], np.array([model.b_], np.float32)
        classes = pairs = None
        n_tasks = 1
    elif model._binary:
        kind, strategy, decision = "svc", "binary", model.decision
        w, bias = model.w_[None], np.array([model.b_], np.float32)
        classes = np.asarray(model.classes_)
        pairs = np.array([[1, 0]], np.int64)
        n_tasks = 1
    else:
        taskset = model._taskset
        kind, strategy, decision = "svc", taskset.strategy, model.decision
        w, bias = model.task_w_, model.task_b_
        classes = np.asarray(model.classes_)
        pairs = np.asarray(taskset.pairs, np.int64)
        n_tasks = taskset.n_tasks
    return PackedModel(
        kind=kind, kernel=model.kernel_params,
        n_features=fmap.n_features, n_tasks=n_tasks, buckets=(),
        strategy=strategy, decision=decision, classes=classes,
        pairs=pairs, feature_map=fm,
        linear_w=np.asarray(w, np.float32),
        linear_b=np.asarray(bias, np.float32))


def quantize(model: PackedModel, sv_dtype: str) -> PackedModel:
    """Re-store an SV-bank pack's ``sv_x``/``sv_coef`` at ``sv_dtype``
    ("fp32" | "fp16" | "bf16"). Biases, counts and routing stay f32 /
    exact; serving upcasts the bank to f32 inside the decide program.
    Quantizing an already-quantized pack re-rounds from the stored
    values (lossless when widening is impossible — keep the fp32 pack
    if you may need it back)."""
    if sv_dtype not in SV_DTYPES:
        raise ValueError(f"unknown sv_dtype {sv_dtype!r}; expected one "
                         f"of {sorted(SV_DTYPES)}")
    if model.feature_map is not None:
        raise ValueError("sv_dtype quantization applies to SV banks; a "
                         "low-rank pack has no SV bank")
    if sv_dtype == model.sv_dtype:
        return model
    dt = SV_DTYPES[sv_dtype]
    buckets = tuple(
        g._replace(sv_x=np.asarray(g.sv_x, dt),
                   sv_coef=np.asarray(g.sv_coef, dt))
        for g in model.buckets)
    return dataclasses.replace(model, buckets=buckets, sv_dtype=sv_dtype)


def pack(model, *, sv_dtype: str = "fp32") -> PackedModel:
    """Compact a fitted ``SVC``/``SVR`` into an immutable PackedModel.

    ``sv_dtype`` ("fp32" default, "fp16" | "bf16") quantizes the stored
    SV bank — see ``quantize``. Low-rank fits reject quantization."""
    if not getattr(model, "_fitted", False):
        raise ValueError("pack() needs a fitted model (call .fit first)")
    if getattr(model, "_feature_map", None) is not None:
        packed = _pack_lowrank(model)
        if sv_dtype != "fp32":
            raise ValueError("sv_dtype quantization applies to SV "
                             "banks; a low-rank fit packs no SV bank")
        return packed
    if hasattr(model, "beta_"):
        packed = _pack_svr(model)
    elif model._binary:
        packed = _pack_binary_svc(model)
    else:
        packed = _pack_multiclass_svc(model)
    return quantize(packed, sv_dtype) if sv_dtype != "fp32" else packed


# ------------------------------------------------------------------ (de)ser
def save(path, model: PackedModel) -> None:
    """Write the versioned .npz artifact (path or open file object).

    The path is written VERBATIM — unlike bare ``np.savez``, which
    silently appends ".npz" to extension-less paths, so a
    ``save(p)`` / ``load(p)`` round-trip always works.
    """
    lowrank = model.feature_map is not None
    quant = model.sv_dtype != "fp32"
    # classic fp32 SV-bank packs keep writing version 1 so pre-low-rank
    # readers stay compatible; low-rank needs version 2, quantized
    # banks version 3 (old readers must refuse, not misread the bank)
    version = (SCHEMA_VERSION_QUANT if quant
               else SCHEMA_VERSION if lowrank else SCHEMA_VERSION_CLASSIC)
    meta = {
        "schema": SCHEMA_NAME,
        "version": version,
        "kind": model.kind, "strategy": model.strategy,
        "decision": model.decision,
        "kernel": dataclasses.asdict(model.kernel),
        "n_features": model.n_features, "n_tasks": model.n_tasks,
        "n_buckets": len(model.buckets),
    }
    if lowrank:
        meta["feature_map"] = model.feature_map.kind
    if quant:
        meta["sv_dtype"] = model.sv_dtype
    arrays = {"meta": np.array(json.dumps(meta, sort_keys=True))}
    if model.classes is not None:
        arrays["classes"] = model.classes
    if model.pairs is not None:
        arrays["pairs"] = model.pairs
    if lowrank:
        arrays["fm_a"] = model.feature_map.a
        arrays["fm_b"] = model.feature_map.b
        arrays["linear_w"] = model.linear_w
        arrays["linear_b"] = model.linear_b
    for i, g in enumerate(model.buckets):
        for field, value in g._asdict().items():
            if value.dtype == ml_dtypes.bfloat16:
                # npz has no bfloat16: store the raw bit pattern;
                # load() views it back (meta.sv_dtype says how)
                value = value.view(np.uint16)
            arrays[f"b{i}_{field}"] = value
    if hasattr(path, "write"):
        np.savez(path, **arrays)
    else:
        with open(os.fspath(path), "wb") as f:
            np.savez(f, **arrays)


def load(path) -> PackedModel:
    """Read an artifact written by ``save``; strict about the schema."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"]))
        if meta.get("schema") != SCHEMA_NAME:
            raise ValueError(f"not a {SCHEMA_NAME} artifact: "
                             f"schema={meta.get('schema')!r}")
        if meta.get("version") not in SCHEMA_VERSIONS:
            raise ValueError(
                f"unsupported {SCHEMA_NAME} version {meta.get('version')!r}"
                f" (this build reads versions {list(SCHEMA_VERSIONS)})")
        sv_dtype = meta.get("sv_dtype", "fp32")
        if sv_dtype not in SV_DTYPES:
            raise ValueError(f"unsupported sv_dtype {sv_dtype!r} "
                             f"(this build reads {sorted(SV_DTYPES)})")

        def _bank(arr):
            # bf16 banks are stored as their uint16 bit pattern
            return (arr.view(ml_dtypes.bfloat16) if sv_dtype == "bf16"
                    else arr)

        buckets = tuple(
            TaskBucket(**{f: _bank(z[f"b{i}_{f}"])
                          if f in ("sv_x", "sv_coef") else z[f"b{i}_{f}"]
                          for f in TaskBucket._fields})
            for i in range(meta["n_buckets"]))
        fm = w = lb = None
        if "feature_map" in meta:
            fm = LowRankMap(kind=meta["feature_map"],
                            a=np.asarray(z["fm_a"], np.float32),
                            b=np.asarray(z["fm_b"], np.float32))
            w = np.asarray(z["linear_w"], np.float32)
            lb = np.asarray(z["linear_b"], np.float32)
        return PackedModel(
            kind=meta["kind"], kernel=K.KernelParams(**meta["kernel"]),
            n_features=meta["n_features"], n_tasks=meta["n_tasks"],
            buckets=buckets, strategy=meta["strategy"],
            decision=meta["decision"],
            classes=z["classes"] if "classes" in z else None,
            pairs=np.asarray(z["pairs"], np.int64) if "pairs" in z
            else None, feature_map=fm, linear_w=w, linear_b=lb,
            sv_dtype=sv_dtype)
