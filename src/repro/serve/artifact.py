"""Packed model artifacts — the immutable, serving-side form of a fit.

Training-side model objects (``SVC`` / ``SVR``) carry solver state,
schedules and engine configs; none of that belongs on a serving host.
A ``PackedModel`` is the compacted essence of a fit: per serving bucket
a stacked, zero-padded SV bank (``sv_x``/``sv_coef``/``b``), plus the
kernel parameters, the class table and the vote-routing ``pairs`` —
everything ``serve.Predictor`` needs to answer requests and nothing
else. Buckets group tasks of similar SV count (the training-side pow2
compaction), so each bucket is one fused decide program at its own
width.

Artifacts serialize to a versioned ``.npz`` schema (``save``/``load``):
one JSON metadata entry (schema name + version, kind, kernel params,
strategy/decision) and flat numeric arrays ``b{i}_<field>`` per bucket.
``load`` refuses unknown schema names/versions instead of guessing.

``pack`` accepts a fitted ``SVC`` (binary or multiclass) or ``SVR`` and
is duck-typed on the fitted attributes, so this module never imports
the training stack.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import NamedTuple, Optional

import numpy as np

from repro.core import kernels as K

SCHEMA_NAME = "repro.svm-pack"
SCHEMA_VERSION = 1


class TaskBucket(NamedTuple):
    """One serving bucket: tasks stacked at a common (padded) SV width.

    ``task_ids[j]`` is the global task index of stacked row j; padding
    rows beyond ``sv_counts[j]`` carry ``sv_coef == 0`` (and zero SVs),
    so they contribute exactly 0 to every decision value.
    """

    task_ids: np.ndarray   # (T,)   int64 global task index per stacked row
    sv_x: np.ndarray       # (T, w, d) float32 support vectors, zero-padded
    sv_coef: np.ndarray    # (T, w) float32 alpha_i * y_i (beta_i for SVR)
    b: np.ndarray          # (T,)   float32 biases
    sv_counts: np.ndarray  # (T,)   int64 real SV count per stacked task

    @property
    def width(self) -> int:
        return self.sv_x.shape[1]


@dataclasses.dataclass(frozen=True)
class PackedModel:
    """Immutable serving artifact; see module docstring.

    kind:     "svc" | "svr".
    strategy: "binary" | "ovo" | "ovr" (SVC) or "svr".
    pairs:    (n_tasks, 2) class-index credit table — column 0 credited
              on decision > 0, column 1 on decision < 0 (−1 = no credit;
              binary packs as [[1, 0]], the sklearn orientation).
    """

    kind: str
    kernel: K.KernelParams
    n_features: int
    n_tasks: int
    buckets: tuple[TaskBucket, ...]
    strategy: str = "binary"
    decision: str = "vote"
    classes: Optional[np.ndarray] = None
    pairs: Optional[np.ndarray] = None

    def __post_init__(self):
        ids = np.sort(np.concatenate([g.task_ids for g in self.buckets]))
        if not np.array_equal(ids, np.arange(self.n_tasks)):
            raise ValueError(
                f"buckets must cover task ids 0..{self.n_tasks - 1} "
                f"exactly once, got {ids.tolist()}")

    @property
    def n_classes(self) -> int:
        return 0 if self.classes is None else len(self.classes)

    @property
    def n_support(self) -> int:
        return int(sum(int(g.sv_counts.sum()) for g in self.buckets))


# ------------------------------------------------------------------- pack
def _single_task_bucket(sv_x: np.ndarray, sv_coef: np.ndarray,
                        b: float) -> TaskBucket:
    sv_x = np.asarray(sv_x, np.float32)
    return TaskBucket(task_ids=np.array([0], np.int64),
                      sv_x=sv_x[None],
                      sv_coef=np.asarray(sv_coef, np.float32)[None],
                      b=np.array([b], np.float32),
                      sv_counts=np.array([sv_x.shape[0]], np.int64))


def _pack_binary_svc(clf) -> PackedModel:
    return PackedModel(
        kind="svc", kernel=clf.kernel_params,
        n_features=clf.support_vectors_.shape[1], n_tasks=1,
        buckets=(_single_task_bucket(clf.support_vectors_, clf.dual_coef_,
                                     clf.b_),),
        strategy="binary", classes=np.asarray(clf.classes_),
        pairs=np.array([[1, 0]], np.int64))


def _pack_multiclass_svc(clf) -> PackedModel:
    taskset = clf._taskset
    buckets = []
    for g in clf._serving_buckets:
        buckets.append(TaskBucket(
            task_ids=np.asarray(g.task_ids, np.int64),
            sv_x=np.asarray(g.sv_x, np.float32),
            sv_coef=np.asarray(g.sv_coef, np.float32),
            b=np.asarray(g.b, np.float32),
            sv_counts=np.asarray(clf.n_support_[g.task_ids], np.int64)))
    return PackedModel(
        kind="svc", kernel=clf.kernel_params,
        n_features=taskset.tasks[0].x.shape[1], n_tasks=taskset.n_tasks,
        buckets=tuple(buckets), strategy=taskset.strategy,
        decision=clf.decision, classes=np.asarray(clf.classes_),
        pairs=np.asarray(taskset.pairs, np.int64))


def _pack_svr(reg) -> PackedModel:
    return PackedModel(
        kind="svr", kernel=reg.kernel_params,
        n_features=reg.support_vectors_.shape[1], n_tasks=1,
        buckets=(_single_task_bucket(reg.support_vectors_, reg.dual_coef_,
                                     reg.b_),),
        strategy="svr")


def pack(model) -> PackedModel:
    """Compact a fitted ``SVC``/``SVR`` into an immutable PackedModel."""
    if not getattr(model, "_fitted", False):
        raise ValueError("pack() needs a fitted model (call .fit first)")
    if hasattr(model, "beta_"):
        return _pack_svr(model)
    if model._binary:
        return _pack_binary_svc(model)
    return _pack_multiclass_svc(model)


# ------------------------------------------------------------------ (de)ser
def save(path, model: PackedModel) -> None:
    """Write the versioned .npz artifact (path or open file object).

    The path is written VERBATIM — unlike bare ``np.savez``, which
    silently appends ".npz" to extension-less paths, so a
    ``save(p)`` / ``load(p)`` round-trip always works.
    """
    meta = {
        "schema": SCHEMA_NAME, "version": SCHEMA_VERSION,
        "kind": model.kind, "strategy": model.strategy,
        "decision": model.decision,
        "kernel": dataclasses.asdict(model.kernel),
        "n_features": model.n_features, "n_tasks": model.n_tasks,
        "n_buckets": len(model.buckets),
    }
    arrays = {"meta": np.array(json.dumps(meta, sort_keys=True))}
    if model.classes is not None:
        arrays["classes"] = model.classes
    if model.pairs is not None:
        arrays["pairs"] = model.pairs
    for i, g in enumerate(model.buckets):
        for field, value in g._asdict().items():
            arrays[f"b{i}_{field}"] = value
    if hasattr(path, "write"):
        np.savez(path, **arrays)
    else:
        with open(os.fspath(path), "wb") as f:
            np.savez(f, **arrays)


def load(path) -> PackedModel:
    """Read an artifact written by ``save``; strict about the schema."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"]))
        if meta.get("schema") != SCHEMA_NAME:
            raise ValueError(f"not a {SCHEMA_NAME} artifact: "
                             f"schema={meta.get('schema')!r}")
        if meta.get("version") != SCHEMA_VERSION:
            raise ValueError(
                f"unsupported {SCHEMA_NAME} version {meta.get('version')!r}"
                f" (this build reads version {SCHEMA_VERSION})")
        buckets = tuple(
            TaskBucket(**{f: z[f"b{i}_{f}"] for f in TaskBucket._fields})
            for i in range(meta["n_buckets"]))
        return PackedModel(
            kind=meta["kind"], kernel=K.KernelParams(**meta["kernel"]),
            n_features=meta["n_features"], n_tasks=meta["n_tasks"],
            buckets=buckets, strategy=meta["strategy"],
            decision=meta["decision"],
            classes=z["classes"] if "classes" in z else None,
            pairs=np.asarray(z["pairs"], np.int64) if "pairs" in z
            else None)
