"""Batched serving engine: resident SV banks + jit-cached decide programs.

The training-side ``decision_function`` rebuilds a ``KernelEngine`` and
re-uploads the support vectors on EVERY call, then loops serving buckets
in Python — fine for evaluating a fit, hopeless under request traffic.
``Predictor`` is the serving-side replacement:

* the packed SV bank (``artifact.PackedModel``) is moved to device once,
  at construction, and stays resident;
* decisions run through ONE jitted program per (bucket shape,
  batch bucket) static configuration — for the pallas backend the fused
  multi-task kernel (``kernels.ops.multitask_decision``), which
  evaluates every stacked task of a bucket against the test batch in a
  single grid; for chunked/dense configs a vmapped ``engine.decide``
  (the reference/fallback path, numerically identical to the legacy
  training-side serving);
* request batches are padding-bucketed: each micro-batch is zero-padded
  up to the next power of two (capped at ``max_batch``; longer requests
  stream in ``max_batch`` slices), so arbitrary request sizes reuse a
  small warm set of compiled programs instead of recompiling per shape.

Padded test rows are sliced off before results leave the predictor, and
padded SV rows carry ``coef == 0``, so padding never changes a served
value. Width-0 banks (the empty-SV degenerate model) serve the constant
bias, matching the training-side behavior.

Low-rank packs (``PackedModel.feature_map`` set) skip the SV-bank
machinery entirely: the feature-map arrays and the stacked linear
weights stay resident, and every batch is one jitted transform +
(rank, n_tasks) matmul — serving cost is independent of the
training-set size.

    pred = Predictor(serve.pack(clf), engine="pallas")
    pred.predict(Z)                   # class labels / SVR values
    pred.decision_function(Z)         # margins, sklearn orientation
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kernel_engine as KE
from repro.core import multiclass as MC
from repro.kernels import ops
from repro.serve.artifact import PackedModel


def serving_config(engine: str | KE.EngineConfig) -> KE.EngineConfig:
    """Resolve an engine choice into the serving-side config: serving
    never needs the (sv, sv) training Gram nor the LRU row cache, so
    dense/auto/sharded degrade to chunked; an explicit pallas choice is
    honored."""
    cfg = (engine if isinstance(engine, KE.EngineConfig)
           else KE.EngineConfig(backend=engine))
    backend = "pallas" if cfg.backend == "pallas" else "chunked"
    return dataclasses.replace(cfg, backend=backend, cache_slots=0)


class Predictor:
    """Serve a ``PackedModel``; see module docstring."""

    def __init__(self, model: PackedModel, *,
                 engine: str | KE.EngineConfig = "auto",
                 max_batch: int = 1024):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.model = model
        self.max_batch = int(max_batch)
        self.engine_cfg = serving_config(engine)
        # SV banks move to device once and stay resident; task_ids stay
        # host-side (they only scatter results back into request order)
        self._banks = tuple(
            (jnp.asarray(g.sv_x), jnp.asarray(g.sv_coef),
             jnp.asarray(g.b), np.asarray(g.task_ids))
            for g in model.buckets)
        if model.feature_map is not None:
            # low-rank pack: resident map arrays + stacked linear
            # weights; one jitted transform+matmul program per batch
            # bucket, no SV bank at all
            fm = model.feature_map
            self._fm_arrays = (jnp.asarray(fm.a), jnp.asarray(fm.b))
            self._linear = (jnp.asarray(model.linear_w),
                            jnp.asarray(model.linear_b))
            kind, kp = fm.kind, model.kernel
            gram_dtype = self.engine_cfg.gram_dtype

            def lowrank_decide(a, b, w, lb, z):
                from repro.core import approx
                m = approx.map_from_arrays(kind, kp, a, b,
                                           gram_dtype=gram_dtype)
                return (m.transform(z) @ w.T).T + lb[:, None]

            self._decide_lowrank = jax.jit(lowrank_decide)
        # one jitted callable; XLA caches one executable per distinct
        # (bucket shape, batch bucket) argument signature
        self._decide = jax.jit(self._decide_stack)
        self.n_requests = 0  # rows served (warmup excluded)

    # ---------------------------------------------------------- programs
    def _decide_stack(self, sv_x, sv_coef, b, z):
        """(T, w, d) stacked bank x (B, d) batch -> (T, B) decisions."""
        kp = self.model.kernel
        if self.engine_cfg.backend == "pallas" and kp.name == "rbf":
            return ops.multitask_decision(
                z, sv_x, sv_coef, b, gamma=kp.gamma, mode="rbf",
                compute_dtype=self.engine_cfg.gram_dtype)

        def one(sv, cf, bb):
            return KE.make_engine(sv, kp, self.engine_cfg).decide(z, cf, bb)

        return jax.vmap(one)(sv_x, sv_coef, b)

    @property
    def n_programs(self) -> int:
        """Compiled decide-program count (the jit cache size)."""
        try:
            return int(self._decide._cache_size())
        except AttributeError:  # pragma: no cover - older/newer jax
            return -1

    def _batch_bucket(self, t: int) -> int:
        return min(self.max_batch, 1 << (max(t, 1) - 1).bit_length())

    def warmup(self, batch_sizes=(1,)) -> "Predictor":
        """Pre-compile the decide programs for the given request sizes.

        Warmup rows are synthetic and do NOT count toward
        ``n_requests`` (the served-row counter)."""
        d = self.model.n_features
        served = self.n_requests
        for t in batch_sizes:
            self.decision_values(np.zeros((int(t), d), np.float32))
        self.n_requests = served
        return self

    # ------------------------------------------------------------ serving
    def decision_values(self, xt: np.ndarray) -> np.ndarray:
        """(n_tasks, nt) stacked binary decision values."""
        xt = np.asarray(xt, np.float32)
        if xt.ndim != 2 or xt.shape[1] != self.model.n_features:
            raise ValueError(
                f"expected (n, {self.model.n_features}) request batch, "
                f"got shape {xt.shape}")
        nt = xt.shape[0]
        out = np.empty((self.model.n_tasks, nt), np.float32)
        for start in range(0, nt, self.max_batch):
            stop = min(start + self.max_batch, nt)
            bucket = self._batch_bucket(stop - start)
            zp = np.zeros((bucket, xt.shape[1]), np.float32)
            zp[:stop - start] = xt[start:stop]
            zj = jnp.asarray(zp)
            if self.model.feature_map is not None:
                a, fb = self._fm_arrays
                w, lb = self._linear
                df = self._decide_lowrank(a, fb, w, lb, zj)
                out[:, start:stop] = np.asarray(df)[:, :stop - start]
                continue
            for sv_x, sv_coef, b, task_ids in self._banks:
                if sv_x.shape[1] == 0:  # empty-SV bank: constant bias
                    out[task_ids, start:stop] = np.asarray(b)[:, None]
                    continue
                df = self._decide(sv_x, sv_coef, b, zj)
                out[task_ids, start:stop] = np.asarray(
                    df)[:, :stop - start]
        self.n_requests += nt
        return out

    def decision_function(self, xt: np.ndarray) -> np.ndarray:
        """Margins in the training-side convention: (nt,) for binary
        SVC and SVR (positive margin => ``classes[1]``), (n_tasks, nt)
        stacked for multiclass."""
        df = self.decision_values(xt)
        return df[0] if self.model.strategy in ("binary", "svr") else df

    def predict(self, xt: np.ndarray) -> np.ndarray:
        """Class labels (SVC) or regression values (SVR)."""
        df = self.decision_values(xt)
        m = self.model
        if m.kind == "svr":
            return df[0]
        if m.strategy == "binary":
            return m.classes[(df[0] > 0).astype(np.int64)]
        idx = MC.decide_from_pairs(jnp.asarray(df), m.pairs, m.n_classes,
                                   m.strategy, m.decision)
        return m.classes[np.asarray(idx)]
