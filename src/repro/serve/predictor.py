"""Batched serving engine: resident SV banks + jit-cached decide programs.

The training-side ``decision_function`` rebuilds a ``KernelEngine`` and
re-uploads the support vectors on EVERY call, then loops serving buckets
in Python — fine for evaluating a fit, hopeless under request traffic.
``Predictor`` is the serving-side replacement:

* the packed SV bank (``artifact.PackedModel``) is moved to device once,
  at construction, and stays resident;
* decisions run through ONE jitted program per (bucket shape,
  batch bucket) static configuration — for the pallas backend the fused
  multi-task kernel (``kernels.ops.multitask_decision``), which
  evaluates every stacked task of a bucket against the test batch in a
  single grid; for chunked/dense configs a vmapped ``engine.decide``
  (the reference/fallback path, numerically identical to the legacy
  training-side serving);
* request batches are padding-bucketed: each micro-batch is zero-padded
  up to the next power of two (capped at ``max_batch``; longer requests
  stream in ``max_batch`` slices), so arbitrary request sizes reuse a
  small warm set of compiled programs instead of recompiling per shape.

Padded test rows are sliced off before results leave the predictor, and
padded SV rows carry ``coef == 0``, so padding never changes a served
value. Width-0 banks (the empty-SV degenerate model) serve the constant
bias, matching the training-side behavior.

Quantized packs (``artifact.pack(..., sv_dtype="fp16"|"bf16")``) keep
their SV banks device-resident AT the storage dtype — half the bank
HBM — and every decide program upcasts the bank tiles to f32 before the
cross-Gram contraction, so accumulation is always f32 regardless of how
the bank is stored. fp32 packs are bit-identical to pre-quantization
serving (the upcast is a no-op).

``decision_values`` is thread-safe: concurrent callers each own their
output buffer, jit dispatch is safe under concurrency, and the served-
row counter / compiled-program ledger are guarded by a lock — the
dynamic-batching service (``serve.service``) and its submitters may
share one predictor freely.

Low-rank packs (``PackedModel.feature_map`` set) skip the SV-bank
machinery entirely: the feature-map arrays and the stacked linear
weights stay resident, and every batch is one jitted transform +
(rank, n_tasks) matmul — serving cost is independent of the
training-set size.

    pred = Predictor(serve.pack(clf), engine="pallas")
    pred.predict(Z)                   # class labels / SVR values
    pred.decision_function(Z)         # margins, sklearn orientation
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import kernel_engine as KE
from repro.core import multiclass as MC
from repro.kernels import ops
from repro.serve.artifact import PackedModel


def serving_config(engine: str | KE.EngineConfig) -> KE.EngineConfig:
    """Resolve an engine choice into the serving-side config: serving
    never needs the (sv, sv) training Gram nor the LRU row cache, so
    dense/auto/sharded degrade to chunked; an explicit pallas choice is
    honored. Training-only fields that reference the TRAINING host's
    topology are stripped — in particular ``shard_axis``: a
    sharded-trained model must pack to a config that cannot name a mesh
    axis the serving host does not have."""
    cfg = (engine if isinstance(engine, KE.EngineConfig)
           else KE.EngineConfig(backend=engine))
    backend = "pallas" if cfg.backend == "pallas" else "chunked"
    return dataclasses.replace(cfg, backend=backend, cache_slots=0,
                               shard_axis=None)


def _pow2_floor(n: int) -> int:
    return 1 << (int(n).bit_length() - 1)


class Predictor:
    """Serve a ``PackedModel``; see module docstring."""

    # the served-row counter and program ledger are mutated by every
    # concurrent decision_values caller (enforced by analysis rule R004)
    _GUARDED_BY = {"n_requests": "_lock", "_program_sigs": "_lock"}

    def __init__(self, model: PackedModel, *,
                 engine: str | KE.EngineConfig = "auto",
                 max_batch: int = 1024):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.model = model
        # max_batch is a rung on the pow2 padding ladder, not a free
        # integer: an off-ladder cap (say 1000) would pad 600-row
        # requests to a 1000-row program shape — one silently compiled
        # extra executable per such size class. Round DOWN to the
        # largest pow2 <= max_batch so the cap itself is on-ladder and
        # never exceeds what the caller asked for.
        self.max_batch = _pow2_floor(max_batch)
        self.engine_cfg = serving_config(engine)
        # SV banks move to device once and stay resident; task_ids stay
        # host-side (they only scatter results back into request order)
        self._banks = tuple(
            (jnp.asarray(g.sv_x), jnp.asarray(g.sv_coef),
             jnp.asarray(g.b), np.asarray(g.task_ids))
            for g in model.buckets)
        if model.feature_map is not None:
            # low-rank pack: resident map arrays + stacked linear
            # weights; one jitted transform+matmul program per batch
            # bucket, no SV bank at all
            fm = model.feature_map
            self._fm_arrays = (jnp.asarray(fm.a), jnp.asarray(fm.b))
            self._linear = (jnp.asarray(model.linear_w),
                            jnp.asarray(model.linear_b))
            kind, kp = fm.kind, model.kernel
            gram_dtype = self.engine_cfg.gram_dtype

            def lowrank_decide(a, b, w, lb, z):
                from repro.core import approx
                m = approx.map_from_arrays(kind, kp, a, b,
                                           gram_dtype=gram_dtype)
                return (m.transform(z) @ w.T).T + lb[:, None]

            self._decide_lowrank = jax.jit(lowrank_decide)
        # one jitted callable; XLA caches one executable per distinct
        # (bucket shape, batch bucket) argument signature
        self._decide = jax.jit(self._decide_stack)
        self.n_requests = 0  # rows served (warmup excluded)
        # predictor-owned ledger of distinct (bank signature, batch
        # bucket) program shapes — what n_programs reports; jax's
        # private jit cache introspection moved across versions
        self._program_sigs: set = set()
        self._lock = threading.Lock()

    # ---------------------------------------------------------- programs
    def _decide_stack(self, sv_x, sv_coef, b, z):
        """(T, w, d) stacked bank x (B, d) batch -> (T, B) decisions."""
        # quantized banks (fp16/bf16 packs) upcast to f32 here, inside
        # the program, so the contraction accumulates in f32 while the
        # resident bank stays at the storage dtype; a no-op for fp32
        sv_x = sv_x.astype(jnp.float32)
        sv_coef = sv_coef.astype(jnp.float32)
        kp = self.model.kernel
        if self.engine_cfg.backend == "pallas" and kp.name == "rbf":
            return ops.multitask_decision(
                z, sv_x, sv_coef, b, gamma=kp.gamma, mode="rbf",
                compute_dtype=self.engine_cfg.gram_dtype)

        def one(sv, cf, bb):
            return KE.make_engine(sv, kp, self.engine_cfg).decide(z, cf, bb)

        return jax.vmap(one)(sv_x, sv_coef, b)

    @property
    def n_programs(self) -> int:
        """Compiled decide-program count: distinct (bank shape/dtype,
        batch bucket) signatures served so far. Owned by the predictor
        — it used to read the private ``jit._cache_size()``, which
        moved across jax versions and returned -1 when absent."""
        with self._lock:
            return len(self._program_sigs)

    def _batch_bucket(self, t: int) -> int:
        return min(self.max_batch, 1 << (max(t, 1) - 1).bit_length())

    def warmup(self, batch_sizes=(1,)) -> "Predictor":
        """Pre-compile the decide programs AND the decode (label) path
        for the given request sizes.

        Warmup rows are synthetic and do NOT count toward
        ``n_requests`` (the served-row counter)."""
        d = self.model.n_features
        for t in batch_sizes:
            # predict() runs decision_values + decode, warming both the
            # decide program and the vote/argmax ops at this bucket
            self.predict(np.zeros((int(t), d), np.float32))
        # subtract exactly the synthetic rows rather than restoring a
        # pre-warmup snapshot: concurrent real requests served DURING
        # warmup keep their counts (the snapshot restore erased them)
        with self._lock:
            self.n_requests -= sum(int(t) for t in batch_sizes)
        return self

    # ------------------------------------------------------------ serving
    def decision_values(self, xt: np.ndarray) -> np.ndarray:
        """(n_tasks, nt) stacked binary decision values."""
        xt = np.asarray(xt, np.float32)
        if xt.ndim != 2 or xt.shape[1] != self.model.n_features:
            raise ValueError(
                f"expected (n, {self.model.n_features}) request batch, "
                f"got shape {xt.shape}")
        nt = xt.shape[0]
        out = np.empty((self.model.n_tasks, nt), np.float32)
        sigs = []
        for start in range(0, nt, self.max_batch):
            stop = min(start + self.max_batch, nt)
            bucket = self._batch_bucket(stop - start)
            zp = np.zeros((bucket, xt.shape[1]), np.float32)
            zp[:stop - start] = xt[start:stop]
            zj = jnp.asarray(zp)
            if self.model.feature_map is not None:
                a, fb = self._fm_arrays
                w, lb = self._linear
                df = self._decide_lowrank(a, fb, w, lb, zj)
                out[:, start:stop] = np.asarray(df)[:, :stop - start]
                sigs.append(("lowrank", bucket))
                continue
            for sv_x, sv_coef, b, task_ids in self._banks:
                if sv_x.shape[1] == 0:  # empty-SV bank: constant bias
                    out[task_ids, start:stop] = np.asarray(b)[:, None]
                    continue
                df = self._decide(sv_x, sv_coef, b, zj)
                out[task_ids, start:stop] = np.asarray(
                    df)[:, :stop - start]
                sigs.append((sv_x.shape, str(sv_x.dtype), bucket))
        with self._lock:
            self._program_sigs.update(sigs)
            self.n_requests += nt
        return out

    def decode(self, df: np.ndarray, op: str = "predict") -> np.ndarray:
        """Post-process stacked decision values ``df (n_tasks, nt)``
        into the requested output — the per-model decode step the
        dynamic-batching service shares across every request of a fused
        batch (compute ``decision_values`` once, decode column slices
        per request).

        op: "values" (the stacked df, unchanged), "decision_function"
        (margins, sklearn orientation) or "predict" (labels / SVR
        values)."""
        m = self.model
        if op == "values":
            return df
        if op == "decision_function":
            return df[0] if m.strategy in ("binary", "svr") else df
        if op != "predict":
            raise ValueError(f"unknown decode op {op!r}; expected "
                             "'predict', 'decision_function' or 'values'")
        if m.kind == "svr":
            return df[0]
        if m.strategy == "binary":
            return m.classes[(df[0] > 0).astype(np.int64)]
        # pad the vote/argmax decode onto the same pow2 ladder as the
        # decide programs: its eager jnp ops compile per distinct width,
        # so decoding at the raw width would grow the compile cache one
        # entry per odd request size (a multi-hundred-ms stall apiece
        # under open-loop traffic). Padded columns (df == 0) are decoded
        # and discarded — the decision is columnwise.
        nt = df.shape[1]
        bucket = 1 << max(nt - 1, 0).bit_length()
        if bucket > nt:
            dfp = np.zeros((df.shape[0], bucket), np.float32)
            dfp[:, :nt] = df
            df = dfp
        idx = MC.decide_from_pairs(jnp.asarray(df), m.pairs, m.n_classes,
                                   m.strategy, m.decision)
        return m.classes[np.asarray(idx)[:nt]]

    def decision_function(self, xt: np.ndarray) -> np.ndarray:
        """Margins in the training-side convention: (nt,) for binary
        SVC and SVR (positive margin => ``classes[1]``), (n_tasks, nt)
        stacked for multiclass."""
        return self.decode(self.decision_values(xt), "decision_function")

    def predict(self, xt: np.ndarray) -> np.ndarray:
        """Class labels (SVC) or regression values (SVR)."""
        return self.decode(self.decision_values(xt), "predict")
