"""Serving subsystem: packed model artifacts + the batched Predictor.

    from repro import serve

    packed = serve.pack(clf)              # fitted SVC / SVR -> artifact
    serve.save("model.npz", packed)       # versioned npz schema
    pred = serve.Predictor(serve.load("model.npz"), engine="pallas")
    pred.predict(Z)                       # jit-cached batched serving

See ``serve.artifact`` for the artifact schema and ``serve.predictor``
for the bucket/jit-cache behavior.
"""
from repro.serve.artifact import (LowRankMap, PackedModel,  # noqa: F401
                                  TaskBucket, SCHEMA_NAME, SCHEMA_VERSION,
                                  SCHEMA_VERSION_CLASSIC, SCHEMA_VERSIONS,
                                  load, pack, save)
from repro.serve.predictor import Predictor, serving_config  # noqa: F401
