"""Serving subsystem: packed artifacts, the batched Predictor, and the
async dynamic-batching service layer.

    from repro import serve

    packed = serve.pack(clf, sv_dtype="fp16")   # quantized SV bank
    serve.save("model.npz", packed)             # versioned npz schema
    pred = serve.Predictor(serve.load("model.npz"), engine="pallas")
    pred.predict(Z)                             # jit-cached batched serving

    svc = serve.ServingService(packed, window_ms=2.0)   # open-loop traffic
    svc.submit(z).result()                      # dynamic-batched future
    reg = serve.ModelRegistry(max_resident=4)   # multi-model LRU residency

See ``serve.artifact`` for the artifact schema (v1/v2/v3 + SV-bank
quantization), ``serve.predictor`` for the bucket/jit-cache behavior,
``serve.registry`` for LRU device residency and ``serve.service`` for
the batching-window semantics.
"""
from repro.serve.artifact import (LowRankMap, PackedModel,  # noqa: F401
                                  TaskBucket, SCHEMA_NAME, SCHEMA_VERSION,
                                  SCHEMA_VERSION_CLASSIC,
                                  SCHEMA_VERSION_QUANT, SCHEMA_VERSIONS,
                                  SV_DTYPES, load, pack, quantize, save)
from repro.serve.predictor import Predictor, serving_config  # noqa: F401
from repro.serve.registry import ModelRegistry  # noqa: F401
from repro.serve.service import ServingService  # noqa: F401
