"""Serving subsystem: packed model artifacts + the batched Predictor.

    from repro import serve

    packed = serve.pack(clf)              # fitted SVC / SVR -> artifact
    serve.save("model.npz", packed)       # versioned npz schema
    pred = serve.Predictor(serve.load("model.npz"), engine="pallas")
    pred.predict(Z)                       # jit-cached batched serving

See ``serve.artifact`` for the artifact schema and ``serve.predictor``
for the bucket/jit-cache behavior.
"""
from repro.serve.artifact import (PackedModel, TaskBucket,  # noqa: F401
                                  SCHEMA_NAME, SCHEMA_VERSION, load, pack,
                                  save)
from repro.serve.predictor import Predictor, serving_config  # noqa: F401
