"""Multi-model registry with LRU device residency.

A serving host holds many packed models but has bounded accelerator
memory: the SV banks of every registered model cannot all stay
device-resident. ``ModelRegistry`` splits the two concerns:

* **registration** is host-side and unbounded — ``register`` keeps the
  ``PackedModel`` (numpy arrays, or loaded from an artifact path) on
  the host, forever cheap;
* **residency** is device-side and LRU-bounded — ``get`` returns a warm
  ``serve.Predictor`` for the name, admitting it (bank upload + decide
  program warmup) on first use and evicting the least-recently-used
  resident model once ``max_resident`` is reached. Eviction drops the
  predictor — its device banks and jit programs — but the host arrays
  stay registered, so re-admission is a re-upload + re-warm, not a
  reload from disk, and serves bit-identical values (same pack, same
  programs).

All public methods are thread-safe (one registry lock); admission work
(upload + warmup) happens under the lock, so concurrent ``get`` calls
for the same cold model admit it exactly once.

    reg = ModelRegistry(max_resident=2, engine="pallas")
    reg.register("fraud-v3", serve.pack(clf))
    reg.register("churn-v1", "/models/churn-v1.npz")   # path form
    reg.get("fraud-v3").predict(Z)                     # admits + serves
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Union

from repro.core import kernel_engine as KE
from repro.serve import artifact
from repro.serve.artifact import PackedModel
from repro.serve.predictor import Predictor


class ModelRegistry:
    """Named packed models with LRU-bounded device residency."""

    # everything mutable is coordinated by the one registry lock
    # (enforced by analysis rule R004); readers go through the locked
    # accessors / the `stats` snapshot property
    _GUARDED_BY = {"_models": "_lock", "_resident": "_lock",
                   "_stats": "_lock"}

    def __init__(self, *, max_resident: int = 4,
                 engine: Union[str, KE.EngineConfig] = "auto",
                 max_batch: int = 1024,
                 warmup_sizes: tuple = (1,)):
        if max_resident < 1:
            raise ValueError(
                f"max_resident must be >= 1, got {max_resident}")
        self.max_resident = int(max_resident)
        self.engine = engine
        self.max_batch = int(max_batch)
        self.warmup_sizes = tuple(warmup_sizes)
        self._models: dict[str, PackedModel] = {}          # host-side
        self._resident: OrderedDict[str, Predictor] = OrderedDict()
        self._lock = threading.RLock()
        self._stats = {"hits": 0, "admissions": 0, "evictions": 0}

    # ------------------------------------------------------- registration
    def register(self, name: str, model, *, replace: bool = False) -> None:
        """Register a ``PackedModel`` (or an artifact path to ``load``)
        under ``name``. Host-side only — nothing touches the device
        until the first ``get``. ``replace=True`` swaps an existing
        entry and evicts its resident predictor (the next ``get``
        serves the new pack)."""
        if not isinstance(model, PackedModel):
            model = artifact.load(model)
        with self._lock:
            if name in self._models and not replace:
                raise ValueError(f"model {name!r} already registered "
                                 "(pass replace=True to swap it)")
            self._models[name] = model
            self._drop_resident(name)

    def unregister(self, name: str) -> None:
        """Forget ``name`` entirely (host arrays and any residency)."""
        with self._lock:
            self._require(name)
            del self._models[name]
            self._drop_resident(name)

    # ---------------------------------------------------------- residency
    def get(self, name: str) -> Predictor:
        """The warm predictor for ``name`` — admitting (upload + warmup,
        evicting the LRU resident if full) or just refreshing recency."""
        with self._lock:
            self._require(name)
            pred = self._resident.get(name)
            if pred is not None:
                self._resident.move_to_end(name)
                self._stats["hits"] += 1
                return pred
            while len(self._resident) >= self.max_resident:
                self._resident.popitem(last=False)   # least recently used
                self._stats["evictions"] += 1
            pred = Predictor(self._models[name], engine=self.engine,
                             max_batch=self.max_batch)
            if self.warmup_sizes:
                pred.warmup(self.warmup_sizes)
            self._resident[name] = pred
            self._stats["admissions"] += 1
            return pred

    def evict(self, name: str) -> bool:
        """Explicitly drop ``name``'s device residency (host arrays
        stay registered). Returns whether it was resident."""
        with self._lock:
            self._require(name)
            return self._drop_resident(name)

    def model(self, name: str) -> PackedModel:
        """The registered host-side pack (no residency side effects)."""
        with self._lock:
            self._require(name)
            return self._models[name]

    # --------------------------------------------------------- inspection
    @property
    def stats(self) -> dict:
        """Snapshot of the hit/admission/eviction counters. A copy:
        callers used to read the live dict while `get` mutated it on
        another thread (a torn read R004 now rejects)."""
        with self._lock:
            return dict(self._stats)

    @property
    def names(self) -> tuple:
        with self._lock:
            return tuple(self._models)

    @property
    def resident(self) -> tuple:
        """Resident names, least- to most-recently used."""
        with self._lock:
            return tuple(self._resident)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._models

    def __len__(self) -> int:
        with self._lock:
            return len(self._models)

    # ----------------------------------------------------------- internal
    def _require(self, name: str) -> None:  # repro: holds[_lock]
        if name not in self._models:
            raise KeyError(f"model {name!r} is not registered "
                           f"(registered: {sorted(self._models)})")

    def _drop_resident(self, name: str) -> bool:  # repro: holds[_lock]
        pred = self._resident.pop(name, None)
        return pred is not None
