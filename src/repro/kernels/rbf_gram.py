"""Tiled Gram-matrix Pallas kernel — the SVM compute hot spot.

The paper's CUDA solver spends its time computing kernel (Gram) rows /
blocks. The TPU-native formulation tiles the (n, m) output into
MXU-aligned VMEM blocks:

  grid (n/bn, m/bm, d/bd):  each step loads  A-tile (bn, bd)  and
  B-tile (bm, bd) from HBM into VMEM, accumulates the inner-product
  block  A·Bᵀ (bn, bm)  on the MXU (f32 accumulation), and on the last
  d-step fuses the RBF transform

      K = exp(-gamma (|a|² + |b|² - 2 a·b))

  directly in VMEM before writing the finished block back to HBM —
  the squared norms ride along as (bn, 1)/(1, bm) VMEM blocks instead of
  being recomputed from the features.

VMEM working set per step = bn·bd + bm·bd + bn·bm floats; the default
(128, 128, 128) tiles use ≈ 192 KiB — far under the ~16 MiB/core budget,
leaving room for the pipeline's double buffering. The default tiles are
only a safe baseline: ``kernels.autotune`` hillclimbs (bn, bm, bd) per
(device kind, dtype, shape bucket) and ``ops.rbf_gram`` picks tuned
values up from the on-disk cache.

Mixed precision: bf16 inputs are fed to the MXU as-is (halving the HBM
tile traffic) while the dot accumulates in f32
(``preferred_element_type``) and the RBF epilogue runs in f32 — the
squared norms are computed OUTSIDE in f32 from the same (rounded)
operand values, so K(x, x) stays 1 up to f32 rounding (~1e-6), not
bf16 epsilon.

The d-axis (reduction) must be the innermost, sequential grid dimension:
the output block is revisited across d-steps (TPU grids are sequential by
default; `dimension_semantics` marks n/m as parallel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_COMPUTE_DTYPES = (jnp.float32, jnp.bfloat16)


def check_block_divisibility(name: str, **axis_blocks) -> None:
    """Uniform padded-shape validation for the Pallas kernels.

    Each kwarg maps an axis label to a ``(size, block)`` pair; any axis
    not a multiple of its block raises a ValueError naming the fix —
    direct callers (and odd tile choices coming out of the autotuner)
    get a clear error instead of a bare assert tuple. The ``ops.py``
    wrappers pad before calling, so they never trip this.
    """
    bad = {axis: (size, block) for axis, (size, block) in
           axis_blocks.items() if size % block != 0}
    if bad:
        detail = ", ".join(f"{axis}={size} % block={block}"
                           for axis, (size, block) in bad.items())
        raise ValueError(
            f"{name}: inputs must be pre-padded to block multiples "
            f"({detail}); call the padding-aware wrapper in "
            f"repro.kernels.ops, or pad the operands / pick block sizes "
            f"dividing the shape")


def _rbf_gram_kernel(a_ref, b_ref, a2_ref, b2_ref, out_ref, *,
                     gamma: float, n_d_steps: int, mode: str):
    """One (bn, bm) output block; accumulates over the d grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                              # (bn, bd) f32 or bf16
    b = b_ref[...]                              # (bm, bd)
    out_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),          # a @ b.T on the MXU
        preferred_element_type=jnp.float32)

    @pl.when(k == n_d_steps - 1)
    def _finish():
        if mode == "rbf":
            d2 = a2_ref[...] + b2_ref[...] - 2.0 * out_ref[...]
            out_ref[...] = jnp.exp(-gamma * jnp.maximum(d2, 0.0))
        # mode == "linear": the accumulated dot IS the Gram block


def rbf_gram_pallas(a: jax.Array, b: jax.Array, *, gamma: float,
                    block_n: int = 128, block_m: int = 128,
                    block_d: int = 128, mode: str = "rbf",
                    interpret: bool = True) -> jax.Array:
    """Gram block K(a, b) of shape (n, m). Inputs must be pre-padded to
    multiples of the block sizes (see ``ops.rbf_gram`` for the public,
    padding-aware wrapper). bf16 inputs run the mixed-precision path:
    bf16 tile loads, f32 accumulation and epilogue."""
    n, d = a.shape
    m, d2 = b.shape
    if d != d2:
        raise ValueError(f"rbf_gram_pallas: feature dims differ "
                         f"({d} vs {d2})")
    check_block_divisibility("rbf_gram_pallas", n=(n, block_n),
                             m=(m, block_m), d=(d, block_d))
    if a.dtype not in _COMPUTE_DTYPES:
        a = a.astype(jnp.float32)
    if b.dtype not in _COMPUTE_DTYPES:
        b = b.astype(jnp.float32)
    grid = (n // block_n, m // block_m, d // block_d)

    a2 = jnp.sum(a.astype(jnp.float32) ** 2, axis=1, keepdims=True)  # (n,1)
    b2 = jnp.sum(b.astype(jnp.float32) ** 2, axis=1, keepdims=True).T  # (1,m)

    kernel = functools.partial(_rbf_gram_kernel, gamma=gamma,
                               n_d_steps=grid[2], mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_m, block_d), lambda i, j, k: (j, k)),
            pl.BlockSpec((block_n, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, block_m), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(a, b, a2, b2)
