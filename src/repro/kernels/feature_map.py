"""Fused random-Fourier-feature map — the approximate-kernel hot spot.

The RFF transform ``Φ = scale * cos(X Ω + phase)`` is the entire Gram
stage of the low-rank training tier (``repro.core.approx.RFFMap``): one
(n, d)x(d, k) matmul plus an elementwise epilogue, exactly the shape of
the RBF Gram kernel with the exp epilogue swapped for cos. It reuses
that kernel's tiling:

  grid (n/bn, k/bm, d/bd): each step loads an X-tile (bn, bd) and an
  Ω-tile (bd, bm) into VMEM, accumulates X·Ω (bn, bm) on the MXU in
  f32, and on the last d-step fuses the feature epilogue

      Φ = scale * cos(acc + phase)

  in VMEM before the single write back to HBM — the phase vector rides
  along as a (1, bm) block, and the intermediate (n, k) pre-activation
  never exists in HBM.

The d-axis (reduction) must be the innermost, sequential grid
dimension, as in ``rbf_gram``. Mixed precision mirrors the Gram
kernels: bf16 tile loads with ``preferred_element_type=f32``
accumulation, f32 epilogue. Block sizes are tunable through
``kernels.autotune`` under the kernel name ``"rff_features"``; the
padding-aware public wrapper is ``ops.rff_features``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rbf_gram import check_block_divisibility

_COMPUTE_DTYPES = (jnp.float32, jnp.bfloat16)


def _rff_kernel(x_ref, w_ref, ph_ref, out_ref, *, scale: float,
                n_d_steps: int):
    """One (bn, bm) feature block; accumulates over the d grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                               # (bn, bd) f32 or bf16
    w = w_ref[...]                               # (bd, bm)
    out_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),           # x @ w on the MXU
        preferred_element_type=jnp.float32)

    @pl.when(k == n_d_steps - 1)
    def _finish():
        out_ref[...] = scale * jnp.cos(out_ref[...] + ph_ref[...])


def rff_features_pallas(x: jax.Array, omega: jax.Array, phase: jax.Array,
                        *, scale: float, block_n: int = 128,
                        block_m: int = 128, block_d: int = 128,
                        interpret: bool = True) -> jax.Array:
    """Feature block ``scale * cos(x @ omega + phase)`` of shape (n, k).

    ``x (n, d)``, ``omega (d, k)``, ``phase (1, k)`` must be pre-padded
    to block multiples (see ``ops.rff_features`` for the public,
    padding-aware wrapper). bf16 x/omega run the mixed-precision path:
    bf16 tile loads, f32 accumulation and epilogue.
    """
    n, d = x.shape
    d2, k = omega.shape
    if d != d2:
        raise ValueError(f"rff_features_pallas: feature dims differ "
                         f"({d} vs {d2})")
    if phase.shape != (1, k):
        raise ValueError(f"rff_features_pallas: phase must be (1, {k}), "
                         f"got {phase.shape}")
    check_block_divisibility("rff_features_pallas", n=(n, block_n),
                             k=(k, block_m), d=(d, block_d))
    if x.dtype not in _COMPUTE_DTYPES:
        x = x.astype(jnp.float32)
    if omega.dtype not in _COMPUTE_DTYPES:
        omega = omega.astype(jnp.float32)
    phase = phase.astype(jnp.float32)
    grid = (n // block_n, k // block_m, d // block_d)

    kernel = functools.partial(_rff_kernel, scale=scale,
                               n_d_steps=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_d, block_m), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, block_m), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(x, omega, phase)
