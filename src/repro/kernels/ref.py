"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical definition the corresponding kernel
must reproduce (asserted with ``assert_allclose`` across shape/dtype
sweeps in ``tests/test_kernels_pallas.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kernels as K


def rbf_gram(a: jax.Array, b: jax.Array, gamma: float) -> jax.Array:
    """K[i, j] = exp(-gamma ||a_i - b_j||^2), float32."""
    return K.rbf_gram(a, b, gamma=gamma)


def linear_gram(a: jax.Array, b: jax.Array) -> jax.Array:
    return K.linear_gram(a, b)


def kkt_select(f: jax.Array, alpha: jax.Array, y: jax.Array,
               mask: jax.Array, c: float):
    """(b_up, i_up, b_low, i_low) — masked KKT min/argmin & max/argmax.

    Same semantics as ``repro.core.smo._selection``.
    """
    eps = 1e-6 * c
    pos, neg = y > 0, y <= 0
    not_upper = alpha < c - eps
    not_lower = alpha > eps
    up_mask = mask & ((pos & not_upper) | (neg & not_lower))
    low_mask = mask & ((pos & not_lower) | (neg & not_upper))
    f_up = jnp.where(up_mask, f, jnp.inf)
    f_low = jnp.where(low_mask, f, -jnp.inf)
    i_up = jnp.argmin(f_up)
    i_low = jnp.argmax(f_low)
    return f_up[i_up], i_up, f_low[i_low], i_low


def decision(x_test: jax.Array, x_train: jax.Array, coef: jax.Array,
             b: jax.Array, gamma: float) -> jax.Array:
    """f(z) = sum_i coef_i exp(-gamma||x_i - z||^2) + b, coef = alpha*y."""
    kmat = K.rbf_gram(x_test, x_train, gamma=gamma)
    return kmat @ coef + b


def ssd_diag(cmat, bmat, x, dt, cs):
    """Intra-chunk SSD oracle (matches repro.models.mamba2.ssd_chunked's
    y_diag stage, G=1). cmat/bmat (BC,Q,N); x (BC,H,Q,P); dt/cs (BC,H,Q)."""
    scores = jnp.einsum("cqn,ckn->cqk", cmat.astype(jnp.float32),
                        bmat.astype(jnp.float32))
    seg = cs[:, :, :, None] - cs[:, :, None, :]      # (BC,H,Q,Q)
    q = cmat.shape[1]
    causal = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(causal[None, None], jnp.exp(seg), 0.0)
    w = scores[:, None] * l_mat * dt[:, :, None, :]
    return jnp.einsum("chqk,chkp->chqp", w, x.astype(jnp.float32))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """Plain softmax attention oracle. q (BH,Sq,d), k/v (BH,Sk,d[v])."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
