"""Roofline-driven tile autotuning for the SVM Pallas kernels.

The hot kernels (``rbf_gram``, ``kkt_select``, ``decision``,
``multitask_decision``) ship MXU-aligned default tiles that are correct
everywhere but optimal nowhere in particular. This module makes every
tile/block knob tunable per (device kind, kernel, dtype, shape bucket):

* ``candidates(kernel, shape, dtype)`` enumerates the feasible tile
  configurations — powers of two per axis, clipped to the shape, lane /
  sublane aligned, and filtered against the ~16 MiB/core VMEM budget
  with double buffering (the same structural constraint
  ``tests/test_kernels_pallas.py::test_blockspec_vmem_budget`` pins for
  the defaults);
* ``roofline_estimate(...)`` prices a configuration with the TPU-v5e
  roofline constants from ``repro.roofline.collect`` — per-tile HBM
  traffic (bigger output tiles re-stream fewer operand bytes) vs MXU
  FLOPs, the collect/differential cost model pointed at the SVM kernels
  instead of the transformer stack;
* ``tune(...)`` hillclimbs from the default configuration: evaluate the
  current config and its single-axis x2 / /2 neighbours (timed jitted
  calls and/or the roofline estimate, see ``objective``), move to the
  best, stop when no neighbour improves or the evaluation budget is
  spent. The default config is ALWAYS evaluated, so the tuned result is
  never worse than the default under the chosen objective;
* ``TuningCache`` persists results as versioned JSON keyed by
  ``device|kernel|dtype|bucket``. A missing, corrupted or
  version-mismatched cache silently falls back to the defaults — tuning
  is an optimization, never a correctness dependency;
* ``lookup(kernel, shape, dtype)`` is the runtime fast path
  ``kernels.ops`` consults when a caller does not pass explicit block
  sizes: tuned config if the cache has this bucket, ``None`` (-> the
  hardcoded defaults) otherwise.

Objectives
----------
``wall``      median wall seconds of the jitted kernel call (the honest
              metric on real TPU hardware).
``roofline``  the analytic estimate alone — deterministic and cheap; the
              right choice for CPU/interpret-mode smoke runs, where wall
              time measures the Pallas interpreter, not the kernel.
``auto``      ``wall`` on TPU; elsewhere ranks by the roofline estimate
              and breaks ties with measured wall time.

The cache location is ``$REPRO_TUNE_CACHE`` when set, else
``~/.cache/repro/autotune.json``; ``repro.roofline.svm_tune`` is the CLI
driver that fills it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

CACHE_VERSION = 1
_ENV_CACHE = "REPRO_TUNE_CACHE"

# ~16 MiB/core VMEM; a candidate's double-buffered working set must fit
VMEM_BUDGET_BYTES = 16 * 2 ** 20

DEFAULTS: dict[str, dict[str, int]] = {
    "rbf_gram": {"block_n": 128, "block_m": 128, "block_d": 128},
    "rff_features": {"block_n": 128, "block_m": 128, "block_d": 128},
    "kkt_select": {"block": 1024},
    "decision": {"block_t": 128, "block_n": 128},
    "multitask_decision": {"block_t": 128, "block_n": 128},
}

# per-axis candidate ladders (powers of two). Lane-mapped axes (the last
# block dimension on TPU) stay >= 128; sublane axes may drop to 64.
_LADDERS: dict[str, dict[str, tuple[int, ...]]] = {
    "rbf_gram": {"block_n": (64, 128, 256, 512),
                 "block_m": (128, 256, 512),
                 "block_d": (128, 256, 512)},
    "rff_features": {"block_n": (64, 128, 256, 512),
                     "block_m": (128, 256, 512),
                     "block_d": (128, 256, 512)},
    "kkt_select": {"block": (256, 512, 1024, 2048, 4096)},
    "decision": {"block_t": (64, 128, 256, 512),
                 "block_n": (128, 256, 512, 1024)},
    "multitask_decision": {"block_t": (64, 128, 256, 512),
                           "block_n": (128, 256, 512, 1024)},
}

_DTYPE_BYTES = {"fp32": 4, "bf16": 2}


def _next_pow2(v: int) -> int:
    return 1 << max(int(v) - 1, 0).bit_length()


def _ceil_div(a: int, b: int) -> int:
    return -(-a) // b


# --------------------------------------------------------------- buckets
def shape_bucket(kernel: str, shape: tuple[int, ...]) -> str:
    """Shape -> cache-bucket string: every axis rounded up to a power of
    two, so one tuning run generalizes to its whole pow2 neighbourhood
    (the serving layer already pads batches to pow2 buckets)."""
    axes = {
        "rbf_gram": ("n", "m", "d"),
        "rff_features": ("n", "k", "d"),
        "kkt_select": ("n",),
        "decision": ("t", "n", "d"),
        "multitask_decision": ("tasks", "t", "w", "d"),
    }[kernel]
    if len(shape) != len(axes):
        raise ValueError(
            f"{kernel} expects a {len(axes)}-axis shape {axes}, got "
            f"{shape}")
    return "_".join(f"{a}{_next_pow2(s)}" for a, s in zip(axes, shape))


def cache_key(device: str, kernel: str, dtype: str,
              shape: tuple[int, ...]) -> str:
    return "|".join((device, kernel, dtype, shape_bucket(kernel, shape)))


def device_kind() -> str:
    import jax
    return jax.devices()[0].device_kind.replace("|", "_")


# ------------------------------------------------------------ candidates
def _block_dims(kernel: str, shape: tuple[int, ...]) -> dict[str, int]:
    """Map each tunable block axis to the shape axis it tiles."""
    if kernel in ("rbf_gram", "rff_features"):
        n, m, d = shape
        return {"block_n": n, "block_m": m, "block_d": d}
    if kernel == "kkt_select":
        n, = shape
        return {"block": n}
    if kernel == "decision":
        t, n, _ = shape
        return {"block_t": t, "block_n": n}
    if kernel == "multitask_decision":
        _, t, w, _ = shape
        return {"block_t": t, "block_n": w}
    raise ValueError(f"unknown tunable kernel {kernel!r}; expected "
                     f"one of {sorted(_LADDERS)}")


def _vmem_bytes(kernel: str, cfg: dict, shape: tuple[int, ...],
                dtype: str) -> int:
    """Per-grid-step VMEM working set (bytes, single-buffered)."""
    es = _DTYPE_BYTES[dtype]
    if kernel == "rbf_gram":
        bn, bm, bd = cfg["block_n"], cfg["block_m"], cfg["block_d"]
        return (bn * bd + bm * bd) * es + (bn * bm + bn + bm) * 4
    if kernel == "rff_features":
        bn, bm, bd = cfg["block_n"], cfg["block_m"], cfg["block_d"]
        return (bn * bd + bd * bm) * es + (bn * bm + bm) * 4
    if kernel == "kkt_select":
        return 4 * cfg["block"] * 4
    d = shape[-1]
    bt, bn = cfg["block_t"], cfg["block_n"]
    return (bt * d + bn * d) * es + (bn + bt) * 4


def candidates(kernel: str, shape: tuple[int, ...],
               dtype: str = "fp32") -> list[dict[str, int]]:
    """Feasible tile configs: ladder values clipped to the (pow2-rounded)
    shape, VMEM-budget filtered, defaults always included."""
    dims = _block_dims(kernel, shape)
    ladders = {}
    for axis, ladder in _LADDERS[kernel].items():
        cap = max(_next_pow2(dims[axis]), ladder[0])
        vals = tuple(v for v in ladder if v <= cap) or (ladder[0],)
        ladders[axis] = vals
    out: list[dict[str, int]] = []

    def expand(axes, partial):
        if not axes:
            out.append(dict(partial))
            return
        axis, rest = axes[0], axes[1:]
        for v in ladders[axis]:
            partial[axis] = v
            expand(rest, partial)

    expand(list(ladders), {})
    default = clip_to_candidates(kernel, DEFAULTS[kernel], shape)
    if default not in out:
        out.insert(0, default)
    feasible = [c for c in out
                if 2 * _vmem_bytes(kernel, c, shape, dtype)
                <= VMEM_BUDGET_BYTES]
    return feasible or [default]


def clip_to_candidates(kernel: str, cfg: dict[str, int],
                       shape: tuple[int, ...]) -> dict[str, int]:
    """Clip a config onto the per-shape ladder (the default config for a
    tiny problem clips down to the largest feasible tile)."""
    dims = _block_dims(kernel, shape)
    out = {}
    for axis, ladder in _LADDERS[kernel].items():
        cap = max(_next_pow2(dims[axis]), ladder[0])
        v = min(cfg.get(axis, DEFAULTS[kernel][axis]), cap)
        out[axis] = max(lv for lv in ladder if lv <= max(v, ladder[0]))
    return out


# ------------------------------------------------------ roofline pricing
def roofline_estimate(kernel: str, shape: tuple[int, ...],
                      dtype: str, cfg: dict[str, int]) -> dict:
    """Analytic per-call roofline terms for one tile configuration.

    HBM traffic follows the kernels' actual pipelining: an operand tile
    is re-fetched whenever its block index changes along the grid
    iteration order, so larger output tiles amortize operand streaming
    (the classic tiled-matmul I/O model); dtype sets the operand element
    size (the bf16 payoff). FLOPs are tile-independent.
    """
    es = _DTYPE_BYTES[dtype]
    if kernel == "rbf_gram":
        n, m, d = shape
        bn, bm = cfg["block_n"], cfg["block_m"]
        flops = 2.0 * n * m * d + 8.0 * n * m
        hbm = (_ceil_div(m, bm) * n * d * es      # A re-streamed per j
               + _ceil_div(n, bn) * m * d * es    # B re-streamed per i
               + n * m * 4                        # output written once
               + _ceil_div(m, bm) * n * 4 + _ceil_div(n, bn) * m * 4)
    elif kernel == "rff_features":
        n, k, d = shape
        bn, bm = cfg["block_n"], cfg["block_m"]
        flops = 2.0 * n * k * d + 12.0 * n * k   # matmul + cos epilogue
        hbm = (_ceil_div(k, bm) * n * d * es      # X re-streamed per j
               + _ceil_div(n, bn) * k * d * es    # Omega re-streamed per i
               + n * k * 4                        # features written once
               + _ceil_div(n, bn) * k * 4)        # phase per i
    elif kernel == "kkt_select":
        n, = shape
        flops = 12.0 * n
        hbm = 4 * n * 4 + 4 * _ceil_div(n, cfg["block"]) * 4
    elif kernel == "decision":
        t, n, d = shape
        bt = cfg["block_t"]
        flops = 2.0 * t * n * d + 10.0 * t * n
        hbm = (t * d * es                          # test tile: reused per i
               + _ceil_div(t, bt) * n * (d * es + 4)  # train+coef per i
               + t * 4)
    elif kernel == "multitask_decision":
        tasks, t, w, d = shape
        bt = cfg["block_t"]
        flops = tasks * (2.0 * t * w * d + 10.0 * t * w)
        hbm = (t * d * es
               + tasks * _ceil_div(t, bt) * w * (d * es + 4)
               + tasks * t * 4)
    else:
        raise ValueError(f"unknown tunable kernel {kernel!r}")
    from repro.roofline.collect import roofline_terms
    terms = roofline_terms(flops=flops, hbm_bytes=hbm,
                           collective_bytes_total=0.0)
    terms["flops"] = flops
    terms["hbm_bytes"] = hbm
    return terms


# ------------------------------------------------------------ measuring
def _timeit(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _bench_closure(kernel: str, shape: tuple[int, ...], dtype: str,
                   cfg: dict[str, int]) -> Callable:
    """A zero-arg closure running the real ops wrapper with explicit
    blocks (imports deferred: ops imports this module for lookup())."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    if kernel == "rbf_gram":
        n, m, d = shape
        a = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        return lambda: ops.rbf_gram(a, b, gamma=0.5, compute_dtype=dtype,
                                    **cfg)
    if kernel == "rff_features":
        n, k, d = shape
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        omega = jnp.asarray(rng.normal(size=(d, k)).astype(np.float32))
        phase = jnp.asarray(
            rng.uniform(0, 2 * np.pi, size=k).astype(np.float32))
        scale = float(np.sqrt(2.0 / k))
        return lambda: ops.rff_features(x, omega, phase, scale=scale,
                                        compute_dtype=dtype, **cfg)
    if kernel == "kkt_select":
        n, = shape
        f = jnp.asarray(rng.normal(size=n).astype(np.float32))
        alpha = jnp.asarray(rng.uniform(0, 1, size=n).astype(np.float32))
        y = jnp.asarray(np.where(rng.random(n) < 0.5, 1.0, -1.0)
                        .astype(np.float32))
        mask = jnp.ones(n, bool)
        return lambda: ops.kkt_select(f, alpha, y, mask, c=1.0,
                                      block=cfg["block"])
    if kernel == "decision":
        t, n, d = shape
        xt = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
        xr = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        coef = jnp.asarray(rng.normal(size=n).astype(np.float32))
        return lambda: ops.decision(xt, xr, coef, 0.0, gamma=0.5,
                                    compute_dtype=dtype, **cfg)
    if kernel == "multitask_decision":
        tasks, t, w, d = shape
        xt = jnp.asarray(rng.normal(size=(t, d)).astype(np.float32))
        sv = jnp.asarray(rng.normal(size=(tasks, w, d)).astype(np.float32))
        coef = jnp.asarray(rng.normal(size=(tasks, w)).astype(np.float32))
        return lambda: ops.multitask_decision(xt, sv, coef, gamma=0.5,
                                              compute_dtype=dtype, **cfg)
    raise ValueError(f"unknown tunable kernel {kernel!r}")


# ------------------------------------------------------------- hillclimb
@dataclasses.dataclass
class Evaluation:
    config: dict[str, int]
    roofline_s: float
    wall_s: Optional[float]
    score: tuple


@dataclasses.dataclass
class TuneResult:
    kernel: str
    shape: tuple[int, ...]
    dtype: str
    objective: str
    best: Evaluation
    default: Evaluation
    trace: list[Evaluation]

    @property
    def config(self) -> dict[str, int]:
        return self.best.config


def _resolve_objective(objective: str) -> str:
    if objective != "auto":
        return objective
    import jax
    return "wall" if jax.default_backend() == "tpu" else "combined"


def _score(objective: str, roofline_s: float,
           wall_s: Optional[float]) -> tuple:
    if objective == "wall":
        return (wall_s,)
    if objective == "roofline":
        return (roofline_s,)
    # combined: roofline leads (2 significant digits), wall breaks ties
    rounded = float(f"{roofline_s:.1e}") if roofline_s > 0 else 0.0
    return (rounded, wall_s if wall_s is not None else 0.0)


def _neighbours(cfg: dict[str, int], space: list[dict[str, int]]
                ) -> list[dict[str, int]]:
    """Single-axis x2 / /2 steps that land inside the candidate space."""
    out = []
    for axis, v in cfg.items():
        for nv in (v * 2, v // 2):
            cand = dict(cfg, **{axis: nv})
            if cand in space and cand not in out:
                out.append(cand)
    return out


def tune(kernel: str, shape: tuple[int, ...], *, dtype: str = "fp32",
         budget: int = 12, objective: str = "auto",
         warmup: int = 1, iters: int = 3) -> TuneResult:
    """Hillclimb the tile configuration for one (kernel, shape, dtype).

    Starts from the (shape-clipped) default, evaluates its single-axis
    x2 / /2 neighbours, moves to the strict best, and repeats until no
    neighbour improves or ``budget`` configurations have been evaluated.
    The default is always evaluated first, so ``result.best`` is never
    worse than the default under the chosen objective.
    """
    obj = _resolve_objective(objective)
    space = candidates(kernel, shape, dtype)
    measure_wall = obj in ("wall", "combined")

    evaluated: dict[tuple, Evaluation] = {}

    def key(cfg):
        return tuple(sorted(cfg.items()))

    def evaluate(cfg) -> Evaluation:
        k = key(cfg)
        if k in evaluated:
            return evaluated[k]
        roofline_s = roofline_estimate(kernel, shape, dtype,
                                       cfg)["t_total_est_s"]
        wall = (_timeit(_bench_closure(kernel, shape, dtype, cfg),
                        warmup=warmup, iters=iters)
                if measure_wall else None)
        ev = Evaluation(config=dict(cfg), roofline_s=roofline_s,
                        wall_s=wall, score=_score(obj, roofline_s, wall))
        evaluated[k] = ev
        return ev

    start = clip_to_candidates(kernel, DEFAULTS[kernel], shape)
    default_ev = evaluate(start)
    best = default_ev
    while len(evaluated) < budget:
        moved = False
        for cand in _neighbours(best.config, space):
            if len(evaluated) >= budget:
                break
            ev = evaluate(cand)
            if ev.score < best.score:
                best = ev
                moved = True
        if not moved:
            break
    return TuneResult(kernel=kernel, shape=tuple(shape), dtype=dtype,
                      objective=obj, best=best, default=default_ev,
                      trace=list(evaluated.values()))


# ----------------------------------------------------------- disk cache
def default_cache_path() -> str:
    env = os.environ.get(_ENV_CACHE)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


class TuningCache:
    """Versioned on-disk tuning cache.

    JSON schema (version 1)::

        {"version": 1,
         "entries": {"<device>|<kernel>|<dtype>|<bucket>": {
             "config": {"block_n": 256, ...},
             "objective": "wall", "wall_s": ..., "roofline_s": ...,
             "n_evaluated": 7}}}

    ``load`` NEVER raises on a bad file: a missing, unreadable,
    corrupted, or version-mismatched cache yields an empty cache, which
    makes every lookup fall back to the hardcoded defaults.
    """

    def __init__(self, entries: Optional[dict] = None):
        self.entries: dict[str, dict] = dict(entries or {})

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        try:
            with open(path) as f:
                raw = json.load(f)
            if not isinstance(raw, dict) or raw.get("version") != CACHE_VERSION:
                return cls()
            entries = raw.get("entries")
            if not isinstance(entries, dict):
                return cls()
            good = {k: v for k, v in entries.items()
                    if isinstance(v, dict)
                    and isinstance(v.get("config"), dict)}
            return cls(good)
        except (OSError, ValueError):
            return cls()

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": self.entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    def get(self, key: str) -> Optional[dict]:
        rec = self.entries.get(key)
        return dict(rec["config"]) if rec else None

    def put(self, key: str, result: TuneResult) -> None:
        self.entries[key] = {
            "config": dict(result.best.config),
            "objective": result.objective,
            "wall_s": result.best.wall_s,
            "roofline_s": result.best.roofline_s,
            "default_wall_s": result.default.wall_s,
            "default_roofline_s": result.default.roofline_s,
            "n_evaluated": len(result.trace),
        }


# ---------------------------------------------------- runtime fast path
_runtime_cache: Optional[TuningCache] = None
_runtime_path: Optional[str] = None


def reset() -> None:
    """Drop the loaded in-process cache so the next lookup reloads from
    disk (tests; or after an external tune run). A path pinned with
    ``set_cache_path`` stays pinned."""
    global _runtime_cache
    _runtime_cache = None


def set_cache_path(path: Optional[str]) -> None:
    """Pin the runtime cache to ``path`` (``None`` -> back to default
    resolution) and reload lazily on next lookup."""
    global _runtime_path
    reset()
    _runtime_path = path


def _runtime(path: Optional[str] = None) -> TuningCache:
    global _runtime_cache
    if _runtime_cache is None:
        p = path or _runtime_path or default_cache_path()
        _runtime_cache = TuningCache.load(p)
    return _runtime_cache


def lookup(kernel: str, shape: tuple[int, ...],
           dtype: str = "fp32") -> Optional[dict[str, int]]:
    """Tuned config for this (device, kernel, dtype, shape bucket) or
    ``None`` when untuned (callers then use ``DEFAULTS``). Total
    fallback safety: any error here means "no tuned config"."""
    try:
        cache = _runtime()
        if not cache.entries:
            return None
        return cache.get(cache_key(device_kind(), kernel, dtype, shape))
    except Exception:
        return None


def resolve_blocks(kernel: str, shape: tuple[int, ...], dtype: str,
                   given: dict[str, Optional[int]]) -> dict[str, int]:
    """Merge caller-specified block sizes over tuned-or-default values:
    explicit args always win; ``None`` slots fill from the tuning cache
    when this bucket was tuned, else from ``DEFAULTS``."""
    tuned = (lookup(kernel, shape, dtype)
             if any(v is None for v in given.values()) else None)
    base = DEFAULTS[kernel]
    out = {}
    for k, v in given.items():
        if v is not None:
            out[k] = int(v)
        elif tuned and k in tuned:
            out[k] = int(tuned[k])
        else:
            out[k] = base[k]
    return out
