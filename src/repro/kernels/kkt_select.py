"""Fused KKT working-set selection Pallas kernel.

The paper's CUDA SMO does working-set selection with a block-level
min/argmin + max/argmax reduction over all n samples. TPU adaptation:
the sample axis is tiled into VMEM rows of shape (1, block); each grid
step computes the KKT up/low masks IN-REGISTER (fusing what would be 4
separate masked elementwise passes) and reduces its tile to a partial
(value, index) pair; ``ops.kkt_select`` finishes the tiny cross-tile
reduction in jnp.

Outputs per tile t:
  up_val[t]  = min_{i in tile & I_up}  f_i     (+inf if empty)
  up_idx[t]  = argmin index (global)
  low_val[t] = max_{i in tile & I_low} f_i     (-inf if empty)
  low_idx[t] = argmax index (global)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rbf_gram import check_block_divisibility


def _kkt_kernel(f_ref, alpha_ref, y_ref, mask_ref,
                upv_ref, upi_ref, lowv_ref, lowi_ref, *,
                c: float, block: int):
    t = pl.program_id(0)
    f = f_ref[...]                      # (1, block) f32
    alpha = alpha_ref[...]
    y = y_ref[...]
    mask = mask_ref[...] != 0

    eps = 1e-6 * c
    pos = y > 0
    neg = jnp.logical_not(pos)
    not_upper = alpha < c - eps
    not_lower = alpha > eps
    up_mask = mask & ((pos & not_upper) | (neg & not_lower))
    low_mask = mask & ((pos & not_lower) | (neg & not_upper))

    f_up = jnp.where(up_mask, f, jnp.inf)
    f_low = jnp.where(low_mask, f, -jnp.inf)

    iota = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    j_up = jnp.argmin(f_up, axis=1)[0]
    j_low = jnp.argmax(f_low, axis=1)[0]
    upv_ref[0, 0] = f_up[0, j_up]
    upi_ref[0, 0] = t * block + j_up.astype(jnp.int32)
    lowv_ref[0, 0] = f_low[0, j_low]
    lowi_ref[0, 0] = t * block + j_low.astype(jnp.int32)


def kkt_select_pallas(f: jax.Array, alpha: jax.Array, y: jax.Array,
                      mask: jax.Array, *, c: float, block: int = 1024,
                      interpret: bool = True):
    """Per-tile partial reductions. n must be a multiple of ``block``.

    Returns (up_val, up_idx, low_val, low_idx), each (n_tiles,).
    """
    n = f.shape[0]
    check_block_divisibility("kkt_select_pallas", n=(n, block))
    n_tiles = n // block
    row = lambda v, dt: v.reshape(1, n).astype(dt)
    kernel = functools.partial(_kkt_kernel, c=c, block=block)
    spec1 = pl.BlockSpec((1, block), lambda t: (0, t))
    outspec = pl.BlockSpec((1, 1), lambda t: (0, t))
    shape = jax.ShapeDtypeStruct((1, n_tiles), jnp.float32)
    ishape = jax.ShapeDtypeStruct((1, n_tiles), jnp.int32)
    upv, upi, lowv, lowi = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[spec1, spec1, spec1, spec1],
        out_specs=(outspec, outspec, outspec, outspec),
        out_shape=(shape, ishape, shape, ishape),
        interpret=interpret,
    )(row(f, jnp.float32), row(alpha, jnp.float32), row(y, jnp.float32),
      row(mask, jnp.int32))
    return upv[0], upi[0], lowv[0], lowi[0]
