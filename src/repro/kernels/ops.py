"""Public, padding-aware jit wrappers around the Pallas kernels.

These are the entry points the rest of the framework uses. They
(1) resolve tile/block sizes — explicit arguments win, otherwise the
``kernels.autotune`` on-disk tuning cache is consulted for this
(device, kernel, dtype, shape bucket) and the hardcoded defaults are
the fallback; (2) pad every axis up to the kernel's block multiples
(MXU/VMEM alignment); (3) dispatch the pallas_call; (4) slice the
padding back off. ``interpret`` defaults to auto: True off-TPU (this
container), False on real TPU hardware.

Mixed precision: the Gram-shaped kernels take ``compute_dtype``
("fp32" | "bf16"). Under "bf16" the operand tiles are cast to bfloat16
AFTER padding (zeros stay zero), halving the HBM tile traffic, while
the MXU accumulates in f32 and the RBF epilogue (norms, exp) runs in
f32 — the engine-level flag ``EngineConfig.gram_dtype`` threads through
here.

Padding correctness notes:
* Gram: padded FEATURE columns are zero in both operands -> contribute 0
  to the dot and to the squared norms; padded SAMPLE rows produce extra
  rows/cols that are sliced off.
* decision: padded train rows carry coef = 0 -> contribute 0.
* kkt_select: padded entries get mask = False -> +-inf sentinels.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import decision as _decision
from repro.kernels import kkt_select as _kkt
from repro.kernels import rbf_gram as _gram

COMPUTE_DTYPES = ("fp32", "bf16")


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _check_compute_dtype(compute_dtype: str) -> None:
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"unknown compute_dtype {compute_dtype!r}; "
                         f"expected one of {COMPUTE_DTYPES}")


def _tile_cast(x: jax.Array, compute_dtype: str) -> jax.Array:
    """Cast padded operand tiles for the kernel (bf16 tile loads, f32
    accumulation happens inside the kernels)."""
    return x.astype(jnp.bfloat16) if compute_dtype == "bf16" else x


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


# --------------------------------------------------------------- rbf_gram
@partial(jax.jit, static_argnames=("gamma", "mode", "block_n", "block_m",
                                   "block_d", "compute_dtype", "interpret"))
def _rbf_gram_padded(a, b, *, gamma, mode, block_n, block_m, block_d,
                     compute_dtype, interpret):
    n, m = a.shape[0], b.shape[0]
    a = _pad_to(_pad_to(a.astype(jnp.float32), 1, block_d), 0, block_n)
    b = _pad_to(_pad_to(b.astype(jnp.float32), 1, block_d), 0, block_m)
    a = _tile_cast(a, compute_dtype)
    b = _tile_cast(b, compute_dtype)
    out = _gram.rbf_gram_pallas(a, b, gamma=gamma, mode=mode,
                                block_n=block_n, block_m=block_m,
                                block_d=block_d, interpret=interpret)
    return out[:n, :m]


def rbf_gram(a: jax.Array, b: jax.Array, *, gamma: float = 1.0,
             mode: str = "rbf", block_n: int | None = None,
             block_m: int | None = None, block_d: int | None = None,
             compute_dtype: str = "fp32",
             interpret: bool | None = None) -> jax.Array:
    """K(a, b): (n, m) float32 Gram matrix (rbf or linear). Block sizes
    left as ``None`` resolve through the autotune cache."""
    _check_compute_dtype(compute_dtype)
    if interpret is None:
        interpret = _auto_interpret()
    blocks = autotune.resolve_blocks(
        "rbf_gram", (a.shape[0], b.shape[0], a.shape[1]), compute_dtype,
        {"block_n": block_n, "block_m": block_m, "block_d": block_d})
    return _rbf_gram_padded(a, b, gamma=gamma, mode=mode,
                            compute_dtype=compute_dtype,
                            interpret=interpret, **blocks)


# ----------------------------------------------------------- rff_features
@partial(jax.jit, static_argnames=("scale", "block_n", "block_m",
                                   "block_d", "compute_dtype", "interpret"))
def _rff_features_padded(x, omega, phase, *, scale, block_n, block_m,
                         block_d, compute_dtype, interpret):
    from repro.kernels import feature_map as _fmap
    n, k = x.shape[0], omega.shape[1]
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 1, block_d), 0, block_n)
    wp = _pad_to(_pad_to(omega.astype(jnp.float32), 0, block_d), 1, block_m)
    # padded frequency columns see omega = phase = 0 -> cos(0) = scale;
    # sliced off below. Padded d rows/cols are zero on both operands.
    php = _pad_to(phase.astype(jnp.float32)[None, :], 1, block_m)
    xp = _tile_cast(xp, compute_dtype)
    wp = _tile_cast(wp, compute_dtype)
    out = _fmap.rff_features_pallas(xp, wp, php, scale=scale,
                                    block_n=block_n, block_m=block_m,
                                    block_d=block_d, interpret=interpret)
    return out[:n, :k]


def rff_features(x: jax.Array, omega: jax.Array, phase: jax.Array, *,
                 scale: float, block_n: int | None = None,
                 block_m: int | None = None, block_d: int | None = None,
                 compute_dtype: str = "fp32",
                 interpret: bool | None = None) -> jax.Array:
    """Fused RFF transform ``scale * cos(x @ omega + phase)``: (n, k)
    float32 feature block (``repro.core.approx.RFFMap``'s TPU path).
    Block sizes left as ``None`` resolve through the autotune cache."""
    _check_compute_dtype(compute_dtype)
    if interpret is None:
        interpret = _auto_interpret()
    blocks = autotune.resolve_blocks(
        "rff_features", (x.shape[0], omega.shape[1], x.shape[1]),
        compute_dtype,
        {"block_n": block_n, "block_m": block_m, "block_d": block_d})
    return _rff_features_padded(x, omega, phase, scale=float(scale),
                                compute_dtype=compute_dtype,
                                interpret=interpret, **blocks)


# ------------------------------------------------------------- kkt_select
@partial(jax.jit, static_argnames=("c", "block", "interpret"))
def _kkt_select_padded(f, alpha, y, mask, *, c, block, interpret):
    fp = _pad_to(f.astype(jnp.float32), 0, block)
    ap = _pad_to(alpha.astype(jnp.float32), 0, block)
    # padded y = +1 with alpha = 0 would look movable; mask handles it
    yp = _pad_to(y.astype(jnp.float32), 0, block)
    mp = _pad_to(mask.astype(jnp.int32), 0, block)
    upv, upi, lowv, lowi = _kkt.kkt_select_pallas(fp, ap, yp, mp, c=c,
                                                  block=block,
                                                  interpret=interpret)
    t_up = jnp.argmin(upv)
    t_low = jnp.argmax(lowv)
    return upv[t_up], upi[t_up], lowv[t_low], lowi[t_low]


def kkt_select(f: jax.Array, alpha: jax.Array, y: jax.Array,
               mask: jax.Array, *, c: float = 1.0,
               block: int | None = None,
               interpret: bool | None = None):
    """Fused masked KKT selection: (b_up, i_up, b_low, i_low)."""
    if interpret is None:
        interpret = _auto_interpret()
    n = f.shape[0]
    block = autotune.resolve_blocks("kkt_select", (n,), "fp32",
                                    {"block": block})["block"]
    block = min(block, max(128, 1 << (n - 1).bit_length()))
    return _kkt_select_padded(f, alpha, y, mask, c=c, block=block,
                              interpret=interpret)


# --------------------------------------------------------------- decision
@partial(jax.jit, static_argnames=("gamma", "block_t", "block_n",
                                   "compute_dtype", "interpret"))
def _decision_padded(x_test, x_train, coef, b, *, gamma, block_t, block_n,
                     compute_dtype, interpret):
    nt = x_test.shape[0]
    d_mult = 128
    xt = _pad_to(_pad_to(x_test.astype(jnp.float32), 1, d_mult), 0, block_t)
    xr = _pad_to(_pad_to(x_train.astype(jnp.float32), 1, d_mult), 0, block_n)
    cf = _pad_to(coef.astype(jnp.float32), 0, block_n)
    xt = _tile_cast(xt, compute_dtype)
    xr = _tile_cast(xr, compute_dtype)
    out = _decision.decision_pallas(xt, xr, cf, gamma=gamma,
                                    block_t=block_t, block_n=block_n,
                                    interpret=interpret)
    return out[:nt] + b


def decision(x_test: jax.Array, x_train: jax.Array, coef: jax.Array,
             b: jax.Array | float = 0.0, *, gamma: float = 1.0,
             block_t: int | None = None, block_n: int | None = None,
             compute_dtype: str = "fp32",
             interpret: bool | None = None) -> jax.Array:
    """f(z) = K(z, X) @ coef + b for a batch of test rows."""
    _check_compute_dtype(compute_dtype)
    if interpret is None:
        interpret = _auto_interpret()
    blocks = autotune.resolve_blocks(
        "decision", (x_test.shape[0], x_train.shape[0], x_test.shape[1]),
        compute_dtype, {"block_t": block_t, "block_n": block_n})
    return _decision_padded(x_test, x_train, coef, b, gamma=gamma,
                            compute_dtype=compute_dtype,
                            interpret=interpret, **blocks)


# ----------------------------------------------------- multitask_decision
@partial(jax.jit, static_argnames=("gamma", "mode", "block_t", "block_n",
                                   "compute_dtype", "interpret"))
def _multitask_decision_padded(x_test, sv_x, coef, b, *, gamma, mode,
                               block_t, block_n, compute_dtype, interpret):
    nt = x_test.shape[0]
    d_mult = 128
    xt = _pad_to(_pad_to(x_test.astype(jnp.float32), 1, d_mult), 0, block_t)
    sv = _pad_to(_pad_to(sv_x.astype(jnp.float32), 2, d_mult), 1, block_n)
    cf = _pad_to(coef.astype(jnp.float32), 1, block_n)
    xt = _tile_cast(xt, compute_dtype)
    sv = _tile_cast(sv, compute_dtype)
    out = _decision.multitask_decision_pallas(
        xt, sv, cf, gamma=gamma, mode=mode, block_t=block_t,
        block_n=block_n, interpret=interpret)[:, :nt]
    return out if b is None else out + b[:, None].astype(jnp.float32)


def multitask_decision(x_test: jax.Array, sv_x: jax.Array, coef: jax.Array,
                       b: jax.Array | None = None, *, gamma: float = 1.0,
                       mode: str = "rbf", block_t: int | None = None,
                       block_n: int | None = None,
                       compute_dtype: str = "fp32",
                       interpret: bool | None = None) -> jax.Array:
    """f_t(z) = K(z, SV_t) @ coef_t + b_t for a stacked (T, w, d) SV bank.

    One fused grid over every task of a serving bucket (the batched
    inference hot spot); padded SV rows carry coef = 0 and padded test
    rows are sliced off, exactly like ``decision``. A width-0 bank (the
    empty-SV degenerate model) short-circuits to the broadcast bias.
    """
    if mode not in ("rbf", "linear"):
        raise ValueError(f"unknown multitask decision mode {mode!r}; "
                         "expected 'rbf' or 'linear'")
    _check_compute_dtype(compute_dtype)
    if interpret is None:
        interpret = _auto_interpret()
    nt = x_test.shape[0]
    n_tasks, w, _ = sv_x.shape
    if w == 0:  # no support vectors anywhere: constant-bias predictor
        out = jnp.zeros((n_tasks, nt), jnp.float32)
        return out if b is None else out + b[:, None].astype(jnp.float32)
    blocks = autotune.resolve_blocks(
        "multitask_decision", (n_tasks, nt, w, x_test.shape[1]),
        compute_dtype, {"block_t": block_t, "block_n": block_n})
    return _multitask_decision_padded(x_test, sv_x, coef, b, gamma=gamma,
                                      mode=mode,
                                      compute_dtype=compute_dtype,
                                      interpret=interpret, **blocks)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    """Flash attention over (B, S, H, D) tensors with GQA broadcast.

    Pads S to tile multiples (padded KV masked out via causality for
    causal=True; for the padded q rows the outputs are sliced off)."""
    from repro.kernels import flash_attn as _fa
    if interpret is None:
        interpret = _auto_interpret()
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    if hkv != h:  # GQA: broadcast kv heads to q heads
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    bq = min(block_q, max(128, sq))
    bk = min(block_k, max(128, k.shape[1]))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    qf = qp.transpose(0, 2, 1, 3).reshape(b * h, qp.shape[1], d)
    kf = kp.transpose(0, 2, 1, 3).reshape(b * h, kp.shape[1], d)
    vf = vp.transpose(0, 2, 1, 3).reshape(b * h, vp.shape[1],
                                          vp.shape[3])
    out = _fa.flash_attention_pallas(qf, kf, vf, causal=causal,
                                     block_q=bq, block_k=bk,
                                     interpret=interpret,
                                     kv_len=k.shape[1])
    out = out.reshape(b, h, qp.shape[1], vp.shape[3]).transpose(0, 2, 1, 3)
    return out[:, :sq]


def gram_row_fn(*, gamma: float, block: int | None = None,
                mode: str = "rbf", compute_dtype: str = "fp32",
                interpret: bool | None = None):
    """``(X, z) -> K(X, z)`` single-row closure for the SMO f-cache update
    (the on-the-fly, O(n d)-memory mode used by the chunked/Pallas
    ``KernelEngine`` backends; ``mode``/``compute_dtype`` mirror
    ``rbf_gram``)."""
    def row(x, z):
        return rbf_gram(x, z[None, :], gamma=gamma, mode=mode,
                        block_n=block, block_m=128,
                        compute_dtype=compute_dtype,
                        interpret=interpret)[:, 0]
    return row
