"""Flash attention Pallas kernel (TPU target, beyond-paper optimization).

The roofline analysis (EXPERIMENTS.md §Roofline) shows every train/
prefill shape is MEMORY-dominated, and ~90% of the per-layer HBM traffic
is the materialized (S x S) attention score tensors. This kernel
computes online-softmax attention entirely in VMEM tiles:

  grid (batch*heads, Sq/bq, Sk/bk):  per (q-tile, kv-step), VMEM holds
  q (bq, d), k/v (bk, d), running (m, l, acc) scratch. HBM traffic
  collapses to Q+K+V+O (+ tiny stats) — the memory roofline term for the
  attention block drops by ~S/bk per layer.

  The kv axis is the innermost sequential grid dimension; (m, l, acc)
  live in VMEM scratch carried across kv steps; the finished tile is
  normalized and written once on the last step.

Causal masking is done per-tile with global position iota; fully-masked
tiles still execute (grid is static) but contribute nothing.

VMEM per step (defaults bq=bk=256, d<=256, f32):
  q/k/v/acc 4 x 256 x 256 x 4B = 1 MiB + stats — comfortably under the
  ~16 MiB budget with double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rbf_gram import check_block_divisibility

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k_steps: int, kv_len: int):
    kv_step = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_step == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = kv_step * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    if causal:
        qpos = q_idx * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
    if kv_len % block_k:  # padded tail keys must not attend
        s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_scr[...]                          # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                       # (bq, bk)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(kv_step == n_k_steps - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = True, kv_len: int = 0):
    """q (BH, Sq, d), k/v (BH, Sk, d) -> (BH, Sq, d).

    Batch and heads pre-flattened (GQA head-broadcast handled by the
    ops.py wrapper). Sq % block_q == 0, Sk % block_k == 0 required.
    ``kv_len``: number of REAL keys (≤ Sk); the padded tail is masked.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    dv = v.shape[2]
    check_block_divisibility("flash_attention_pallas", sq=(sq, block_q),
                             sk=(sk, block_k))
    grid = (bh, sq // block_q, sk // block_k)
    scale = d ** -0.5
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_k_steps=grid[2], kv_len=kv_len or sk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
