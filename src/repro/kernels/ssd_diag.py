"""SSD intra-chunk Pallas kernel (mamba2/zamba2 hot spot).

The roofline table shows SSM train/prefill shapes are memory-dominated,
and the biggest single producer is the intra-chunk stage of the SSD
algorithm: the (Q x Q) decay matrix L = exp(cs_i - cs_j) and the masked
quadratic form

    Y_diag[q, p] = sum_{k<=q} (C_q . B_k) * L[q, k] * dt_k * x[k, p]

materialized per (batch, chunk, head) in f32 HBM by the XLA path
(`repro.models.mamba2.ssd_chunked`). This kernel computes the whole
stage per grid cell inside VMEM:

  grid (B*NC, H): per step, VMEM holds C,B (Q, N), x (Q, P), dt/cs (Q,)
  and the (Q, Q) intermediates live only in registers/VMEM — HBM traffic
  collapses to the O(Q*(N+P)) inputs + O(Q*P) output.

VMEM per step (Q=256, N=128, P=64, f32): C+B 256 KiB, x/y 128 KiB,
scores/L 512 KiB — well under budget. MXU does both (Q,N)x(N,Q) and
(Q,Q)x(Q,P) matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_diag_kernel(c_ref, b_ref, x_ref, dt_ref, cs_ref, o_ref):
    c = c_ref[0].astype(jnp.float32)          # (Q, N)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q,)
    cs = cs_ref[0, 0].astype(jnp.float32)     # (Q,)

    q = c.shape[0]
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    seg = cs[:, None] - cs[None, :]           # (Q, Q)
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(col <= row, jnp.exp(seg), 0.0)
    w = scores * l_mat * dt[None, :]
    o_ref[0, 0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def ssd_diag_pallas(cmat, bmat, x, dt, cs, *, interpret: bool = True):
    """Intra-chunk SSD contribution.

    cmat/bmat (BC, Q, N)  — chunk C/B projections (group-shared, G=1)
    x         (BC, H, Q, P)
    dt        (BC, H, Q)  — softplus'd step sizes
    cs        (BC, H, Q)  — inclusive cumsum of dt*A within the chunk
    Returns   (BC, H, Q, P) f32.
    """
    bc, q, n = cmat.shape
    h, p = x.shape[1], x.shape[3]
    grid = (bc, h)
    return pl.pallas_call(
        _ssd_diag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, h, q, p), jnp.float32),
        interpret=interpret,
    )(cmat, bmat, x, dt, cs)
