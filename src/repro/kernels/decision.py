"""Batched SVM decision-function Pallas kernels (inference hot spot).

f(z) = sum_i coef_i K(x_i, z) + b  for a batch of test rows z, fusing the
RBF Gram block with the contraction against coef = alpha*y so the (nt, n)
kernel matrix never materializes in HBM:

  grid (nt/bt, n/bn):  per step, VMEM holds the test tile (bt, d), the
  train tile (bn, d) and coef tile (1, bn); computes the RBF block on the
  MXU, contracts it with coef, and accumulates into the (bt, 1) output
  column. The train axis (reduction) is the innermost sequential grid
  dimension; features stay resident per-tile (SVM d is small — 4..102 —
  so one d-chunk suffices; ops.py pads d to the 128 lane width).

``multitask_decision_pallas`` is the serving-side generalization: a
stacked bank of T binary tasks (T, w, d) — one serving bucket of the
packed model artifact — evaluated against ONE test batch in a single
grid (T, nt/bt, w/bn). The task axis is the outermost grid dimension, so
per task the (i, k) iteration order — and therefore the f32 accumulation
order — is exactly the single-task kernel's, and the test tile is reused
across all T tasks instead of re-streaming per task.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.rbf_gram import (_COMPUTE_DTYPES,
                                    check_block_divisibility)


def _decision_kernel(xt_ref, xr_ref, coef_ref, out_ref, *,
                     gamma: float, n_steps: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xt = xt_ref[...]                          # (bt, d) f32 or bf16
    xr = xr_ref[...]                          # (bn, d)
    coef = coef_ref[...].astype(jnp.float32)  # (1, bn)

    # dot runs at the tile dtype (bf16 tiles feed the MXU natively) with
    # f32 accumulation; norms use f32 of the SAME rounded values so the
    # zero-distance diagonal stays exact under mixed precision
    dot = jax.lax.dot_general(xt, xr, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    xtf = xt.astype(jnp.float32)
    xrf = xr.astype(jnp.float32)
    t2 = jnp.sum(xtf * xtf, axis=1, keepdims=True)     # (bt, 1)
    r2 = jnp.sum(xrf * xrf, axis=1, keepdims=True).T   # (1, bn)
    kblock = jnp.exp(-gamma * jnp.maximum(t2 + r2 - 2.0 * dot, 0.0))
    out_ref[...] += jnp.sum(kblock * coef, axis=1, keepdims=True)


def decision_pallas(x_test: jax.Array, x_train: jax.Array, coef: jax.Array,
                    *, gamma: float, block_t: int = 128, block_n: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Returns (nt,) decision values WITHOUT bias (add b outside).

    Shapes must be pre-padded: nt % block_t == 0, n % block_n == 0;
    padded train rows must carry coef == 0.
    """
    nt, d = x_test.shape
    n, d2 = x_train.shape
    if d != d2:
        raise ValueError(f"decision_pallas: feature dims differ "
                         f"({d} vs {d2})")
    check_block_divisibility("decision_pallas", nt=(nt, block_t),
                             n=(n, block_n))
    if x_test.dtype not in _COMPUTE_DTYPES:
        x_test = x_test.astype(jnp.float32)
    if x_train.dtype not in _COMPUTE_DTYPES:
        x_train = x_train.astype(jnp.float32)
    grid = (nt // block_t, n // block_n)
    kernel = functools.partial(_decision_kernel, gamma=gamma,
                               n_steps=grid[1])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, k: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, k: (k, 0)),
            pl.BlockSpec((1, block_n), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((block_t, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, 1), jnp.float32),
        interpret=interpret,
    )(x_test, x_train, coef.reshape(1, n))
    return out[:, 0]


def _multitask_kernel(xt_ref, sv_ref, coef_ref, out_ref, *,
                      gamma: float, mode: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xt = xt_ref[...]                              # (bt, d) f32 or bf16
    sv = sv_ref[...][0]                           # (bn, d) task-t SV tile
    coef = coef_ref[...].astype(jnp.float32)      # (1, bn)

    dot = jax.lax.dot_general(xt, sv, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if mode == "rbf":
        xtf = xt.astype(jnp.float32)
        svf = sv.astype(jnp.float32)
        t2 = jnp.sum(xtf * xtf, axis=1, keepdims=True)     # (bt, 1)
        r2 = jnp.sum(svf * svf, axis=1, keepdims=True).T   # (1, bn)
        kblock = jnp.exp(-gamma * jnp.maximum(t2 + r2 - 2.0 * dot, 0.0))
    else:                                         # linear
        kblock = dot
    out_ref[...] += jnp.sum(kblock * coef, axis=1, keepdims=True).T


def multitask_decision_pallas(x_test: jax.Array, sv_x: jax.Array,
                              coef: jax.Array, *, gamma: float,
                              mode: str = "rbf", block_t: int = 128,
                              block_n: int = 128,
                              interpret: bool = True) -> jax.Array:
    """(T, nt) stacked decision values WITHOUT bias (add b outside).

    ``sv_x`` is a (T, w, d) serving bucket: T binary tasks padded to a
    common SV width w. Shapes must be pre-padded: nt % block_t == 0,
    w % block_n == 0; padded SV rows must carry coef == 0 (zero-padded
    test rows are sliced off by the caller).
    """
    nt, d = x_test.shape
    n_tasks, w, d2 = sv_x.shape
    if d != d2:
        raise ValueError(f"multitask_decision_pallas: feature dims "
                         f"differ ({d} vs {d2})")
    check_block_divisibility("multitask_decision_pallas",
                             nt=(nt, block_t), w=(w, block_n))
    if coef.shape != (n_tasks, w):
        raise ValueError(f"multitask_decision_pallas: coef shape "
                         f"{coef.shape} != bank shape {(n_tasks, w)}")
    if x_test.dtype not in _COMPUTE_DTYPES:
        x_test = x_test.astype(jnp.float32)
    if sv_x.dtype not in _COMPUTE_DTYPES:
        sv_x = sv_x.astype(jnp.float32)
    grid = (n_tasks, nt // block_t, w // block_n)
    kernel = functools.partial(_multitask_kernel, gamma=gamma, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda t, i, k: (i, 0)),
            pl.BlockSpec((1, block_n, d), lambda t, i, k: (t, k, 0)),
            pl.BlockSpec((1, block_n), lambda t, i, k: (t, k)),
        ],
        out_specs=pl.BlockSpec((1, block_t), lambda t, i, k: (t, i)),
        out_shape=jax.ShapeDtypeStruct((n_tasks, nt), jnp.float32),
        interpret=interpret,
    )(x_test, sv_x, coef)
