"""Batched SVM decision-function Pallas kernel (inference hot spot).

f(z) = sum_i coef_i K(x_i, z) + b  for a batch of test rows z, fusing the
RBF Gram block with the contraction against coef = alpha*y so the (nt, n)
kernel matrix never materializes in HBM:

  grid (nt/bt, n/bn):  per step, VMEM holds the test tile (bt, d), the
  train tile (bn, d) and coef tile (1, bn); computes the RBF block on the
  MXU, contracts it with coef, and accumulates into the (bt, 1) output
  column. The train axis (reduction) is the innermost sequential grid
  dimension; features stay resident per-tile (SVM d is small — 4..102 —
  so one d-chunk suffices; ops.py pads d to the 128 lane width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decision_kernel(xt_ref, xr_ref, coef_ref, out_ref, *,
                     gamma: float, n_steps: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xt = xt_ref[...].astype(jnp.float32)     # (bt, d)
    xr = xr_ref[...].astype(jnp.float32)     # (bn, d)
    coef = coef_ref[...].astype(jnp.float32)  # (1, bn)

    dot = jax.lax.dot_general(xt, xr, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    t2 = jnp.sum(xt * xt, axis=1, keepdims=True)       # (bt, 1)
    r2 = jnp.sum(xr * xr, axis=1, keepdims=True).T     # (1, bn)
    kblock = jnp.exp(-gamma * jnp.maximum(t2 + r2 - 2.0 * dot, 0.0))
    out_ref[...] += jnp.sum(kblock * coef, axis=1, keepdims=True)


def decision_pallas(x_test: jax.Array, x_train: jax.Array, coef: jax.Array,
                    *, gamma: float, block_t: int = 128, block_n: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Returns (nt,) decision values WITHOUT bias (add b outside).

    Shapes must be pre-padded: nt % block_t == 0, n % block_n == 0;
    padded train rows must carry coef == 0.
    """
    nt, d = x_test.shape
    n, d2 = x_train.shape
    assert d == d2 and nt % block_t == 0 and n % block_n == 0
    grid = (nt // block_t, n // block_n)
    kernel = functools.partial(_decision_kernel, gamma=gamma,
                               n_steps=grid[1])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, k: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, k: (k, 0)),
            pl.BlockSpec((1, block_n), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((block_t, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, 1), jnp.float32),
        interpret=interpret,
    )(x_test, x_train, coef.reshape(1, n))
    return out[:, 0]
