"""Production mesh construction (TPU v5e pods).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  512 chips as (pod=2, data=16, model=16) — the "pod" axis is
the slowest (DCN/ICI-sparse) dimension and only ever carries
data-parallel traffic (gradient all-reduce), matching how real multi-pod
slices are scheduled.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_workers: int = 1, axis: str = "workers"):
    """Small mesh over however many (possibly forced-host) devices exist —
    used by tests and the SVM distributed examples."""
    return jax.make_mesh((n_workers,), (axis,))


def make_shard_mesh(n_shards: int | None = None, axis: str = "shards"):
    """1-D mesh for the data-parallel single-problem SVM path
    (``smo.sharded_binary_smo`` / ``SVC(shard="data")``): the named axis
    carries the SAMPLE dimension of one QP, not independent tasks.

    ``n_shards=None`` takes every visible device. An explicit count above
    the visible device count raises instead of silently under-sharding.
    """
    n_avail = len(jax.devices())
    if n_shards is None:
        n_shards = n_avail
    if n_shards > n_avail:
        raise ValueError(
            f"requested {n_shards} shards but only {n_avail} devices are "
            f"visible (force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N before "
            f"jax initializes)")
    return jax.make_mesh((n_shards,), (axis,))


def set_mesh(mesh):
    """Version-compat ``jax.set_mesh``: jax >= 0.6 has the top-level
    context manager; on 0.4/0.5 the Mesh object itself is the context
    manager that installs the physical mesh."""
    sm = getattr(jax, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    return mesh
