"""End-to-end LM training driver (CPU-runnable at reduced scale).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2_780m \
        --reduced --steps 200 --batch 8 --seq 128

Builds the model (optionally the reduced smoke variant), a synthetic
token pipeline, AdamW with cosine schedule, runs the jitted train step,
logs loss, and checkpoints at the end. With ``--mesh dxm`` it builds a
local device mesh (forced host devices) and shards params/batch with the
production rules — the same code path the real pod launcher uses.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_780m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="",
                    help="e.g. 2x2 -> force 4 host devices (data,model)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override d_model (e.g. ~100M quickstart)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    if args.mesh:
        d, m = (int(v) for v in args.mesh.split("x"))
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={d * m} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import get_config, reduced as make_reduced
    from repro.data.lm import token_batches
    from repro.models.model import Model, abstract_init
    from repro.optim.adamw import AdamW, cosine_schedule
    from repro.sharding import rules
    from repro.training.train import make_train_step
    from repro.checkpoint import ckpt as CK

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
    import dataclasses
    if args.d_model:
        cfg = dataclasses.replace(cfg, d_model=args.d_model)
    if args.n_layers:
        cfg = dataclasses.replace(cfg, n_layers=args.n_layers)

    model = Model(cfg)
    params, logical = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,}")

    mesh = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        shardings = jax.tree.map(
            lambda lg: NamedSharding(mesh, rules.spec(lg, mesh)),
            logical, is_leaf=lambda x: isinstance(x, tuple))
        params = jax.tree.map(
            lambda p, s: jax.device_put(p, s)
            if all(p.shape[i] % (np.prod([mesh.shape[a] for a in
                   (ax if isinstance(ax, tuple) else (ax,))])
                   if ax else 1) == 0
                   for i, ax in enumerate(list(s.spec) + [None] * (
                       p.ndim - len(s.spec)))) else p,
            params, shardings)

    opt = AdamW(lr=cosine_schedule(peak_lr=args.lr, warmup=20,
                                   total=args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    it = token_batches(vocab_size=cfg.vocab_size, batch=args.batch,
                       seq_len=args.seq, n_batches=args.steps, seed=1)
    from repro.launch.mesh import set_mesh
    ctx = set_mesh(mesh) if mesh is not None else None
    if ctx:
        ctx.__enter__()
    try:
        for i, nb in enumerate(it):
            batch = {k: jnp.asarray(v) for k, v in nb.items()}
            if cfg.arch_type == "vlm":
                batch["vision_embeds"] = 0.02 * jnp.ones(
                    (args.batch, cfg.vision_tokens, cfg.d_model),
                    jnp.bfloat16)
            if cfg.arch_type == "audio":
                batch["frames"] = 0.02 * jnp.ones(
                    (args.batch, cfg.encoder_frames, cfg.d_model),
                    jnp.bfloat16)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                print(f"step {i:5d} loss {losses[-1]:.4f} "
                      f"({dt / (i + 1):.3f}s/step)", flush=True)
    finally:
        if ctx:
            ctx.__exit__(None, None, None)

    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"loss first5={first:.4f} last5={last:.4f} "
          f"improved={last < first}")
    if args.ckpt:
        CK.save(args.ckpt, params, step=args.steps)
        print(f"checkpoint -> {args.ckpt}")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
