"""Abstract input specs (ShapeDtypeStruct) per (architecture x shape).

The dry-run lowers against these — weak-type-correct, shardable, zero
allocation. ``input_specs(cfg, shape, mesh)`` returns (batch_specs,
batch_shardings); decode shapes additionally get cache specs from the
model itself.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.sharding import rules


def _dp_spec(mesh: Optional[Mesh]) -> P:
    if mesh is None:
        return P()
    return P(rules.dp_axes(mesh))


def batch_specs(cfg: ModelConfig, shape: InputShape,
                mesh: Optional[Mesh]) -> tuple[dict, dict]:
    """Training / prefill batch: tokens (+ modality stubs)."""
    b = shape.global_batch
    s = shape.seq_len
    dp = _dp_spec(mesh)
    text = s
    specs: dict = {}
    shard: dict = {}
    if cfg.arch_type == "vlm":
        text = s - cfg.vision_tokens      # total length stays seq_len
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        shard["vision_embeds"] = P(dp[0] if dp else None, None, None)
    if cfg.arch_type == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        shard["frames"] = P(dp[0] if dp else None, None, None)
    specs["tokens"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
    shard["tokens"] = P(dp[0] if dp else None, None)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
        shard["labels"] = P(dp[0] if dp else None, None)
    return specs, shard


def decode_token_specs(_cfg: ModelConfig, shape: InputShape,
                       mesh: Optional[Mesh]) -> tuple[Any, Any]:
    # _cfg: kept for call-signature symmetry with input_specs; decode
    # token shape is (batch,) regardless of architecture
    b = shape.global_batch
    dp = _dp_spec(mesh)
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    return tok, P(dp[0] if dp and b > 1 else None)


def abstract_params(model, key=None) -> tuple[Any, Any]:
    """(ShapeDtypeStruct params tree, logical spec tree) — no allocation."""
    import jax.random as jrandom
    key = jrandom.PRNGKey(0) if key is None else key
    shapes = jax.eval_shape(model.init, key)
    return shapes[0], jax.eval_shape(lambda: None) if False else shapes


def abstract_cache(model, batch: int, max_len: int) -> Any:
    return jax.eval_shape(lambda: model.cache_init(batch, max_len))
