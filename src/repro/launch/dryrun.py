import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import — jax locks the device count at first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3_12b \
        --shape train_4k [--multi-pod] [--out results.json]

    PYTHONPATH=src python -m repro.launch.dryrun --all

For each combo it builds the production mesh, abstract params/batch
(ShapeDtypeStruct — zero allocation), jits the train/prefill/decode step
with explicit in/out shardings, lowers, compiles, and records:

  * memory_analysis()      (per-device bytes: args/temp/output)
  * cost_analysis()        (per-device HLO FLOPs + bytes accessed)
  * collective bytes       (parsed from post-SPMD compiled HLO)

Results are appended as JSON lines for the roofline report.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_NAMES, INPUT_SHAPES, get_config,
                                supports_shape)
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch import specs as SP
from repro.models.model import Model, abstract_init
from repro.optim.adamw import AdamW
from repro.roofline.collect import collective_bytes, summarize_cost
from repro.sharding import rules
from repro.training.train import make_train_step


def _shardings(logical_tree, mesh, *, serve_pure_tp=False):
    return jax.tree.map(
        lambda lg: NamedSharding(
            mesh, rules.spec(lg, mesh, serve_pure_tp=serve_pure_tp)),
        logical_tree, is_leaf=lambda x: isinstance(x, tuple))


def _fit(shardings, shapes, mesh):
    """Null out sharded axes whose dim isn't divisible by the axis size
    (e.g. batch=1 on the dp axes for long_500k) — standard fallback."""
    import numpy as _np

    def one(sh, aval):
        spec = list(sh.spec) + [None] * (len(aval.shape) - len(sh.spec))
        new = []
        for dim, ax in zip(aval.shape, spec):
            if ax is None:
                new.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(_np.prod([mesh.shape[a] for a in axes]))
            new.append(ax if dim % size == 0 else None)
        return NamedSharding(mesh, P(*new))
    return jax.tree.map(one, shardings, shapes)


def _broadcast_cache(shardings, shapes):
    """Validate the cache sharding tree matches the cache shape tree."""
    jax.tree_util.tree_structure(shapes)  # noqa: touch both trees
    return shardings


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                remat: bool = True, extra_tag: str = "",
                n_layers: int = 0, cfg_overrides: dict | None = None,
                keep_hlo: bool = False):
    """Returns a result dict (or raises). No real allocation happens.

    ``n_layers`` overrides depth (the roofline differential probes use
    two shallow depths to recover per-layer costs — XLA cost_analysis
    counts scan bodies ONCE, not per trip)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if n_layers:
        kw = {"n_layers": n_layers}
        if cfg.arch_type == "audio":
            kw["encoder_layers"] = n_layers
        cfg = _dc.replace(cfg, **kw)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    if not supports_shape(cfg, shape):
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped (full attention at 500k; DESIGN.md §6)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg, remat=remat and shape.kind == "train")

    from repro.models import runtime as RT
    serve_tp = RT.SERVE_PURE_TP and shape.kind != "train"
    t0 = time.time()
    params_shapes, logical = abstract_init(model)
    p_shardings = _fit(_shardings(logical, mesh, serve_pure_tp=serve_tp),
                       params_shapes, mesh)

    if shape.kind == "train":
        opt = AdamW(lr=1e-4)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        # opt state shards exactly like params (mu/nu trees) + scalar step
        o_shardings = type(opt_shapes)(
            step=NamedSharding(mesh, P()),
            mu=p_shardings, nu=p_shardings)
        bspecs, bshard = SP.batch_specs(cfg, shape, mesh)
        b_shardings = _fit({k: NamedSharding(mesh, v)
                            for k, v in bshard.items()}, bspecs, mesh)
        step_fn = make_train_step(model, opt)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shardings, o_shardings, b_shardings),
            out_shardings=(p_shardings, o_shardings,
                           NamedSharding(mesh, P())))
        with set_mesh(mesh):
            lowered = jitted.lower(params_shapes, opt_shapes, bspecs)
    elif shape.kind == "prefill":
        bspecs, bshard = SP.batch_specs(cfg, shape, mesh)
        b_shardings = _fit({k: NamedSharding(mesh, v)
                            for k, v in bshard.items()}, bspecs, mesh)
        cache_shapes = jax.eval_shape(
            lambda: model.cache_init(shape.global_batch, shape.seq_len))
        c_shardings = _fit(_broadcast_cache(_shardings(model.cache_specs(),
                                                       mesh), cache_shapes),
                           cache_shapes, mesh)
        jitted = jax.jit(
            model.prefill,
            in_shardings=(p_shardings, b_shardings, c_shardings),
            out_shardings=(NamedSharding(mesh, P()), c_shardings))
        with set_mesh(mesh):
            lowered = jitted.lower(params_shapes, bspecs, cache_shapes)
    else:  # decode
        tok_spec, tok_ps = SP.decode_token_specs(cfg, shape, mesh)
        cache_shapes = jax.eval_shape(
            lambda: model.cache_init(shape.global_batch, shape.seq_len))
        c_shardings = _fit(_broadcast_cache(_shardings(model.cache_specs(),
                                                       mesh), cache_shapes),
                           cache_shapes, mesh)
        batch_ax = tok_ps[0] if len(tok_ps) else None
        logits_sh = NamedSharding(mesh, P(batch_ax, "model"))
        jitted = jax.jit(
            model.decode_step,
            in_shardings=(p_shardings, NamedSharding(mesh, tok_ps),
                          c_shardings),
            out_shardings=(logits_sh, c_shardings))
        with set_mesh(mesh):
            lowered = jitted.lower(params_shapes, tok_spec, cache_shapes)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    res = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "tag": extra_tag,
        "status": "ok",
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": summarize_cost(cost),
        "collectives": coll,
    }
    if keep_hlo:
        res["_hlo"] = hlo_text
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every supported (arch x shape) on this mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    combos = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for a in ARCH_NAMES:
            for s in INPUT_SHAPES:
                for mp in meshes:
                    combos.append((a, s, mp))
    else:
        assert args.arch and args.shape
        for mp in meshes:
            combos.append((args.arch, args.shape, mp))

    out_f = open(args.out, "a") if args.out else None
    n_ok = n_skip = n_fail = 0
    for arch, shp, mp in combos:
        label = f"{arch} x {shp} x {'2x16x16' if mp else '16x16'}"
        try:
            res = lower_combo(arch, shp, multi_pod=mp,
                              remat=not args.no_remat, extra_tag=args.tag)
            if res["status"].startswith("skip"):
                n_skip += 1
                print(f"SKIP {label}: {res['status']}", flush=True)
            else:
                n_ok += 1
                print(f"OK   {label}: compile={res['compile_s']}s "
                      f"flops/dev={res['cost'].get('flops', 0):.3e} "
                      f"coll={res['collectives']['total_bytes']:.3e}B",
                      flush=True)
        except Exception as e:
            n_fail += 1
            res = {"arch": arch, "shape": shp, "multi_pod": mp,
                   "status": f"FAIL: {type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"FAIL {label}: {type(e).__name__}: {e}", flush=True)
        if out_f:
            out_f.write(json.dumps(res) + "\n")
            out_f.flush()
    if out_f:
        out_f.close()
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
