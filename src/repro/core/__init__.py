# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core.kernel_engine import (ChunkedKernelEngine,  # noqa: F401
                                      DenseKernelEngine, EngineConfig,
                                      KernelEngine, PallasKernelEngine,
                                      make_engine)
from repro.core.multiclass import (BinaryTask, Bucket,  # noqa: F401
                                   MulticlassStrategy, OneVsOneStrategy,
                                   OneVsRestStrategy, Schedule,
                                   ScheduleConfig, TaskSet, build_schedule,
                                   decide_from_pairs, get_strategy,
                                   schedule_stats)
