# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core.kernel_engine import (ChunkedKernelEngine,  # noqa: F401
                                      DenseKernelEngine, EngineConfig,
                                      KernelEngine, PallasKernelEngine,
                                      make_engine)
