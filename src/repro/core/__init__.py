# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core.approx import (LowRankKernelEngine, NystromMap,  # noqa: F401
                               RFFMap, make_feature_map)
from repro.core.kernel_engine import (ChunkedKernelEngine,  # noqa: F401
                                      DenseKernelEngine, EngineConfig,
                                      KernelEngine, LOWRANK_BACKENDS,
                                      PallasKernelEngine, make_engine)
from repro.core.linear import (DCDConfig, DCDResult,  # noqa: F401
                               linear_svc, linear_svr)
from repro.core.multiclass import (BinaryTask, Bucket,  # noqa: F401
                                   MulticlassStrategy, OneVsOneStrategy,
                                   OneVsRestStrategy, Schedule,
                                   ScheduleConfig, TaskSet, build_schedule,
                                   decide_from_pairs, get_strategy,
                                   schedule_stats)
