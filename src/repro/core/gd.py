"""Gradient-descent dual kernel-SVM — the paper's TensorFlow baseline.

The paper's TensorFlow implementation (Sec. III-C, Fig. 5) builds the
classic dataflow-graph SVM: trainable dual variables ("Variables"), a
Gaussian RBF kernel, and a plain ``GradientDescentOptimizer`` run for a
fixed number of steps inside a session. This is the "implicit control"
side of the comparison — a generic autodiff optimizer applied to the
(negated) dual objective with a soft penalty for the equality constraint,
re-evaluating the FULL Gram interaction every step.

We reproduce that baseline faithfully in JAX (the baseline must be
implemented, not assumed): same math, same fixed-step loop, same
full-Gram-per-step cost profile. ``jax.jit`` plays the role of the TF
session executor; running with jit disabled is the "graph-free eager"
point used by the Table-VI portability benchmark.

Loss (maximizing the soft-margin dual by gradient DESCENT on its negation):

    L(a) = -[ 1'a - 1/2 a'(yy' * K)a ] + lam_eq * (y'a)^2
    a clipped to [0, C] after every step (projected GD).

``svr_gd`` is the regression analog — the same projected fixed-step loop
on the epsilon-insensitive dual, in the doubled-variable layout of
``core.smo.svr_smo`` (signs s = [+1; -1] over [x; x], linear term
p = [eps - y; eps + y], box [0, C]):

    L(b) = 1/2 (sb)' K (sb) + p'b + lam_eq * (s'b)^2
    b clipped to [0, C] after every step.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import kernel_engine as KE
from repro.core import kernels as K


@dataclasses.dataclass(frozen=True)
class GDConfig:
    C: float = 1.0
    lr: float = 0.01
    steps: int = 2000          # the TF recipes run a fixed session loop
    eq_penalty: float = 1.0    # soft penalty for sum_i a_i y_i = 0


class GDResult(NamedTuple):
    alpha: jax.Array
    b: jax.Array
    loss_curve: jax.Array   # (steps,) training loss per step
    n_iter: jax.Array


def _dual_loss_mv(alpha, y, matvec, eq_penalty, n_valid):
    ay = alpha * y
    dual = jnp.sum(alpha) - 0.5 * ay @ matvec(ay)
    eq = jnp.sum(ay)
    # penalty normalized by n so the curvature (hence the stable lr) does
    # not grow with dataset size — plain GD diverges otherwise
    return -dual + eq_penalty * eq * eq / n_valid


def _dual_loss(alpha, y, gram, eq_penalty, n_valid):
    return _dual_loss_mv(alpha, y, lambda v: gram @ v, eq_penalty, n_valid)


def _qp_loss_mv(alpha, y, p, matvec, eq_penalty, n_valid):
    """Penalized negated dual of the general box QP (p = -1 recovers
    ``_dual_loss_mv``): 1/2 (ya)'K(ya) + p'a + pen * (y'a)^2 / n."""
    ay = alpha * y
    eq = jnp.sum(ay)
    return (0.5 * ay @ matvec(ay) + p @ alpha
            + eq_penalty * eq * eq / n_valid)


def binary_gd(x: jax.Array,
              y: jax.Array,
              mask: Optional[jax.Array] = None,
              *,
              cfg: GDConfig = GDConfig(),
              kernel: K.KernelParams = K.KernelParams(),
              gram: Optional[jax.Array] = None,
              engine: Optional[KE.KernelEngine | KE.EngineConfig | str]
              = None) -> GDResult:
    """Train one binary SVM by projected gradient descent on the dual.

    ``engine`` routes the per-step Gram interaction through a
    ``KernelEngine`` (``engine.matvec`` — chunked backends keep the
    baseline's full-interaction-per-step cost profile WITHOUT holding the
    (n, n) Gram). ``gram=`` is the legacy shim and forces the dense path.
    """
    n = x.shape[0]
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones((n,), dtype=bool)
    mask = mask & (jnp.abs(y) > 0.5)

    if gram is not None:
        matvec = lambda v: gram @ v
    else:
        if engine is None:
            engine = KE.DenseKernelEngine(x, kernel)
        elif not isinstance(engine, KE.KernelEngine):
            engine = KE.make_engine(x, kernel, engine)
        matvec = engine.matvec

    n_valid = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    grad_fn = jax.grad(_dual_loss_mv)

    def step(alpha, _):
        g = grad_fn(alpha, y, matvec, cfg.eq_penalty, n_valid)
        alpha = alpha - cfg.lr * g
        alpha = jnp.clip(alpha, 0.0, cfg.C) * mask   # projection onto box
        return alpha, _dual_loss_mv(alpha, y, matvec, cfg.eq_penalty,
                                    n_valid)

    alpha0 = jnp.zeros((n,), jnp.float32)
    alpha, losses = jax.lax.scan(step, alpha0, None, length=cfg.steps)

    b = _estimate_bias(alpha, y, matvec, mask, cfg.C)
    return GDResult(alpha=alpha, b=b, loss_curve=losses,
                    n_iter=jnp.asarray(cfg.steps, jnp.int32))


def _estimate_bias(alpha, y, matvec, mask, c):
    """b from free support vectors (0 < a < C), falling back to all SVs."""
    g = matvec(alpha * y)                       # decision without bias
    free = mask & (alpha > 1e-6) & (alpha < c - 1e-6)
    anysv = mask & (alpha > 1e-6)
    use = jnp.where(jnp.any(free), free, anysv)
    cnt = jnp.maximum(jnp.sum(use), 1)
    return jnp.sum(jnp.where(use, y - g, 0.0)) / cnt


class SVRGDResult(NamedTuple):
    beta: jax.Array        # (n,) alpha - alpha*: K(x_i, .) coefficients
    b: jax.Array           # () bias, prediction = sum beta_i K(x_i,.) + b
    alpha: jax.Array       # (2n,) raw doubled multipliers [alpha; alpha*]
    loss_curve: jax.Array  # (steps,) training loss per step
    n_iter: jax.Array


def svr_gd(x: jax.Array,
           y: jax.Array,
           mask: Optional[jax.Array] = None,
           *,
           epsilon: float = 0.1,
           cfg: GDConfig = GDConfig(),
           kernel: K.KernelParams = K.KernelParams(),
           engine: Optional[KE.EngineConfig | str] = None) -> SVRGDResult:
    """Train one epsilon-SVR by projected gradient descent on the
    epsilon-insensitive dual — the regression analog of the paper's
    TensorFlow baseline: a generic fixed-step optimizer re-evaluating
    the full (doubled) Gram interaction every step.

    The engine is built on the DOUBLED (2n, d) sample matrix (same
    layout as ``core.smo.svr_smo``), so pass an ``EngineConfig`` or
    backend name, never a pre-bound engine.
    """
    if isinstance(engine, KE.KernelEngine):
        raise ValueError(
            "svr_gd solves the doubled 2n-variable dual and must build "
            "its engine on [x; x]; pass an EngineConfig or backend name, "
            f"not a bound engine ({type(engine).__name__})")
    n = x.shape[0]
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones((n,), dtype=bool)
    # the doubled layout is owned by core.smo — both solvers must
    # optimize the exact same QP (box bounds are cfg.C via the clip)
    from repro.core.smo import _svr_spec
    s, p, _, _ = _svr_spec(y, epsilon, cfg.C)
    x2 = jnp.concatenate([x, x], axis=0)
    y2 = jnp.concatenate([y, y])
    m2 = jnp.concatenate([mask, mask])

    if engine is None:
        eng = KE.DenseKernelEngine(x2, kernel)
    else:
        eng = KE.make_engine(x2, kernel, engine)
    matvec = eng.matvec

    n_valid = jnp.maximum(jnp.sum(m2.astype(jnp.float32)), 1.0)
    grad_fn = jax.grad(_qp_loss_mv)

    def step(alpha, _):
        g = grad_fn(alpha, s, p, matvec, cfg.eq_penalty, n_valid)
        alpha = alpha - cfg.lr * g
        alpha = jnp.clip(alpha, 0.0, cfg.C) * m2   # projection onto box
        return alpha, _qp_loss_mv(alpha, s, p, matvec, cfg.eq_penalty,
                                  n_valid)

    alpha0 = jnp.zeros((2 * n,), jnp.float32)
    alpha, losses = jax.lax.scan(step, alpha0, None, length=cfg.steps)

    b = _estimate_svr_bias(alpha, s, y2, matvec, m2, cfg.C, epsilon)
    return SVRGDResult(beta=alpha[:n] - alpha[n:], b=b, alpha=alpha,
                       loss_curve=losses,
                       n_iter=jnp.asarray(cfg.steps, jnp.int32))


def _estimate_svr_bias(alpha, s, y2, matvec, mask, c, epsilon):
    """b from free doubled multipliers: a free alpha_i sits ON the upper
    tube edge (y_i - f(x_i) = eps), a free alpha*_i on the lower one
    (= -eps), i.e. b = y_i - g_i - s_i * eps; falls back to all SVs,
    then (degenerate all-zero dual) to every valid sample — which
    averages out to mean(y)."""
    g = matvec(alpha * s)                  # prediction without bias
    free = mask & (alpha > 1e-6) & (alpha < c - 1e-6)
    anysv = mask & (alpha > 1e-6)
    use = jnp.where(jnp.any(free), free,
                    jnp.where(jnp.any(anysv), anysv, mask))
    cnt = jnp.maximum(jnp.sum(use), 1)
    return jnp.sum(jnp.where(use, y2 - g - s * epsilon, 0.0)) / cnt
