"""Unified KernelEngine — every Gram evaluation in the system, one interface.

The paper's central observation is that SVM training cost is dominated by
kernel (Gram) evaluations inside the QP solve, and that the winning
implementation is the one that organizes those evaluations for the
hardware. Before this module the repo scattered that logic over four call
sites (inline Pallas routing in ``core.smo``, the decision paths in
``core.svm``, the OvO layer in ``core.dist`` and ``kernels.ops``), and
every path either materialized the full O(n^2) Gram or recomputed rows
from scratch. ``KernelEngine`` centralizes it.

Interface (all methods jit/vmap-safe; ``x`` may be a tracer)::

    engine.full()            # (n, n) Gram — dense backends only
    engine.diag()            # (n,)  K(x_i, x_i)
    engine.row(i, cache)     # ((n,), cache') one kernel row, LRU-cached
    engine.block(rows, cols) # (r, c) arbitrary sub-block
    engine.matvec(v)         # (n,)  K @ v, chunked — never builds (n, n)
    engine.cross(z)          # (t, n) K(z, X) test-vs-train block
    engine.decide(z, coef,b) # (t,)  K(z, X) @ coef + b, chunked serving
    engine.init_cache()      # functional row-cache state (None if unused)

Backends
--------
``dense``
    Precomputes the (n, n) Gram once (jnp reference kernels). Fastest for
    n up to a few thousand; memory O(n^2). ``row`` is a gather, the cache
    state is ``None``.
``chunked``
    Never materializes (n, n). Rows are computed on the fly in O(n d) and
    cached in a fixed-capacity functional LRU keyed on the working-set
    index — SMO revisits the same violating pair region for many
    consecutive iterations, so the cache converts most row requests into
    a (slots, n) gather. ``matvec``/``decide`` stream over row blocks of
    ``chunk`` samples (peak extra memory O(chunk * n)). This is the
    backend that trains n = 16k-32k RBF problems the dense path cannot
    hold.
``pallas``
    The chunked layout with the Gram hot spots routed through the tiled
    Pallas TPU kernels in ``repro.kernels.ops`` (MXU-aligned VMEM blocks;
    RBF and linear). Non-Pallas kernels fall back to the jnp path.
``sharded``
    The data-parallel backend for SINGLE-problem solves, used INSIDE a
    ``shard_map`` body whose sample axis is sharded over
    ``EngineConfig.shard_axis``. ``x`` is the local (n_local, d) shard;
    the full (n, d) sample matrix is all-gathered once (the data, never
    the Gram), after which every Gram evaluation is local compute:
    methods return the LOCAL SLICE of the global quantity. ``row(i)`` is
    the owner-replicated global row restricted to local samples,
    ``matvec(v_local)`` all-gathers ``v`` and returns the local row
    block of ``K @ v``, ``decide`` psums per-shard partial decisions.
    This is the engine behind ``core.smo.sharded_binary_smo`` — the JAX
    analog of the paper's per-rank Gram row blocks + MPI_Allreduce.

Mixed precision (engine-level)
------------------------------
``EngineConfig(gram_dtype="bf16")`` switches every backend's Gram
computation to bf16 operands with f32 accumulation: the dense/chunked
jnp paths via ``kernels.make_gram_fn(..., compute_dtype=...)``, the
Pallas backend via bf16 tile loads in ``repro.kernels.ops``. Squared
norms are computed from the same rounded values, so RBF self-similarity
stays exactly 1. fp32 remains the default; the bf16 path is
parity-gated against fp32 on the KKT-violation certificate and serving
deltas in ``tests/test_mixed_precision.py``.

Adaptive shrinking (solver-side, engine-aware)
----------------------------------------------
``SMOConfig(shrink_every=k)`` turns on mask-based adaptive shrinking in
``core.smo.binary_smo`` (Narasimhan et al., *Fast SVMs Using Parallel
Adaptive Shrinking*): every ``k`` convergence checks, samples whose alpha
is pinned at a bound (0 or C) and whose optimality value ``f`` lies
beyond the current ``[b_up, b_low]`` corridor on its non-violating side
(``f > b_low + slack`` for I_up-only members, ``f < b_up - slack`` for
I_low-only, slack = ``shrink_slack * tol``) are frozen out of the active
set; working-set selection and f-cache updates are restricted to the
survivors. When the
active set converges, the solver reconstructs the exact f-cache for ALL
samples with one ``engine.matvec`` (chunked — no (n, n) materialization)
and re-checks the un-shrunk KKT conditions before reporting convergence;
if the full problem still violates, the active set resets and
optimization resumes. Knobs: ``shrink_every`` (checks between shrink
passes; 0 disables) and ``shrink_slack`` (corridor slack in units of
``tol``; larger = more conservative freezing).

Shrinking targets the SINGLE-problem (binary, scalar-jit) path. Under
``vmap``/``shard_map`` OvO batching, ``lax.cond`` lowers to ``select``
and executes BOTH branches, so the un-shrink ``matvec`` would run at
every convergence check for every task — leave ``shrink_every=0`` there
(the ``core.dist`` entry points also strip the LRU row cache for the
same reason: a batched cache lookup recomputes the row regardless).

Migration note (old ``gram=`` / ``row_fn=`` / ``use_pallas`` arguments)
-----------------------------------------------------------------------
The pre-engine keyword plumbing still works as thin deprecation shims::

    binary_smo(x, y, gram=G)                  -> DenseKernelEngine(gram=G)
    binary_smo(x, y, row_fn=f)                -> ChunkedKernelEngine(row_fn=f)
    SMOConfig(use_pallas=True)                -> pallas backend
    SMOConfig(precompute_gram=False)          -> chunked backend

New code should pass ``engine=EngineConfig(backend=...)`` (built lazily
inside the jitted solver) or a bound engine from ``make_engine``:

    eng = make_engine(x, kernel, EngineConfig(backend="chunked"))
    r = binary_smo(x, y, engine=eng, cfg=SMOConfig(shrink_every=4))

``SVC`` accepts ``engine="auto"|"dense"|"chunked"|"pallas"`` or a full
``EngineConfig``, and after ``fit`` serves predictions from a compacted
support-vector set (alpha > 0 rows only), so serving cost scales with
#SV rather than n. Serving itself routes through ``repro.serve``: the
predictor's chunked/dense configs run ``engine.decide`` (built inside
the jitted decide program — every method here is jit/vmap-safe), which
makes this module the REFERENCE path the fused pallas serving kernel is
tested against; ``serve.serving_config`` owns the training->serving
backend degradation (dense/auto -> chunked, cache_slots=0).

Regression rides the same engines: the epsilon-SVR solvers
(``core.smo.svr_smo`` / ``core.gd.svr_gd`` / ``SVR``) bind their engine
to the DOUBLED sample matrix [x; x] — the doubled QP's Gram is exactly
the Gram of [x; x], so no backend needs any regression-specific code.
The only knob that reads differently there is ``dense_limit``: the
auto dense/chunked switch sees 2n rows.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import kernels as K

try:  # jax >= 0.6 exposes shard_map at top level
    _shard_map_fn = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map_fn


def shard_map_compat(f, mesh, in_specs, out_specs):
    """Version-compat shard_map: the replication-check kwarg was renamed
    (``check_rep`` on jax 0.4/0.5, ``check_vma`` on jax >= 0.6); calling
    with the wrong one is a TypeError. Shared by ``core.dist`` (task
    sharding) and ``core.smo.sharded_binary_smo`` (sample sharding)."""
    try:
        return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except TypeError:
        return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)


class RowCache(NamedTuple):
    """Functional LRU row-cache state (threaded through solver loops)."""

    keys: jax.Array    # (slots,) int32 row index per slot, -1 = empty
    stamp: jax.Array   # (slots,) int32 last-use tick (min = LRU victim)
    rows: jax.Array    # (slots, n) float32 cached kernel rows
    clock: jax.Array   # () int32 monotone tick
    hits: jax.Array    # () int32 lookup statistics
    misses: jax.Array  # () int32


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine selection/config — hashable, safe to close over jit.

    backend:     auto | dense | chunked | pallas | sharded, or one of
                 the low-rank approximations nystrom | rff
                 (``repro.core.approx.LowRankKernelEngine``: K ≈ Φ Φ^T
                 from an explicit (n, rank) feature map — the
                 million-sample tier).
    cache_slots: LRU row-cache capacity (chunked/pallas row mode).
    chunk:       row-block size for matvec()/decide() streaming.
    dense_limit: 'auto' picks dense up to this n, chunked above; also the
                 guard above which ChunkedKernelEngine.full() refuses to
                 materialize (n, n).
    shard_axis:  mesh axis name the sample axis is sharded over —
                 required by (and only meaningful for) the "sharded"
                 backend, which must be built inside a shard_map body.
    gram_dtype:  Gram compute precision, "fp32" (exact, default) or
                 "bf16" (mixed precision: bf16 operands with f32
                 accumulation — halves Gram HBM traffic on every
                 backend; Pallas tiles load bf16 natively). Training
                 under bf16 is parity-gated against fp32 by the
                 KKT-certificate tests (tests/test_mixed_precision.py).
    rank:        low-rank backends only: feature count (RFF) / landmark
                 count (Nyström, capped at n).
    landmarks:   Nyström landmark sampling, "uniform" | "kmeans++".
    seed:        PRNG seed for landmark choice / frequency sampling —
                 part of the config so a fit is exactly reproducible.
    """

    backend: str = "auto"
    cache_slots: int = 32
    chunk: int = 2048
    dense_limit: int = 8192
    shard_axis: Optional[str] = None
    gram_dtype: str = "fp32"
    rank: int = 256
    landmarks: str = "uniform"
    seed: int = 0


class KernelEngine:
    """Base: owns x + kernel params; subclasses define the Gram strategy."""

    backend = "base"

    def __init__(self, x: jax.Array, kernel: K.KernelParams,
                 cfg: EngineConfig = EngineConfig()):
        self.x = jnp.asarray(x, jnp.float32)
        self.n = self.x.shape[0]
        self.kernel = kernel
        self.cfg = cfg
        self._gram_fn = K.make_gram_fn(kernel,
                                       compute_dtype=cfg.gram_dtype)

    # -------------------------------------------------------- interface
    def full(self) -> jax.Array:
        raise NotImplementedError

    def diag(self) -> jax.Array:
        if self.kernel.name == "rbf":  # K(x, x) = exp(0) exactly
            return jnp.ones((self.n,), jnp.float32)
        return jax.vmap(lambda r: self._gram_fn(r[None], r[None])[0, 0])(
            self.x)

    def row(self, i: jax.Array, cache=None):
        raise NotImplementedError

    def block(self, rows: jax.Array, cols: jax.Array) -> jax.Array:
        return self._gram_fn(self.x[rows], self.x[cols])

    def cross(self, z: jax.Array) -> jax.Array:
        return self._gram_fn(jnp.asarray(z, jnp.float32), self.x)

    def matvec(self, v: jax.Array) -> jax.Array:
        raise NotImplementedError

    def decide(self, z: jax.Array, coef: jax.Array,
               b: jax.Array | float = 0.0) -> jax.Array:
        """K(z, X) @ coef + b, streamed over test-row chunks."""
        z = jnp.asarray(z, jnp.float32)
        t = z.shape[0]
        chunk = min(self.cfg.chunk, max(t, 1))
        pad = (-t) % chunk
        zp = jnp.pad(z, ((0, pad), (0, 0)))
        blocks = zp.reshape(-1, chunk, z.shape[1])
        out = jax.lax.map(lambda zb: self.cross(zb) @ coef, blocks)
        return out.reshape(-1)[:t] + b

    def init_cache(self):
        return None


class DenseKernelEngine(KernelEngine):
    """Precomputed (n, n) Gram — the n<=~8k fast path."""

    backend = "dense"

    def __init__(self, x, kernel, cfg: EngineConfig = EngineConfig(), *,
                 gram: Optional[jax.Array] = None):
        super().__init__(x, kernel, cfg)
        self.gram = self._gram_fn(self.x, self.x) if gram is None else gram

    def full(self):
        return self.gram

    def diag(self):
        return jnp.diagonal(self.gram)

    def row(self, i, cache=None):
        return self.gram[i], cache

    def block(self, rows, cols):
        return self.gram[rows][:, cols]

    def matvec(self, v):
        return self.gram @ v


class ChunkedKernelEngine(KernelEngine):
    """On-the-fly rows + functional LRU cache; O(n d) resident memory."""

    backend = "chunked"

    def __init__(self, x, kernel, cfg: EngineConfig = EngineConfig(), *,
                 row_fn: Optional[Callable] = None):
        super().__init__(x, kernel, cfg)
        self._row_fn = row_fn

    # ------------------------------------------------------------- rows
    def _compute_row(self, i):
        if self._row_fn is not None:
            return self._row_fn(self.x, self.x[i])
        return self._gram_fn(self.x, self.x[i][None, :])[:, 0]

    def init_cache(self) -> Optional[RowCache]:
        slots = self.cfg.cache_slots
        if slots <= 0:
            return None
        z32 = jnp.zeros((), jnp.int32)
        return RowCache(keys=jnp.full((slots,), -1, jnp.int32),
                        stamp=jnp.zeros((slots,), jnp.int32),
                        rows=jnp.zeros((slots, self.n), jnp.float32),
                        clock=z32, hits=z32, misses=z32)

    def row(self, i, cache: Optional[RowCache] = None):
        if cache is None:
            return self._compute_row(i), None
        hit_vec = cache.keys == i
        hit_slot = jnp.argmax(hit_vec)
        lru_slot = jnp.argmin(cache.stamp)
        tick = cache.clock + 1

        def on_hit(c: RowCache):
            return c.rows[hit_slot], c._replace(
                stamp=c.stamp.at[hit_slot].set(tick),
                clock=tick, hits=c.hits + 1)

        def on_miss(c: RowCache):
            r = self._compute_row(i)
            return r, c._replace(
                keys=c.keys.at[lru_slot].set(i.astype(jnp.int32)
                                             if hasattr(i, "astype")
                                             else jnp.int32(i)),
                rows=c.rows.at[lru_slot].set(r),
                stamp=c.stamp.at[lru_slot].set(tick),
                clock=tick, misses=c.misses + 1)

        return jax.lax.cond(jnp.any(hit_vec), on_hit, on_miss, cache)

    # ---------------------------------------------------------- streams
    def _row_blocks(self):
        chunk = min(self.cfg.chunk, self.n)
        pad = (-self.n) % chunk
        xp = jnp.pad(self.x, ((0, pad), (0, 0)))
        return xp.reshape(-1, chunk, self.x.shape[1]), chunk

    def matvec(self, v):
        blocks, _ = self._row_blocks()
        out = jax.lax.map(lambda xb: self._gram_fn(xb, self.x) @ v, blocks)
        return out.reshape(-1)[:self.n]

    def full(self):
        if self.n > self.cfg.dense_limit:
            raise RuntimeError(
                f"ChunkedKernelEngine.full(): refusing to materialize a "
                f"({self.n}, {self.n}) Gram (dense_limit="
                f"{self.cfg.dense_limit}); use row()/block()/matvec()")
        blocks, _ = self._row_blocks()
        out = jax.lax.map(lambda xb: self._gram_fn(xb, self.x), blocks)
        return out.reshape(-1, self.n)[:self.n]


class PallasKernelEngine(ChunkedKernelEngine):
    """Chunked layout with Gram hot spots on the tiled Pallas TPU kernels.

    RBF and linear route through ``repro.kernels.ops`` (MXU-aligned VMEM
    tiles); other kernels fall back to the jnp reference path.
    """

    backend = "pallas"

    def __init__(self, x, kernel, cfg: EngineConfig = EngineConfig()):
        from repro.kernels import ops as pallas_ops
        self._ops = pallas_ops
        self._pallas_mode = (kernel.name
                             if kernel.name in ("rbf", "linear") else None)
        row_fn = None
        if kernel.name == "rbf":
            row_fn = pallas_ops.gram_row_fn(gamma=kernel.gamma,
                                            compute_dtype=cfg.gram_dtype)
        super().__init__(x, kernel, cfg, row_fn=row_fn)

    def _pallas_gram(self, a, b):
        return self._ops.rbf_gram(a, b, gamma=self.kernel.gamma,
                                  mode=self._pallas_mode,
                                  compute_dtype=self.cfg.gram_dtype)

    def cross(self, z):
        if self._pallas_mode is None:
            return super().cross(z)
        return self._pallas_gram(jnp.asarray(z, jnp.float32), self.x)

    def block(self, rows, cols):
        if self._pallas_mode is None:
            return super().block(rows, cols)
        return self._pallas_gram(self.x[rows], self.x[cols])

    def matvec(self, v):
        if self._pallas_mode is None:
            return super().matvec(v)
        blocks, _ = self._row_blocks()
        out = jax.lax.map(lambda xb: self._pallas_gram(xb, self.x) @ v,
                          blocks)
        return out.reshape(-1)[:self.n]

    def decide(self, z, coef, b=0.0):
        if self.kernel.name == "rbf":
            return self._ops.decision(jnp.asarray(z, jnp.float32), self.x,
                                      coef, b, gamma=self.kernel.gamma,
                                      compute_dtype=self.cfg.gram_dtype)
        return super().decide(z, coef, b)

    def full(self):
        if self.n > self.cfg.dense_limit:
            raise RuntimeError(
                f"PallasKernelEngine.full(): refusing to materialize a "
                f"({self.n}, {self.n}) Gram (dense_limit="
                f"{self.cfg.dense_limit})")
        if self._pallas_mode is None:
            return super().full()
        return self._pallas_gram(self.x, self.x)


class ShardedKernelEngine(ChunkedKernelEngine):
    """Sample-axis-sharded engine for use INSIDE a ``shard_map`` body.

    ``x`` is the LOCAL (n_local, d) shard of the sample matrix;
    construction all-gathers the full (n, d) matrix once (tiled — the
    data is O(n d) and replicating it is what makes every subsequent
    Gram evaluation collective-free; the (n, n) Gram itself is never
    materialized anywhere). Methods return the LOCAL SLICE of the global
    quantity, so the solver's per-sample state (f-cache, alpha, mask)
    stays sharded:

      row(i)     -> (n_local,)  K(x_i, x_local); i is a GLOBAL index,
                    LRU-cached per shard under the global key
      matvec(v)  -> (n_local,)  local row block of K @ v from the LOCAL
                    shard of v (one all_gather of v per call)
      diag()     -> (n_local,)  local self-kernel diagonal
      cross(z)   -> (t, n_local) local column block of K(z, X)
      decide(..) -> (t,)        exact global decision (psum of partials)

    ``full()`` is refused: there is no global Gram in this layout.
    """

    backend = "sharded"

    def __init__(self, x, kernel, cfg: EngineConfig = EngineConfig()):
        if not cfg.shard_axis:
            raise ValueError(
                "ShardedKernelEngine needs EngineConfig.shard_axis (the "
                "mesh axis the sample dimension is sharded over)")
        super().__init__(x, kernel, cfg)
        self.axis = cfg.shard_axis
        self.x_full = jax.lax.all_gather(self.x, self.axis, tiled=True)
        self.n_global = self.x_full.shape[0]

    def _compute_row(self, i):
        # x_i comes off the replicated x_full: no collective per row
        if self._row_fn is not None:
            return self._row_fn(self.x, self.x_full[i])
        return self._gram_fn(self.x, self.x_full[i][None, :])[:, 0]

    def matvec(self, v):
        v_full = jax.lax.all_gather(v, self.axis, tiled=True)
        blocks, _ = self._row_blocks()
        out = jax.lax.map(
            lambda xb: self._gram_fn(xb, self.x_full) @ v_full, blocks)
        return out.reshape(-1)[:self.n]

    def decide(self, z, coef, b=0.0):
        # per-shard partial over local columns, then one psum
        part = super().decide(z, coef, 0.0)
        return jax.lax.psum(part, self.axis) + b

    def full(self):
        raise RuntimeError(
            "ShardedKernelEngine has no global Gram; row()/matvec() "
            "return local slices of the sharded sample axis")


_BACKENDS = {
    "dense": DenseKernelEngine,
    "chunked": ChunkedKernelEngine,
    "pallas": PallasKernelEngine,
    "sharded": ShardedKernelEngine,
}

# low-rank approximation backends resolve lazily (repro.core.approx
# imports this module for the base class / EngineConfig)
LOWRANK_BACKENDS = ("nystrom", "rff")


def make_engine(x: jax.Array, kernel: K.KernelParams,
                cfg: EngineConfig | str = EngineConfig(), *,
                gram: Optional[jax.Array] = None,
                row_fn: Optional[Callable] = None) -> KernelEngine:
    """Resolve an EngineConfig (or backend name) into a bound engine.

    ``gram``/``row_fn`` are the deprecation shims for the old keyword
    plumbing: a provided Gram forces the dense backend, a provided row
    function forces chunked.
    """
    if isinstance(cfg, str):
        cfg = EngineConfig(backend=cfg)
    backend = cfg.backend
    if gram is not None:
        return DenseKernelEngine(x, kernel, cfg, gram=gram)
    if row_fn is not None:
        return ChunkedKernelEngine(x, kernel, cfg, row_fn=row_fn)
    if backend == "auto":
        backend = "dense" if x.shape[0] <= cfg.dense_limit else "chunked"
    if backend in LOWRANK_BACKENDS:
        from repro.core.approx import LowRankKernelEngine
        return LowRankKernelEngine(x, kernel, cfg)
    try:
        cls = _BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown engine backend {backend!r}; expected one of "
            f"{sorted([*_BACKENDS, *LOWRANK_BACKENDS])} or 'auto'"
        ) from None
    return cls(x, kernel, cfg)
