"""Linear-path dual coordinate descent — the O(n·k) solver behind the
approximate-kernel tier.

Once a kernel problem has an explicit feature map ``Φ ∈ (n, k)``
(Nyström landmarks or random Fourier features, ``repro.core.approx``),
the kernel QP becomes a LINEAR SVM in feature space and the per-pair
SMO machinery — O(n) f-cache updates per iteration, iteration counts
that grow with n — is the wrong tool. This module implements the
LIBLINEAR dual coordinate descent of Hsieh et al. (2008): sweep the
dual variables cyclically, and for each coordinate apply the exact
box-clipped Newton step

    beta_i <- clip(beta_i - g_i / Q_ii, lo_i, hi_i),
    g_i = y_i (phibar_i . w) + p_i,   w = PhiBar^T (y * beta)

maintaining the primal image ``w`` incrementally (O(k) per coordinate,
O(n k) per epoch, O(n + k) solver state beyond Φ itself — never any
(n, n) object). The bias is the classic augmented constant feature
``phibar_i = [phi_i, bias]``, which drops the equality constraint from
the dual — exactly the no-offset box QP whose optimality the
``smo.kkt_violation`` certificate checks with the multiplier pinned at
``r = 0``.

Stopping follows LIBLINEAR: the maximum projected gradient over a full
epoch. The loop exits at ``viol <= tol / 2`` so the REPORTED solution
(whose coordinates moved after their gradient was measured) still
certifies at ``kkt_violation(..., r=0) <= tol`` — the convention the
KKT-certificate tests pin for both backends, SVC and SVR.

Both entry points mirror the SMO QP specs (``smo._classification_spec``
/ ``smo._svr_spec``): ``linear_svc`` is the hinge-loss dual (p = -1,
box [0, C]); ``linear_svr`` solves the epsilon-insensitive dual as the
doubled-variable QP over ``[Φ; Φ]`` with signs [+1; -1] — the same
doubling the kernel path uses, so beta = alpha - alpha* and the
certificate harness needs no regression-specific code.

Everything is jit-safe (``lax.while_loop`` over ``lax.fori_loop``);
``fit_linear_svc`` / ``fit_linear_svr`` are the jitted, config-cached
wrappers ``SVC`` / ``SVR`` call (cf. ``svm._jitted_binary_fit``).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DCDConfig:
    """Static DCD solver config — hashable, safe to close over jit.

    C:          box constraint (upper bound of every dual variable).
    tol:        certificate tolerance: the solve stops once the max
                projected gradient over an epoch is <= tol / 2, which
                certifies ``kkt_violation(..., r=0) <= tol``.
    max_epochs: full passes over the n dual coordinates.
    bias:       augmented constant-feature value (the bias enters the
                model as ``bias * w_bias``); 0 disables the intercept.
    """

    C: float = 1.0
    tol: float = 1e-3
    max_epochs: int = 1000
    bias: float = 1.0


class DCDResult(NamedTuple):
    alpha: jax.Array      # (n,) dual variables at the box optimum
    w: jax.Array          # (k,) primal weights  Phi^T (y * alpha)
    b: jax.Array          # ()   intercept  bias * w_bias
    n_iter: jax.Array     # ()   epochs run
    converged: jax.Array  # ()   bool: viol <= tol/2 before max_epochs
    gap: jax.Array        # ()   last epoch's max projected gradient


def dcd_qp(phi: jax.Array, y: jax.Array, p: jax.Array,
           lo: jax.Array, hi: jax.Array,
           mask: Optional[jax.Array] = None, *,
           cfg: DCDConfig = DCDConfig(),
           alpha0: Optional[jax.Array] = None) -> DCDResult:
    """Minimize ``1/2 beta^T Qbar beta + p^T (y-signed terms)`` over the
    box ``lo <= beta <= hi`` where ``Qbar_ij = y_i y_j (phi_i.phi_j +
    bias^2)`` — generic spec-driven form shared by SVC and SVR (module
    docstring). ``mask=False`` coordinates are frozen at their initial
    value (0) and excluded from the stopping criterion. ``alpha0`` warm
    starts the sweep (clipped to the box, zeroed on masked coordinates);
    the augmented-bias dual has no equality constraint, so any
    box-feasible start is admissible — None keeps the cold beta = 0
    start bit-identical to the pre-warm-start solver."""
    phi = jnp.asarray(phi, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    p = jnp.broadcast_to(jnp.asarray(p, jnp.float32), y.shape)
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), y.shape)
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), y.shape)
    n, k = phi.shape
    live = (jnp.ones((n,), bool) if mask is None
            else jnp.asarray(mask, bool))
    bias = jnp.float32(cfg.bias)
    stop = 0.5 * cfg.tol
    # deterministic per-epoch coordinate shuffles (the LIBLINEAR trick:
    # cyclic order couples badly with correlated features — low-rank Φ
    # columns ARE correlated — and can slow convergence by orders of
    # magnitude); a fixed key keeps refits bit-identical
    key = jax.random.PRNGKey(0)

    # per-coordinate curvature Qbar_ii (y_i^2 = 1); the floor guards
    # all-zero feature rows (a padded sample) from a 0/0 Newton step
    q_diag = jnp.maximum(jnp.sum(phi * phi, axis=1) + bias * bias, 1e-12)
    ys = jnp.where(live, y, 0.0)

    def exact_w(beta):
        # O(n k) matmul refresh of the incremental primal image: bounds
        # the f32 drift of n accumulated rank-1 updates to one epoch, so
        # the measured projected gradient IS the certificate quantity
        coef = ys * beta
        return phi.T @ coef, jnp.sum(coef)

    def coord(t, carry):
        beta, w, wb, viol, perm = carry
        i = perm[t]
        phi_i = phi[i]
        g = y[i] * (phi_i @ w + bias * wb) + p[i]
        # projected gradient: the certificate quantity at this coordinate
        at_lo = beta[i] <= lo[i]
        at_hi = beta[i] >= hi[i]
        pg = jnp.where(at_lo, jnp.minimum(g, 0.0),
                       jnp.where(at_hi, jnp.maximum(g, 0.0), g))
        viol = jnp.where(live[i], jnp.maximum(viol, jnp.abs(pg)), viol)
        b_new = jnp.clip(beta[i] - g / q_diag[i], lo[i], hi[i])
        d = jnp.where(live[i], b_new - beta[i], 0.0)
        return (beta.at[i].add(d), w + d * y[i] * phi_i,
                wb + d * y[i] * bias, viol, perm)

    def epoch(state):
        beta, _, _, _, n_ep = state
        w, wsum = exact_w(beta)
        perm = jax.random.permutation(jax.random.fold_in(key, n_ep), n)
        beta, w, wb, viol, _ = jax.lax.fori_loop(
            0, n, coord, (beta, w, wsum, jnp.float32(0.0), perm))
        return beta, w, wb, viol, n_ep + 1

    def keep_going(state):
        _, _, _, viol, n_ep = state
        return (viol > stop) & (n_ep < cfg.max_epochs)

    if alpha0 is None:
        beta0 = jnp.zeros((n,), jnp.float32)
    else:
        # each epoch refreshes (w, wb) from beta via exact_w, so the warm
        # start only needs the clipped multipliers themselves
        beta0 = jnp.clip(jnp.asarray(alpha0, jnp.float32), lo, hi) * live
    init = (beta0, jnp.zeros((k,), jnp.float32),
            jnp.float32(0.0), jnp.float32(jnp.inf), jnp.int32(0))
    beta, _, _, viol, n_ep = jax.lax.while_loop(keep_going, epoch, init)
    w, wsum = exact_w(beta)   # the served/certified state, drift-free
    return DCDResult(alpha=beta, w=w, b=bias * wsum, n_iter=n_ep,
                     converged=viol <= stop, gap=viol)


def linear_svc(phi: jax.Array, y: jax.Array, *,
               cfg: DCDConfig = DCDConfig(),
               mask: Optional[jax.Array] = None,
               alpha0: Optional[jax.Array] = None) -> DCDResult:
    """Hinge-loss dual on explicit features: p = -1, box [0, C] (the
    linear-space image of ``smo._classification_spec``). ``y`` in
    {-1, +1}; decision f(z) = phi(z) . w + b."""
    n = phi.shape[0]
    return dcd_qp(phi, y, -jnp.ones((n,), jnp.float32),
                  jnp.zeros((n,), jnp.float32),
                  jnp.full((n,), cfg.C, jnp.float32), mask, cfg=cfg,
                  alpha0=alpha0)


class LinearSVRResult(NamedTuple):
    beta: jax.Array       # (n,) alpha - alpha*
    w: jax.Array          # (k,) Phi^T beta
    b: jax.Array          # ()
    alpha: jax.Array      # (2n,) raw doubled variables [alpha; alpha*]
    n_iter: jax.Array
    converged: jax.Array
    gap: jax.Array


def linear_svr(phi: jax.Array, y: jax.Array, *, epsilon: float,
               cfg: DCDConfig = DCDConfig(),
               mask: Optional[jax.Array] = None,
               alpha0: Optional[jax.Array] = None) -> LinearSVRResult:
    """epsilon-insensitive dual as the doubled QP over [Φ; Φ] with signs
    s = [+1; -1] and p = [eps - y; eps + y] (the linear-space image of
    ``smo._svr_spec``); w = Φ^T (alpha - alpha*) falls out of the
    doubling automatically. ``mask`` and ``alpha0`` are per-SAMPLE
    (length n): the mask doubles with the variables; ``alpha0`` is a
    beta = alpha - alpha* warm start split into its canonical doubled
    decomposition ``[max(beta, 0); max(-beta, 0)]``."""
    n = phi.shape[0]
    y = jnp.asarray(y, jnp.float32)
    phi2 = jnp.concatenate([phi, phi], axis=0)
    s = jnp.concatenate([jnp.ones((n,), jnp.float32),
                         -jnp.ones((n,), jnp.float32)])
    p = jnp.concatenate([epsilon - y, epsilon + y])
    m2 = None
    if mask is not None:
        m2 = jnp.concatenate([mask, mask])
    a2 = None
    if alpha0 is not None:
        beta0 = jnp.asarray(alpha0, jnp.float32)
        a2 = jnp.concatenate([jnp.maximum(beta0, 0.0),
                              jnp.maximum(-beta0, 0.0)])
    r = dcd_qp(phi2, s, p, jnp.zeros((2 * n,), jnp.float32),
               jnp.full((2 * n,), cfg.C, jnp.float32), m2, cfg=cfg,
               alpha0=a2)
    beta = r.alpha[:n] - r.alpha[n:]
    return LinearSVRResult(beta=beta, w=r.w, b=r.b, alpha=r.alpha,
                           n_iter=r.n_iter, converged=r.converged,
                           gap=r.gap)


@lru_cache(maxsize=64)
def fit_linear_svc(cfg: DCDConfig):
    """Jitted classification solve, cached per static config (jit keys
    its cache on the callable — cf. ``svm._jitted_binary_fit``)."""
    return jax.jit(lambda phi, y: linear_svc(phi, y, cfg=cfg))


@lru_cache(maxsize=64)
def fit_linear_svr(epsilon: float, cfg: DCDConfig):
    """Jitted epsilon-SVR solve, cached per static config."""
    return jax.jit(lambda phi, y: linear_svr(phi, y, epsilon=epsilon,
                                             cfg=cfg))
