"""Kernel (Gram) functions for SVM — pure-jnp reference path.

These are the mathematical kernels K(x, z) used by both solvers. The
performance-critical tiled TPU versions live in ``repro.kernels`` (Pallas);
every Pallas kernel's oracle delegates to the functions here.

All functions take matrices ``A (n, d)`` and ``B (m, d)`` and return the
Gram block ``K (n, m)`` in float32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Hyper-parameters of the SVM kernel function.

    gamma:  RBF / poly / sigmoid scale. ``gamma <= 0`` means "scale":
            1 / (d * Var[X]) resolved at fit time.
    degree: polynomial degree.
    coef0:  poly / sigmoid offset.
    """

    name: str = "rbf"  # linear | poly | rbf | sigmoid
    gamma: float = 1.0
    degree: int = 3
    coef0: float = 0.0


COMPUTE_DTYPES = ("fp32", "bf16")


def _compute_cast(a: jax.Array, b: jax.Array, compute_dtype: str):
    """Round operands to the Gram compute precision. Under "bf16" both
    the dot and the squared norms see the SAME rounded values (the dot
    itself still accumulates in f32 via ``preferred_element_type``), so
    the RBF zero-distance diagonal stays 1 up to f32 summation-order
    rounding (~1e-6) instead of drifting by the full bf16 epsilon."""
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"unknown compute_dtype {compute_dtype!r}; "
                         f"expected one of {COMPUTE_DTYPES}")
    if compute_dtype == "bf16":
        return a.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
    return a.astype(jnp.float32), b.astype(jnp.float32)


def linear_gram(a: jax.Array, b: jax.Array, *,
                compute_dtype: str = "fp32") -> jax.Array:
    a, b = _compute_cast(a, b, compute_dtype)
    return jnp.dot(a, b.T, preferred_element_type=jnp.float32)


def poly_gram(a: jax.Array, b: jax.Array, *, gamma: float, degree: int,
              coef0: float, compute_dtype: str = "fp32") -> jax.Array:
    return (gamma * linear_gram(a, b, compute_dtype=compute_dtype)
            + coef0) ** degree


def sigmoid_gram(a: jax.Array, b: jax.Array, *, gamma: float,
                 coef0: float, compute_dtype: str = "fp32") -> jax.Array:
    return jnp.tanh(gamma * linear_gram(a, b, compute_dtype=compute_dtype)
                    + coef0)


def sqdist(a: jax.Array, b: jax.Array, *,
           compute_dtype: str = "fp32") -> jax.Array:
    """Pairwise squared Euclidean distances, numerically clamped at 0.

    Norms are accumulated in f32 from the compute-precision values, so
    the ``sqdist(x, x)`` diagonal stays ~0 (f32 rounding, not bf16
    epsilon) under bf16; the clamp removes the negative residues."""
    a, b = _compute_cast(a, b, compute_dtype)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    a2 = jnp.sum(af * af, axis=-1, keepdims=True)        # (n, 1)
    b2 = jnp.sum(bf * bf, axis=-1, keepdims=True).T      # (1, m)
    d2 = a2 + b2 - 2.0 * jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    return jnp.maximum(d2, 0.0)


def rbf_gram(a: jax.Array, b: jax.Array, *, gamma: float,
             compute_dtype: str = "fp32") -> jax.Array:
    return jnp.exp(-gamma * sqdist(a, b, compute_dtype=compute_dtype))


def make_gram_fn(params: KernelParams, *, compute_dtype: str = "fp32"
                 ) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Resolve a KernelParams into a jit-friendly ``(A, B) -> K`` closure.

    ``compute_dtype`` selects the Gram operand precision ("fp32" the
    exact default, "bf16" the mixed-precision path: bf16 operands, f32
    accumulation — the jnp realization of ``EngineConfig.gram_dtype``).
    """
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"unknown compute_dtype {compute_dtype!r}; "
                         f"expected one of {COMPUTE_DTYPES}")
    name = params.name
    if name == "linear":
        return partial(linear_gram, compute_dtype=compute_dtype)
    if name == "poly":
        return partial(poly_gram, gamma=params.gamma, degree=params.degree,
                       coef0=params.coef0, compute_dtype=compute_dtype)
    if name == "sigmoid":
        return partial(sigmoid_gram, gamma=params.gamma, coef0=params.coef0,
                       compute_dtype=compute_dtype)
    if name == "rbf":
        return partial(rbf_gram, gamma=params.gamma,
                       compute_dtype=compute_dtype)
    raise ValueError(f"unknown kernel {name!r}")


def resolve_gamma(params: KernelParams, x: jax.Array) -> KernelParams:
    """Resolve gamma<=0 to the sklearn-style 'scale' heuristic.

    Constant / near-constant features get ``gamma = 1.0`` (sklearn's
    fallback): the old ``max(var, 1e-12)`` clamp produced gamma ~ 1e12,
    which degenerates the RBF Gram to the identity matrix.
    """
    if params.gamma > 0:
        return params
    var = float(jnp.var(x))
    gamma = 1.0 / (x.shape[-1] * var) if var > 1e-12 else 1.0
    return dataclasses.replace(params, gamma=gamma)
