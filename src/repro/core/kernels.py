"""Kernel (Gram) functions for SVM — pure-jnp reference path.

These are the mathematical kernels K(x, z) used by both solvers. The
performance-critical tiled TPU versions live in ``repro.kernels`` (Pallas);
every Pallas kernel's oracle delegates to the functions here.

All functions take matrices ``A (n, d)`` and ``B (m, d)`` and return the
Gram block ``K (n, m)`` in float32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Hyper-parameters of the SVM kernel function.

    gamma:  RBF / poly / sigmoid scale. ``gamma <= 0`` means "scale":
            1 / (d * Var[X]) resolved at fit time.
    degree: polynomial degree.
    coef0:  poly / sigmoid offset.
    """

    name: str = "rbf"  # linear | poly | rbf | sigmoid
    gamma: float = 1.0
    degree: int = 3
    coef0: float = 0.0


def linear_gram(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b.T, preferred_element_type=jnp.float32)


def poly_gram(a: jax.Array, b: jax.Array, *, gamma: float, degree: int,
              coef0: float) -> jax.Array:
    return (gamma * linear_gram(a, b) + coef0) ** degree


def sigmoid_gram(a: jax.Array, b: jax.Array, *, gamma: float,
                 coef0: float) -> jax.Array:
    return jnp.tanh(gamma * linear_gram(a, b) + coef0)


def sqdist(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise squared Euclidean distances, numerically clamped at 0."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)          # (n, 1)
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T        # (1, m)
    d2 = a2 + b2 - 2.0 * jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    return jnp.maximum(d2, 0.0)


def rbf_gram(a: jax.Array, b: jax.Array, *, gamma: float) -> jax.Array:
    return jnp.exp(-gamma * sqdist(a, b))


def make_gram_fn(params: KernelParams) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Resolve a KernelParams into a jit-friendly ``(A, B) -> K`` closure."""
    name = params.name
    if name == "linear":
        return linear_gram
    if name == "poly":
        return partial(poly_gram, gamma=params.gamma, degree=params.degree,
                       coef0=params.coef0)
    if name == "sigmoid":
        return partial(sigmoid_gram, gamma=params.gamma, coef0=params.coef0)
    if name == "rbf":
        return partial(rbf_gram, gamma=params.gamma)
    raise ValueError(f"unknown kernel {name!r}")


def resolve_gamma(params: KernelParams, x: jax.Array) -> KernelParams:
    """Resolve gamma<=0 to the sklearn-style 'scale' heuristic.

    Constant / near-constant features get ``gamma = 1.0`` (sklearn's
    fallback): the old ``max(var, 1e-12)`` clamp produced gamma ~ 1e12,
    which degenerates the RBF Gram to the identity matrix.
    """
    if params.gamma > 0:
        return params
    var = float(jnp.var(x))
    gamma = 1.0 / (x.shape[-1] * var) if var > 1e-12 else 1.0
    return dataclasses.replace(params, gamma=gamma)
