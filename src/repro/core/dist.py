"""The "MPI layer": distributing independent OvO tasks over the mesh.

Paper Fig. 4 (``MPI-CUDA_multiSMO``): C = m(m-1)/2 binary problems are
statically partitioned over P workers, N = C/P problems each; every worker
runs the same binary-SMO program on its slice (SPMD); communication is
only the initial broadcast of data and the final gather of alphas.

JAX-native mapping:

  MPI rank            ->  a slice of the mesh worker axis / axes
  static partition    ->  task-axis sharding of (x, y, mask) via shard_map
  SPMD binary SMO     ->  vmap(binary_smo) inside the shard_map body
  MPI_Bcast / Gather  ->  in/out shardings (device_put in, addressable
                          gather out); NO collectives inside the solver
                          loop, exactly the paper's comm profile.

``sequential_ovo_fit`` is the "Multi-Tensorflow" side: one GD session per
task, executed one after another (the paper runs multiple TF sessions
sequentially).

Every fit entry point threads an optional ``engine`` (an ``EngineConfig``
or backend name from ``repro.core.kernel_engine``) down to the binary
solvers, so the per-task Gram strategy — dense, chunked + LRU row cache,
or Pallas-tiled — is chosen once at the top.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import gd as gd_mod
from repro.core import kernel_engine as KE
from repro.core import kernels as K
from repro.core import smo as smo_mod
from repro.core.ovo import OvOTasks

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map  # type: ignore


def _shard_map(f, mesh, in_specs, out_specs):
    """Version-compat shard_map: the replication-check kwarg was renamed
    (``check_rep`` on jax 0.4/0.5, ``check_vma`` on jax >= 0.6); calling
    with the wrong one is a TypeError, which on the old kwarg silently
    broke the whole distributed path."""
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)
    except TypeError:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def _batched_engine(engine):
    """Strip the LRU row cache for vmapped/sharded dispatch: a batched
    ``lax.cond`` executes both branches, so a cache lookup recomputes the
    kernel row regardless of hit while still paying the (slots, n)
    buffer scatter per task — strictly worse than no cache."""
    if engine is None:
        return None
    if isinstance(engine, str):
        engine = KE.EngineConfig(backend=engine)
    if isinstance(engine, KE.EngineConfig) and engine.cache_slots:
        return dataclasses.replace(engine, cache_slots=0)
    return engine


class OvOFit(NamedTuple):
    alpha: jax.Array      # (C, n_task)
    b: jax.Array          # (C,)
    n_iter: jax.Array     # (C,)
    converged: jax.Array  # (C,) bool (always True for GD: fixed steps)


def _fit_many_smo(x, y, mask, *, cfg: smo_mod.SMOConfig,
                  kernel: K.KernelParams,
                  engine: Optional[KE.EngineConfig | str] = None) -> OvOFit:
    """vmap of the binary solver over a stacked task axis."""
    engine = _batched_engine(engine)

    def one(xt, yt, mt):
        r = smo_mod.binary_smo(xt, yt, mt, cfg=cfg, kernel=kernel,
                               engine=engine)
        return OvOFit(r.alpha, r.b, r.n_iter, r.converged)
    return jax.vmap(one)(x, y, mask)


def _fit_many_gd(x, y, mask, *, cfg: gd_mod.GDConfig,
                 kernel: K.KernelParams,
                 engine: Optional[KE.EngineConfig | str] = None) -> OvOFit:
    def one(xt, yt, mt):
        r = gd_mod.binary_gd(xt, yt, mt, cfg=cfg, kernel=kernel,
                             engine=engine)
        return OvOFit(r.alpha, r.b, r.n_iter,
                      jnp.asarray(True))
    return jax.vmap(one)(x, y, mask)


def distributed_ovo_fit(tasks: OvOTasks,
                        mesh: Mesh,
                        worker_axes: tuple[str, ...] = ("workers",),
                        *,
                        solver: str = "smo",
                        smo_cfg: smo_mod.SMOConfig = smo_mod.SMOConfig(),
                        gd_cfg: gd_mod.GDConfig = gd_mod.GDConfig(),
                        kernel: K.KernelParams = K.KernelParams(),
                        engine: Optional[KE.EngineConfig | str] = None
                        ) -> OvOFit:
    """Fit all OvO tasks, task axis sharded over ``worker_axes`` of ``mesh``.

    The task axis length must be divisible by the total worker count
    (use ``build_tasks(pad_tasks_to=n_workers)``).
    """
    n_workers = int(np.prod([mesh.shape[a] for a in worker_axes]))
    c_total = tasks.x.shape[0]
    if c_total % n_workers:
        raise ValueError(
            f"task count {c_total} not divisible by {n_workers} workers; "
            f"build tasks with pad_tasks_to={n_workers}")

    if solver == "smo":
        fit_local = partial(_fit_many_smo, cfg=smo_cfg, kernel=kernel,
                            engine=engine)
    elif solver == "gd":
        fit_local = partial(_fit_many_gd, cfg=gd_cfg, kernel=kernel,
                            engine=engine)
    else:
        raise ValueError(f"unknown solver {solver!r}")

    spec = P(worker_axes)
    fit = _shard_map(fit_local, mesh,
                     (spec, spec, spec),
                     OvOFit(spec, spec, spec, spec))
    fit = jax.jit(fit)

    sh = NamedSharding(mesh, spec)
    x = jax.device_put(jnp.asarray(tasks.x), sh)
    y = jax.device_put(jnp.asarray(tasks.y), sh)
    mask = jax.device_put(jnp.asarray(tasks.mask), sh)
    return fit(x, y, mask)


def vmapped_ovo_fit(tasks: OvOTasks, *, solver: str = "smo",
                    smo_cfg: smo_mod.SMOConfig = smo_mod.SMOConfig(),
                    gd_cfg: gd_mod.GDConfig = gd_mod.GDConfig(),
                    kernel: K.KernelParams = K.KernelParams(),
                    engine: Optional[KE.EngineConfig | str] = None
                    ) -> OvOFit:
    """Single-device stacked fit (no mesh) — the CUDA-only configuration."""
    x, y, mask = (jnp.asarray(tasks.x), jnp.asarray(tasks.y),
                  jnp.asarray(tasks.mask))
    if solver == "smo":
        return jax.jit(partial(_fit_many_smo, cfg=smo_cfg, kernel=kernel,
                               engine=engine))(x, y, mask)
    return jax.jit(partial(_fit_many_gd, cfg=gd_cfg, kernel=kernel,
                           engine=engine))(x, y, mask)


def sequential_ovo_fit(tasks: OvOTasks, *, solver: str = "gd",
                       smo_cfg: smo_mod.SMOConfig = smo_mod.SMOConfig(),
                       gd_cfg: gd_mod.GDConfig = gd_mod.GDConfig(),
                       kernel: K.KernelParams = K.KernelParams(),
                       engine: Optional[KE.EngineConfig | str] = None,
                       n_real_tasks: Optional[int] = None) -> OvOFit:
    """The paper's "Multi-Tensorflow": one session per task, sequentially.

    A Python loop of separately-dispatched solver calls — intentionally
    NOT vmapped/sharded, to reproduce the baseline's execution profile.
    The jitted solver is built ONCE outside the loop: every task has the
    same padded shape, so one trace serves all of them (the sequential
    dispatch profile is preserved; only redundant retraces went away).
    """
    c_total = tasks.x.shape[0] if n_real_tasks is None else n_real_tasks
    if solver == "gd":
        solve = jax.jit(partial(gd_mod.binary_gd, cfg=gd_cfg,
                                kernel=kernel, engine=engine))
    else:
        solve = jax.jit(partial(smo_mod.binary_smo, cfg=smo_cfg,
                                kernel=kernel, engine=engine))
    outs = []
    for t in range(c_total):
        xt = jnp.asarray(tasks.x[t])
        yt = jnp.asarray(tasks.y[t])
        mt = jnp.asarray(tasks.mask[t])
        r = solve(xt, yt, mt)
        if solver == "gd":
            outs.append(OvOFit(r.alpha, r.b, r.n_iter, jnp.asarray(True)))
        else:
            outs.append(OvOFit(r.alpha, r.b, r.n_iter, r.converged))
    stack = lambda *xs: jnp.stack(xs)
    return jax.tree.map(stack, *outs)
