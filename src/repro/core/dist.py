"""The "MPI layer": distributing independent OvO tasks over the mesh.

Paper Fig. 4 (``MPI-CUDA_multiSMO``): C = m(m-1)/2 binary problems are
statically partitioned over P workers, N = C/P problems each; every worker
runs the same binary-SMO program on its slice (SPMD); communication is
only the initial broadcast of data and the final gather of alphas.

JAX-native mapping:

  MPI rank            ->  a slice of the mesh worker axis / axes
  static partition    ->  task-axis sharding of (x, y, mask) via shard_map
  SPMD binary SMO     ->  vmap(binary_smo) inside the shard_map body
  MPI_Bcast / Gather  ->  in/out shardings (device_put in, addressable
                          gather out); NO collectives inside the solver
                          loop, exactly the paper's comm profile.

``fit_taskset`` is the general entry point: it consumes a strategy-built
``repro.core.multiclass.TaskSet`` plus a size-bucketed ``Schedule`` and
runs ONE vmapped / shard_mapped solver program PER BUCKET, each at its
own padded width — on imbalanced datasets this replaces the old
pad-everything-to-the-widest-pair layout whose FLOPs were mostly zeros.
Worker placement inside each bucket follows the schedule's greedy LPT
grid rather than blind ``C/P`` striping.

``shard`` adds the second parallelism axis from the paper — data-parallel
WITHIN one QP: ``shard="data"`` runs every task through
``smo.sharded_binary_smo`` (samples sharded over the mesh, collective
working-set selection), and ``shard="auto"`` picks per bucket — wide
buckets with fewer tasks than workers go data-parallel, the rest stay
task-parallel. The hybrid is what lets a 3-class problem with one huge
pair use all 8 devices instead of 3.

``vmapped_ovo_fit`` / ``distributed_ovo_fit`` survive as shims over
``fit_taskset``: they convert the legacy padded ``OvOTasks`` stack into
a TaskSet and run it under a single-bucket ``bucket_by="none"`` schedule
at the original padded width, preserving the old numerics exactly.

``sequential_ovo_fit`` is the "Multi-Tensorflow" side: one GD session per
task, executed one after another (the paper runs multiple TF sessions
sequentially).

Every fit entry point threads an optional ``engine`` (an ``EngineConfig``
or backend name from ``repro.core.kernel_engine``) down to the binary
solvers, so the per-task Gram strategy — dense, chunked + LRU row cache,
or Pallas-tiled — is chosen once at the top.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import gd as gd_mod
from repro.core import kernel_engine as KE
from repro.core import kernels as K
from repro.core import multiclass as MC
from repro.core import smo as smo_mod
from repro.core.ovo import OvOTasks

# version-compat shard_map wrapper now lives next to the sharded engine
_shard_map = KE.shard_map_compat

# fit_taskset(shard="auto") sends a bucket data-parallel only when its
# tasks are wide enough to amortize the per-iteration collectives AND too
# few to keep every worker busy under task parallelism
DATA_PARALLEL_MIN_WIDTH = 2048


def _batched_engine(engine):
    """Strip the LRU row cache for vmapped/sharded dispatch: a batched
    ``lax.cond`` executes both branches, so a cache lookup recomputes the
    kernel row regardless of hit while still paying the (slots, n)
    buffer scatter per task — strictly worse than no cache."""
    if engine is None:
        return None
    if isinstance(engine, str):
        engine = KE.EngineConfig(backend=engine)
    if isinstance(engine, KE.EngineConfig) and engine.cache_slots:
        return dataclasses.replace(engine, cache_slots=0)
    return engine


class OvOFit(NamedTuple):
    alpha: jax.Array      # (C, n_task)
    b: jax.Array          # (C,)
    n_iter: jax.Array     # (C,)
    converged: jax.Array  # (C,) bool (always True for GD: fixed steps)


def resolve_worker_count(mesh: Optional[Mesh],
                         worker_axes: tuple[str, ...]) -> int:
    """Worker count of a task-parallel layout: the product of the mesh
    extents over ``worker_axes`` (1 without a mesh). Validates the axis
    names up front — ``mesh.shape[axis]`` raises a bare ``KeyError``
    otherwise, which used to surface from ``shard="auto"`` as an opaque
    crash. Shared by ``fit_taskset`` and the ``SVC``/``SVR`` routing so
    the entry points cannot drift."""
    if mesh is None:
        return 1
    missing = tuple(a for a in worker_axes if a not in mesh.shape)
    if missing:
        raise ValueError(
            f"worker axes {missing} are not axes of the mesh "
            f"(mesh axes: {tuple(mesh.shape)}); pass worker_axes "
            f"matching the mesh (make_shard_mesh's default axis is "
            f"'shards')")
    return int(np.prod([mesh.shape[a] for a in worker_axes]))


def _fit_many_smo(x, y, mask, a0=None, *, cfg: smo_mod.SMOConfig,
                  kernel: K.KernelParams,
                  engine: Optional[KE.EngineConfig | str] = None) -> OvOFit:
    """vmap of the binary solver over a stacked task axis; ``a0`` is an
    optional stacked per-task warm start (cascade outer rounds)."""
    engine = _batched_engine(engine)
    if cfg.shrink_every:
        # adaptive shrinking targets the scalar-jit path: under vmap the
        # un-shrink lax.cond lowers to select and would run its chunked
        # matvec at EVERY convergence check of EVERY task (see the
        # kernel_engine module docs) — force it off for batched dispatch
        cfg = dataclasses.replace(cfg, shrink_every=0)

    def one(xt, yt, mt, a0t=None):
        r = smo_mod.binary_smo(xt, yt, mt, cfg=cfg, kernel=kernel,
                               engine=engine, alpha0=a0t)
        return OvOFit(r.alpha, r.b, r.n_iter, r.converged)
    if a0 is None:
        return jax.vmap(one)(x, y, mask)
    return jax.vmap(one)(x, y, mask, a0)


def _fit_many_svr(x, y, mask, a0=None, *, epsilon: float,
                  cfg: smo_mod.SMOConfig, kernel: K.KernelParams,
                  engine: Optional[KE.EngineConfig | str] = None) -> OvOFit:
    """vmap of the doubled epsilon-SVR solver over a stacked task axis.
    ``y`` holds real-valued targets; ``OvOFit.alpha`` carries the
    per-sample regression coefficients beta = alpha - alpha* (the raw
    doubled multipliers stay internal). ``a0`` is a stacked per-task
    BETA warm start, split into its canonical doubled decomposition."""
    engine = _batched_engine(engine)
    if cfg.shrink_every:
        cfg = dataclasses.replace(cfg, shrink_every=0)

    def one(xt, yt, mt, b0=None):
        a02 = None
        if b0 is not None:
            # traced under the bucketed _fit_many jit: b0 has scheduler
            # bucket width, not request width
            a02 = jnp.concatenate([jnp.maximum(b0, 0.0),  # repro: noqa[R001] -- traced inside the bucketed _fit_many jit; shapes are bucket widths
                                   jnp.maximum(-b0, 0.0)])  # repro: noqa[R001] -- traced inside the bucketed _fit_many jit; shapes are bucket widths
        r = smo_mod.svr_smo(xt, yt, mt, epsilon=epsilon, cfg=cfg,
                            kernel=kernel, engine=engine, alpha0=a02)
        return OvOFit(r.beta, r.b, r.n_iter, r.converged)
    if a0 is None:
        return jax.vmap(one)(x, y, mask)
    return jax.vmap(one)(x, y, mask, a0)


def _fit_many_gd(x, y, mask, *, cfg: gd_mod.GDConfig,
                 kernel: K.KernelParams,
                 engine: Optional[KE.EngineConfig | str] = None) -> OvOFit:
    def one(xt, yt, mt):
        r = gd_mod.binary_gd(xt, yt, mt, cfg=cfg, kernel=kernel,
                             engine=engine)
        return OvOFit(r.alpha, r.b, r.n_iter,
                      jnp.asarray(True))
    return jax.vmap(one)(x, y, mask)


@partial(jax.jit, static_argnames=("solver", "smo_cfg", "gd_cfg",
                                   "kernel", "engine", "svr_epsilon"))
def _fit_many(x, y, mask, a0=None, *, solver, smo_cfg, gd_cfg, kernel,
              engine, svr_epsilon=None):
    """Jitted stacked fit with all configs static: one compiled program
    per (config, bucket SHAPE) pair, shared across fit_taskset calls —
    a fresh ``jax.jit(partial(...))`` per call would retrace every
    bucket on every fit. ``svr_epsilon`` switches the tasks to the
    doubled epsilon-SVR spec (``y`` = targets, alpha out = beta)."""
    if svr_epsilon is not None:
        return _fit_many_svr(x, y, mask, a0, epsilon=svr_epsilon,
                             cfg=smo_cfg, kernel=kernel, engine=engine)
    if solver == "smo":
        return _fit_many_smo(x, y, mask, a0, cfg=smo_cfg, kernel=kernel,
                             engine=engine)
    return _fit_many_gd(x, y, mask, cfg=gd_cfg, kernel=kernel,
                        engine=engine)


@lru_cache(maxsize=64)
def _sharded_fit_many(mesh, worker_axes, solver, smo_cfg, gd_cfg, kernel,
                      engine, svr_epsilon=None, warm=False):
    """shard_map-wrapped jitted fit, cached per (mesh, config): jit keys
    its trace cache on the callable object, so rebuilding the wrapper
    inside the bucket loop would recompile every bucket on every call.
    ``warm`` switches to the 4-input (x, y, mask, alpha0) wrapper — the
    in_specs tuple must match the argument count."""
    fit_local = partial(_fit_many, solver=solver, smo_cfg=smo_cfg,
                        gd_cfg=gd_cfg, kernel=kernel, engine=engine,
                        svr_epsilon=svr_epsilon)
    spec = P(worker_axes)
    n_in = 4 if warm else 3
    return jax.jit(_shard_map(fit_local, mesh, (spec,) * n_in,
                              OvOFit(spec, spec, spec, spec)))


class TaskSetFit(NamedTuple):
    """Host-side results for a fitted TaskSet. Row ``t`` of ``alpha`` is
    valid up to ``sizes[t]`` (tasks were solved at their bucket width;
    storage pads to the widest task — cheap, it's only (C, max_k))."""

    alpha: np.ndarray      # (C, max_k) float32
    b: np.ndarray          # (C,) float32
    n_iter: np.ndarray     # (C,) int
    converged: np.ndarray  # (C,) bool
    sizes: np.ndarray      # (C,) int true task lengths


def _bucket_arrays(taskset: MC.TaskSet, bucket: MC.Bucket,
                   alpha0: Optional[np.ndarray] = None):
    """Stack one bucket's tasks into (P * slots, width, d) solver inputs,
    rows ordered so a worker-axis shard gives worker p exactly the tasks
    the LPT layout assigned it. Dummy slots (-1) are fully masked.
    ``alpha0`` is a (C, max_k) per-task warm-start matrix (TaskSetFit
    layout); the stacked (slots, width) warm starts come back as the
    fourth element (None when no warm start was given)."""
    ids = bucket.task_ids.reshape(-1)
    d = taskset.tasks[0].x.shape[1]
    xt = np.zeros((len(ids), bucket.width, d), np.float32)
    yt = np.zeros((len(ids), bucket.width), np.float32)
    mk = np.zeros((len(ids), bucket.width), bool)
    a0 = (None if alpha0 is None
          else np.zeros((len(ids), bucket.width), np.float32))
    for s, t in enumerate(ids):
        if t < 0:
            continue
        task = taskset.tasks[t]
        k = task.size
        xt[s, :k] = task.x
        yt[s, :k] = task.y
        mk[s, :k] = True
        if a0 is not None:
            a0[s, :k] = alpha0[t, :k]
    return xt, yt, mk, a0


def _data_parallel_bucket(taskset: MC.TaskSet, bucket: MC.Bucket, *,
                          mesh: Mesh, axis: str,
                          smo_cfg: smo_mod.SMOConfig,
                          kernel: K.KernelParams, engine):
    """Solve one bucket's tasks SEQUENTIALLY, each task sample-sharded
    over the whole mesh axis (``smo.sharded_binary_smo``). Every task is
    padded to the bucket width, so the bucket shares one compiled
    program. Returns results in ``_bucket_arrays`` slot order (dummy
    slots collapse: the grid is flattened to real task ids only)."""
    ids = [int(t) for t in bucket.task_ids.reshape(-1) if t >= 0]
    outs = {}
    for t in ids:
        task = taskset.tasks[t]
        k = task.size
        xt = np.zeros((bucket.width, task.x.shape[1]), np.float32)
        yt = np.zeros((bucket.width,), np.float32)
        mk = np.zeros((bucket.width,), bool)
        xt[:k], yt[:k], mk[:k] = task.x, task.y, True
        r = smo_mod.sharded_binary_smo(
            jnp.asarray(xt), jnp.asarray(yt), jnp.asarray(mk),
            mesh=mesh, axis=axis, cfg=smo_cfg, kernel=kernel,
            engine=engine)
        outs[t] = r
    return outs


def validate_data_shard(mesh, worker_axes, solver: str) -> None:
    """Hard requirements of the sample-sharded (``shard="data"``) path —
    shared by ``fit_taskset`` and ``SVC`` so the two entry points cannot
    drift. An explicit data request that can't be honored must raise,
    never silently degrade to a single-device task-parallel fit."""
    if mesh is None:
        raise ValueError("shard='data' needs a mesh to shard the sample "
                         "axis over (e.g. launch.mesh.make_shard_mesh)")
    if solver != "smo":
        raise ValueError("shard='data' requires solver='smo' (the GD "
                         "baseline has no sharded path)")
    if len(worker_axes) != 1:
        raise ValueError("shard='data' shards the sample axis over "
                         "exactly one mesh axis; got "
                         f"worker_axes={worker_axes}")
    if worker_axes[0] not in mesh.shape:
        raise ValueError(
            f"worker axis {worker_axes[0]!r} is not an axis of the mesh "
            f"(axes: {tuple(mesh.shape)}); pass worker_axes matching the "
            f"mesh (make_shard_mesh's default axis is 'shards')")


def _wants_data_parallel(shard: str, bucket: MC.Bucket, n_real: int,
                         n_workers: int, solver: str, mesh,
                         worker_axes, data_min_width: int) -> bool:
    """Per-bucket parallelism mode. Explicit ``shard="data"`` validates
    hard; ``"auto"`` goes data-parallel only where it wins — wide tasks
    (collectives amortized over O(width) row work) that are too few to
    fill the worker grid — and silently stays task-parallel elsewhere."""
    if shard == "data":
        validate_data_shard(mesh, worker_axes, solver)
        return True
    if shard == "task" or mesh is None or n_workers <= 1:
        return False
    # auto: hybrid per bucket
    return (solver == "smo" and len(worker_axes) == 1
            and bucket.width >= data_min_width and n_real < n_workers)


def fit_taskset(taskset: MC.TaskSet,
                schedule: Optional[MC.Schedule] = None,
                *,
                mesh: Optional[Mesh] = None,
                worker_axes: tuple[str, ...] = ("workers",),
                solver: str = "smo",
                smo_cfg: smo_mod.SMOConfig = smo_mod.SMOConfig(),
                gd_cfg: gd_mod.GDConfig = gd_mod.GDConfig(),
                kernel: K.KernelParams = K.KernelParams(),
                engine: Optional[KE.EngineConfig | str] = None,
                schedule_cfg: Optional[MC.ScheduleConfig] = None,
                shard: str = "task",
                data_min_width: int = DATA_PARALLEL_MIN_WIDTH,
                alpha0: Optional[np.ndarray] = None,
                svr_epsilon: Optional[float] = None
                ) -> TaskSetFit:
    """Fit every binary task of ``taskset``, one solver program per
    schedule bucket.

    Without ``mesh`` each bucket is vmapped on the local device; with a
    mesh the bucket's slot axis is sharded over ``worker_axes`` via
    shard_map (each worker receives the contiguous run of slots the LPT
    layout placed on it). ``schedule`` defaults to a fresh pow2-bucketed
    build; pass ``schedule_cfg`` to tune bucketing without prebuilding.

    ``shard`` picks the parallelism AXIS per bucket:

    * ``"task"`` (default) — independent tasks across workers, the
      paper's MPI_multiSMO layout.
    * ``"data"`` — every task solved one after another, its SAMPLE axis
      sharded over the whole mesh (``smo.sharded_binary_smo``); for few
      huge tasks that task parallelism can't balance (requires
      ``solver="smo"`` and a single worker axis).
    * ``"auto"`` — hybrid: a bucket goes data-parallel when its width is
      >= ``data_min_width`` AND it has fewer real tasks than workers
      (i.e. task parallelism would leave devices idle); small/plentiful
      buckets stay vmapped task-parallel.

    ``alpha0`` is an optional (C, max_k) per-task warm-start matrix in
    the ``TaskSetFit.alpha`` layout (the cascade feeds a previous
    round's solution back in); ``svr_epsilon`` switches every task to
    the doubled epsilon-SVR spec (task ``y`` = real targets, returned
    ``alpha`` = per-sample beta). Both are task-parallel SMO features:
    they require ``solver="smo"`` and never route data-parallel.
    """
    n_workers = resolve_worker_count(mesh, tuple(worker_axes))
    if (alpha0 is not None or svr_epsilon is not None):
        if solver != "smo":
            raise ValueError(
                "alpha0 warm starts / svr_epsilon tasks require "
                f"solver='smo' (got solver={solver!r})")
        if shard == "data":
            raise ValueError(
                "alpha0/svr_epsilon run on the task-parallel vmapped "
                "path only; shard='data' (sharded_binary_smo) has no "
                "warm-start or SVR-taskset support — use shard='task' "
                "or 'auto'")
    if schedule is None:
        cfg = schedule_cfg if schedule_cfg is not None else MC.ScheduleConfig()
        cfg = dataclasses.replace(cfg, n_workers=n_workers)
        schedule = MC.build_schedule(taskset.sizes, cfg)
    if schedule.n_workers != n_workers:
        raise ValueError(
            f"schedule laid out for {schedule.n_workers} workers but the "
            f"mesh provides {n_workers}")

    if solver not in ("smo", "gd"):
        raise ValueError(f"unknown solver {solver!r}")
    if shard not in ("task", "data", "auto"):
        raise ValueError(f"unknown shard mode {shard!r}; expected "
                         "'task', 'data' or 'auto'")
    if isinstance(engine, str):
        engine = KE.EngineConfig(backend=engine)
    cfgs = dict(solver=solver, smo_cfg=smo_cfg, gd_cfg=gd_cfg,
                kernel=kernel, engine=engine, svr_epsilon=svr_epsilon)

    sizes = taskset.sizes
    c = taskset.n_tasks
    alpha = np.zeros((c, int(sizes.max())), np.float32)
    b = np.zeros(c, np.float32)
    n_iter = np.zeros(c, np.int64)
    converged = np.zeros(c, bool)

    warmless = alpha0 is None and svr_epsilon is None
    for bucket in schedule.buckets:
        real_ids = bucket.task_ids.reshape(-1)
        real_ids = real_ids[real_ids >= 0]
        if warmless and _wants_data_parallel(
                shard, bucket, len(real_ids), n_workers, solver, mesh,
                worker_axes, data_min_width):
            outs = _data_parallel_bucket(
                taskset, bucket, mesh=mesh, axis=worker_axes[0],
                smo_cfg=smo_cfg, kernel=kernel, engine=engine)
            for t, r in outs.items():
                k = int(sizes[t])
                alpha[t, :k] = np.asarray(r.alpha)[:k]
                b[t] = float(r.b)
                n_iter[t] = int(r.n_iter)
                converged[t] = bool(r.converged)
            continue
        xt, yt, mk, a0 = _bucket_arrays(taskset, bucket, alpha0)
        if mesh is None:
            out = _fit_many(jnp.asarray(xt), jnp.asarray(yt),
                            jnp.asarray(mk),
                            None if a0 is None else jnp.asarray(a0),
                            **cfgs)
        else:
            fit = _sharded_fit_many(mesh, tuple(worker_axes),
                                    warm=a0 is not None, **cfgs)
            sh = NamedSharding(mesh, P(worker_axes))
            args = [jax.device_put(jnp.asarray(xt), sh),
                    jax.device_put(jnp.asarray(yt), sh),
                    jax.device_put(jnp.asarray(mk), sh)]
            if a0 is not None:
                args.append(jax.device_put(jnp.asarray(a0), sh))
            out = fit(*args)
        out = jax.tree.map(np.asarray, out)
        for s, t in enumerate(bucket.task_ids.reshape(-1)):
            if t < 0:
                continue
            k = int(sizes[t])
            alpha[t, :k] = out.alpha[s, :k]
            b[t] = out.b[s]
            n_iter[t] = out.n_iter[s]
            converged[t] = out.converged[s]
    return TaskSetFit(alpha=alpha, b=b, n_iter=n_iter, converged=converged,
                      sizes=sizes)


def taskset_from_ovo(tasks: OvOTasks) -> MC.TaskSet:
    """Legacy padded ``OvOTasks`` stack -> variable-length TaskSet.

    Fully-masked padding tasks (the ``pad_tasks_to`` dummies) are
    dropped — the scheduler re-creates worker-count padding as dummy
    slots on its own."""
    cls_index = {c: i for i, c in enumerate(tasks.classes)}
    out = []
    seen_empty = False
    for t in range(tasks.x.shape[0]):
        k = int(tasks.mask[t].sum())
        if k == 0:
            seen_empty = True
            continue
        if seen_empty:
            # the shims re-expand results positionally (alpha[:c_real]),
            # which is only correct when dropped dummies are TRAILING
            raise ValueError(
                f"fully-masked OvOTasks entry precedes real task {t}; "
                f"padding tasks must be trailing (ovo.build_tasks "
                f"pad_tasks_to appends them)")
        if not tasks.mask[t, :k].all():
            raise ValueError(f"OvOTasks mask for task {t} is not a "
                             f"prefix; cannot convert to a TaskSet")
        a, b = tasks.pairs[t]
        out.append(MC.BinaryTask(
            x=np.asarray(tasks.x[t, :k], np.float32),
            y=np.asarray(tasks.y[t, :k], np.float32),
            pos=cls_index[a], neg=cls_index[b]))
    return MC.TaskSet(tasks=tuple(out), classes=tasks.classes,
                      strategy="ovo")


def _ovo_fit_shim(tasks: OvOTasks, mesh, worker_axes, *, solver, smo_cfg,
                  gd_cfg, kernel, engine) -> OvOFit:
    """Run a legacy OvOTasks stack through fit_taskset at the original
    padded width (single bucket), re-expanding results to the old
    (c_total, n_task) layout."""
    c_total, n_task = tasks.y.shape
    taskset = taskset_from_ovo(tasks)
    fit = fit_taskset(
        taskset, mesh=mesh, worker_axes=worker_axes, solver=solver,
        smo_cfg=smo_cfg, gd_cfg=gd_cfg, kernel=kernel, engine=engine,
        schedule_cfg=MC.ScheduleConfig(bucket_by="none", pad_width=n_task))
    c_real = taskset.n_tasks
    alpha = np.zeros((c_total, n_task), np.float32)
    alpha[:c_real, :fit.alpha.shape[1]] = fit.alpha
    b = np.zeros(c_total, np.float32)
    b[:c_real] = fit.b
    n_iter = np.zeros(c_total, np.int32)
    n_iter[:c_real] = fit.n_iter
    converged = np.ones(c_total, bool)  # dummy tasks trivially converge
    converged[:c_real] = fit.converged
    return OvOFit(alpha=jnp.asarray(alpha), b=jnp.asarray(b),
                  n_iter=jnp.asarray(n_iter),
                  converged=jnp.asarray(converged))


def distributed_ovo_fit(tasks: OvOTasks,
                        mesh: Mesh,
                        worker_axes: tuple[str, ...] = ("workers",),
                        *,
                        solver: str = "smo",
                        smo_cfg: smo_mod.SMOConfig = smo_mod.SMOConfig(),
                        gd_cfg: gd_mod.GDConfig = gd_mod.GDConfig(),
                        kernel: K.KernelParams = K.KernelParams(),
                        engine: Optional[KE.EngineConfig | str] = None
                        ) -> OvOFit:
    """Legacy shim: fit a padded OvO stack, task axis sharded over
    ``worker_axes`` of ``mesh``, via ``fit_taskset``.

    The task axis length must be divisible by the total worker count
    (use ``build_tasks(pad_tasks_to=n_workers)``).
    """
    n_workers = resolve_worker_count(mesh, tuple(worker_axes))
    c_total = tasks.x.shape[0]
    if c_total % n_workers:
        raise ValueError(
            f"task count {c_total} not divisible by {n_workers} workers; "
            f"build tasks with pad_tasks_to={n_workers}")
    return _ovo_fit_shim(tasks, mesh, worker_axes, solver=solver,
                         smo_cfg=smo_cfg, gd_cfg=gd_cfg, kernel=kernel,
                         engine=engine)


def vmapped_ovo_fit(tasks: OvOTasks, *, solver: str = "smo",
                    smo_cfg: smo_mod.SMOConfig = smo_mod.SMOConfig(),
                    gd_cfg: gd_mod.GDConfig = gd_mod.GDConfig(),
                    kernel: K.KernelParams = K.KernelParams(),
                    engine: Optional[KE.EngineConfig | str] = None
                    ) -> OvOFit:
    """Legacy shim: single-device stacked fit (no mesh) — the CUDA-only
    configuration — via ``fit_taskset``."""
    return _ovo_fit_shim(tasks, None, ("workers",), solver=solver,
                         smo_cfg=smo_cfg, gd_cfg=gd_cfg, kernel=kernel,
                         engine=engine)


def sequential_ovo_fit(tasks: OvOTasks, *, solver: str = "gd",
                       smo_cfg: smo_mod.SMOConfig = smo_mod.SMOConfig(),
                       gd_cfg: gd_mod.GDConfig = gd_mod.GDConfig(),
                       kernel: K.KernelParams = K.KernelParams(),
                       engine: Optional[KE.EngineConfig | str] = None,
                       n_real_tasks: Optional[int] = None) -> OvOFit:
    """The paper's "Multi-Tensorflow": one session per task, sequentially.

    A Python loop of separately-dispatched solver calls — intentionally
    NOT vmapped/sharded, to reproduce the baseline's execution profile.
    The jitted solver is built ONCE outside the loop: every task has the
    same padded shape, so one trace serves all of them (the sequential
    dispatch profile is preserved; only redundant retraces went away).
    """
    c_total = tasks.x.shape[0] if n_real_tasks is None else n_real_tasks
    if solver == "gd":
        solve = jax.jit(partial(gd_mod.binary_gd, cfg=gd_cfg,  # repro: noqa[R001] -- paper-baseline reproduction: jit built once per call, outside the task loop
                                kernel=kernel, engine=engine))
    else:
        solve = jax.jit(partial(smo_mod.binary_smo, cfg=smo_cfg,  # repro: noqa[R001] -- paper-baseline reproduction: jit built once per call, outside the task loop
                                kernel=kernel, engine=engine))
    outs = []
    for t in range(c_total):
        xt = jnp.asarray(tasks.x[t])  # repro: noqa[R001] -- tasks pre-padded by build_tasks; every row has the same shape
        yt = jnp.asarray(tasks.y[t])  # repro: noqa[R001] -- tasks pre-padded by build_tasks; every row has the same shape
        mt = jnp.asarray(tasks.mask[t])  # repro: noqa[R001] -- tasks pre-padded by build_tasks; every row has the same shape
        r = solve(xt, yt, mt)
        if solver == "gd":
            outs.append(OvOFit(r.alpha, r.b, r.n_iter, jnp.asarray(True)))
        else:
            outs.append(OvOFit(r.alpha, r.b, r.n_iter, r.converged))
    stack = lambda *xs: jnp.stack(xs)
    return jax.tree.map(stack, *outs)
