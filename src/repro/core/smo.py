"""Parallel binary SMO — the paper's CUDA solver, adapted to TPU/JAX.

The paper (Fig. 3) launches one CUDA thread per training sample so that
every data-parallel stage of SMO runs on the device, and performs
convergence checks "on the host for every set of iterations on the
device". The TPU-native adaptation:

* the per-sample axis is vectorized (VPU lanes / Pallas VMEM tiles)
  instead of SIMT threads;
* working-set selection (the block-reduce argmax in CUDA) is a masked
  max/argmax reduction — optionally the fused Pallas ``kkt_select``
  kernel;
* the host-side convergence check becomes the predicate of a
  ``lax.while_loop`` whose body runs ``check_every`` SMO iterations
  (``lax.fori_loop``), mirroring the paper's device-iterations-between-
  checks structure without host round-trips (free scalar check on-chip).

The algorithm is first-order working-set selection SMO (Keerthi
modification 2, the same family as the GPU SVM implementations the paper
builds on):

  f_i = sum_j alpha_j y_j K_ij - y_i                (optimality gradient)
  I_up  = {i: (y_i=+1, a_i<C) or (y_i=-1, a_i>0)}
  I_low = {i: (y_i=+1, a_i>0) or (y_i=-1, a_i<C)}
  b_up = min_{I_up} f_i ;  b_low = max_{I_low} f_i
  converged  <=>  b_low <= b_up + 2 tol

Each iteration updates the maximal-violating pair (i_low, i_up) and then
updates the WHOLE f-cache with two kernel rows — the fully data-parallel
"one thread per sample" stage.

Everything is mask-aware so that one ``vmap``/``shard_map`` program can
drive many padded one-vs-one tasks (the MPI layer in ``core.dist``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import kernels as K

_EPS = 1e-8
_BIG = jnp.inf


@dataclasses.dataclass(frozen=True)
class SMOConfig:
    """Solver hyper-parameters (box constraint + stopping rule)."""

    C: float = 1.0
    tol: float = 1e-3
    max_iter: int = 100_000       # hard cap on SMO pair updates
    check_every: int = 32         # device iterations per convergence check
    precompute_gram: bool = True  # n<=~8k: keep the full Gram in memory
    use_pallas: bool = False      # route Gram/selection through Pallas ops
    selection: str = "first"      # first (paper) | second (WSS2, beyond-
                                  # paper: maximal-gain partner choice)


class SMOResult(NamedTuple):
    alpha: jax.Array      # (n,) Lagrange multipliers
    b: jax.Array          # () bias, decision = sum a_i y_i K(x_i, .) + b
    n_iter: jax.Array     # () pair updates actually applied
    converged: jax.Array  # () bool
    gap: jax.Array        # () final b_low - b_up duality-violation gap


class _State(NamedTuple):
    alpha: jax.Array
    f: jax.Array
    n_iter: jax.Array
    b_up: jax.Array
    b_low: jax.Array


def _selection(f, alpha, y, mask, c):
    """Working-set selection: (b_up, i_up, b_low, i_low).

    This is the reduction stage — CUDA block-reduce in the paper, a masked
    min/argmax on the vector unit here (or the Pallas ``kkt_select``
    kernel when routed through ``repro.kernels.ops``).

    Membership epsilon is RELATIVE to C: f32 residues (alpha ~ 1e-8 left
    over from a clipped update) must not count as movable, or the solver
    can cycle on a box-blocked maximal-violating pair forever.
    """
    eps = 1e-6 * c
    pos, neg = y > 0, y <= 0
    not_upper = alpha < c - eps    # can increase
    not_lower = alpha > eps        # can decrease
    up_mask = mask & ((pos & not_upper) | (neg & not_lower))
    low_mask = mask & ((pos & not_lower) | (neg & not_upper))
    f_up = jnp.where(up_mask, f, _BIG)
    f_low = jnp.where(low_mask, f, -_BIG)
    i_up = jnp.argmin(f_up)
    i_low = jnp.argmax(f_low)
    return f_up[i_up], i_up, f_low[i_low], i_low


def _smo_iteration(state: _State, *, x, y, mask, gram, row_fn,
                   cfg: SMOConfig, _kdiag=None):
    """One working-set pair update + full f-cache refresh.

    selection="first": maximal violating pair (the paper's GPU solver).
    selection="second" (WSS2, Fan et al. 2005): i = argmin_{I_up} f, then
    j maximizes the guaranteed objective gain (f_j - f_i)^2 / (2 eta_ij)
    over I_low — pays one already-needed kernel row, typically converges
    in ~2x fewer iterations.
    """
    alpha, f = state.alpha, state.f
    c = cfg.C
    b_up, i_up, b_low, i_low = _selection(f, alpha, y, mask, c)
    active = b_low > b_up + 2.0 * cfg.tol  # not yet converged

    j = i_up
    if gram is not None:
        row_j = gram[j]
    else:
        row_j = row_fn(x, x[j])
    k_jj = row_j[j]

    if cfg.selection == "second":
        # gain_l = (f_l - b_up)^2 / (2 eta_lj) over valid I_low partners
        eps = 1e-6 * c
        pos, neg = y > 0, y <= 0
        low_mask = mask & ((pos & (alpha > eps)) | (neg & (alpha < c - eps)))
        diag = jnp.diagonal(gram) if gram is not None else _kdiag
        eta_all = jnp.maximum(diag + k_jj - 2.0 * row_j, 1e-12)
        df = f - b_up
        gain = jnp.where(low_mask & (df > 0.0), df * df / eta_all, -jnp.inf)
        i = jnp.argmax(gain)
    else:
        i = i_low

    y_i, y_j = y[i], y[j]
    a_i, a_j = alpha[i], alpha[j]

    if gram is not None:
        row_i = gram[i]
    else:
        row_i = row_fn(x, x[i])
    k_ii = row_i[i]
    k_ij = row_i[j]
    # recompute the pair's violation for the update step size
    b_low_pair = f[i]
    b_up_pair = f[j]
    eta = jnp.maximum(k_ii + k_jj - 2.0 * k_ij, 1e-12)

    # unconstrained step on a_j, then clip to the box segment
    # (pair's own violation: == b_low - b_up under first-order selection)
    a_j_new = a_j + y_j * (b_low_pair - b_up_pair) / eta
    same = y_i == y_j
    lo = jnp.where(same, jnp.maximum(0.0, a_i + a_j - c), jnp.maximum(0.0, a_j - a_i))
    hi = jnp.where(same, jnp.minimum(c, a_i + a_j), jnp.minimum(c, c + a_j - a_i))
    a_j_new = jnp.clip(a_j_new, lo, hi)
    a_i_new = a_i + y_i * y_j * (a_j - a_j_new)

    # snap to exact bounds: f32 residues near 0/C would otherwise keep
    # dead multipliers inside I_up/I_low and stall working-set selection
    snap = 1e-6 * c
    a_j_new = jnp.where(a_j_new < snap, 0.0,
                        jnp.where(a_j_new > c - snap, c, a_j_new))
    a_i_new = jnp.where(a_i_new < snap, 0.0,
                        jnp.where(a_i_new > c - snap, c, a_i_new))

    d_i = jnp.where(active, a_i_new - a_i, 0.0)
    d_j = jnp.where(active, a_j_new - a_j, 0.0)

    alpha = alpha.at[i].add(d_i)
    alpha = alpha.at[j].add(d_j)
    # the "one thread per sample" stage: every sample updates its f entry
    f = f + d_i * y_i * row_i + d_j * y_j * row_j

    return _State(alpha=alpha,
                  f=f,
                  n_iter=state.n_iter + active.astype(jnp.int32),
                  b_up=b_up,
                  b_low=b_low)


def binary_smo(x: jax.Array,
               y: jax.Array,
               mask: Optional[jax.Array] = None,
               *,
               cfg: SMOConfig = SMOConfig(),
               kernel: K.KernelParams = K.KernelParams(),
               gram: Optional[jax.Array] = None,
               row_fn: Optional[Callable] = None) -> SMOResult:
    """Solve one binary soft-margin SVM dual with parallel SMO.

    Args:
      x: (n, d) float training samples.
      y: (n,) labels in {+1, -1} (float or int).
      mask: (n,) bool validity mask — padded entries are never selected and
        keep alpha = 0 (used by the distributed OvO layer).
      gram: optional precomputed (n, n) Gram matrix. If None and
        ``cfg.precompute_gram``, it is computed here; otherwise kernel rows
        are computed on the fly (O(n d) memory).
      row_fn: optional ``(X, z) -> K(X, z)`` row function override (e.g.
        the Pallas tiled row kernel from ``repro.kernels.ops``).
    """
    n = x.shape[0]
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones((n,), dtype=bool)
    mask = mask & (jnp.abs(y) > 0.5)  # padded labels may be 0

    if cfg.use_pallas and kernel.name == "rbf":
        # route the Gram hot spot through the tiled Pallas kernels
        from repro.kernels import ops as pallas_ops
        if row_fn is None:
            row_fn = pallas_ops.gram_row_fn(gamma=kernel.gamma)
        if gram is None and cfg.precompute_gram:
            gram = pallas_ops.rbf_gram(x, x, gamma=kernel.gamma)
    if row_fn is None:
        gram_fn = K.make_gram_fn(kernel)
        row_fn = lambda xs, z: gram_fn(xs, z[None, :])[:, 0]
    if gram is None and cfg.precompute_gram:
        gram = K.make_gram_fn(kernel)(x, x)

    f0 = -y  # alpha = 0  =>  f_i = -y_i
    state0 = _State(alpha=jnp.zeros((n,), jnp.float32), f=f0,
                    n_iter=jnp.zeros((), jnp.int32),
                    b_up=jnp.asarray(-1.0, jnp.float32),
                    b_low=jnp.asarray(1.0, jnp.float32))

    kdiag = None
    if cfg.selection == "second" and gram is None:
        # K(x,x) diagonal for the WSS2 eta terms (RBF: exactly 1)
        if kernel.name == "rbf":
            kdiag = jnp.ones((n,), jnp.float32)
        else:
            gf = K.make_gram_fn(kernel)
            kdiag = jax.vmap(lambda r: gf(r[None], r[None])[0, 0])(x)
    iteration = partial(_smo_iteration, x=x, y=y, mask=mask, gram=gram,
                        row_fn=row_fn, cfg=cfg, _kdiag=kdiag)

    def cond(state: _State):
        return (state.b_low > state.b_up + 2.0 * cfg.tol) & (
            state.n_iter < cfg.max_iter)

    def body(state: _State):
        # paper Fig. 3: run `check_every` device iterations between checks
        return jax.lax.fori_loop(0, cfg.check_every,
                                 lambda _, s: iteration(s), state)

    state = jax.lax.while_loop(cond, body, state0)
    # final selection for the reported gap / bias
    b_up, _, b_low, _ = _selection(state.f, state.alpha, y, mask, cfg.C)
    b = -(b_up + b_low) / 2.0
    return SMOResult(alpha=state.alpha * mask, b=b, n_iter=state.n_iter,
                     converged=b_low <= b_up + 2.0 * cfg.tol,
                     gap=b_low - b_up)


def decision_function(x_train, y_train, alpha, b, x_test, *,
                      kernel: K.KernelParams = K.KernelParams(),
                      gram_fn: Optional[Callable] = None) -> jax.Array:
    """f(z) = sum_i alpha_i y_i K(x_i, z) + b for each test row z."""
    if gram_fn is None:
        gram_fn = K.make_gram_fn(kernel)
    kmat = gram_fn(x_test.astype(jnp.float32), x_train.astype(jnp.float32))
    coef = (alpha * y_train.astype(jnp.float32))
    return kmat @ coef + b


def dual_objective(y, alpha, gram) -> jax.Array:
    """W(alpha) = 1'a - 1/2 a' (yy' * K) a — maximized by the dual SVM."""
    ay = alpha * y
    return jnp.sum(alpha) - 0.5 * ay @ (gram @ ay)
