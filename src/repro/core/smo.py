"""Parallel binary SMO — the paper's CUDA solver, adapted to TPU/JAX.

The paper (Fig. 3) launches one CUDA thread per training sample so that
every data-parallel stage of SMO runs on the device, and performs
convergence checks "on the host for every set of iterations on the
device". The TPU-native adaptation:

* the per-sample axis is vectorized (VPU lanes / Pallas VMEM tiles)
  instead of SIMT threads;
* working-set selection (the block-reduce argmax in CUDA) is a masked
  max/argmax reduction — optionally the fused Pallas ``kkt_select``
  kernel;
* the host-side convergence check becomes the predicate of a
  ``lax.while_loop`` whose body runs ``check_every`` SMO iterations
  (``lax.fori_loop``), mirroring the paper's device-iterations-between-
  checks structure without host round-trips (free scalar check on-chip).

The algorithm is first-order working-set selection SMO (Keerthi
modification 2, the same family as the GPU SVM implementations the paper
builds on):

  f_i = sum_j alpha_j y_j K_ij - y_i                (optimality gradient)
  I_up  = {i: (y_i=+1, a_i<C) or (y_i=-1, a_i>0)}
  I_low = {i: (y_i=+1, a_i>0) or (y_i=-1, a_i<C)}
  b_up = min_{I_up} f_i ;  b_low = max_{I_low} f_i
  converged  <=>  b_low <= b_up + 2 tol

Each iteration updates the maximal-violating pair (i_low, i_up) and then
updates the WHOLE f-cache with two kernel rows — the fully data-parallel
"one thread per sample" stage.

All Gram access goes through a ``repro.core.kernel_engine.KernelEngine``
(dense precomputed, chunked on-the-fly with an LRU row cache, or
Pallas-tiled); the old ``gram=`` / ``row_fn=`` / ``use_pallas`` plumbing
survives as deprecation shims that resolve to an engine. With
``cfg.shrink_every > 0`` the solver runs mask-aware adaptive shrinking:
bound-pinned samples outside the violation corridor are frozen out of
selection and f-cache updates, and a final un-shrunk KKT re-check (one
chunked ``engine.matvec``) gates the reported convergence.

Everything is mask-aware so that one ``vmap``/``shard_map`` program can
drive many padded one-vs-one tasks (the MPI layer in ``core.dist``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import kernel_engine as KE
from repro.core import kernels as K

_EPS = 1e-8
_BIG = jnp.inf


@dataclasses.dataclass(frozen=True)
class SMOConfig:
    """Solver hyper-parameters (box constraint + stopping rule)."""

    C: float = 1.0
    tol: float = 1e-3
    max_iter: int = 100_000       # hard cap on SMO pair updates
    check_every: int = 32         # device iterations per convergence check
    precompute_gram: bool = True  # legacy shim -> dense/chunked backend
    use_pallas: bool = False      # legacy shim -> pallas backend
    selection: str = "first"      # first (paper) | second (WSS2, beyond-
                                  # paper: maximal-gain partner choice)
    shrink_every: int = 0         # convergence checks between adaptive-
                                  # shrinking passes; 0 disables shrinking
    shrink_slack: float = 1.0     # freeze corridor slack, in units of tol


class SMOResult(NamedTuple):
    alpha: jax.Array      # (n,) Lagrange multipliers
    b: jax.Array          # () bias, decision = sum a_i y_i K(x_i, .) + b
    n_iter: jax.Array     # () pair updates actually applied
    converged: jax.Array  # () bool
    gap: jax.Array        # () final b_low - b_up duality-violation gap
    n_active: jax.Array   # () samples still active at exit (== n valid
                          # when shrinking is off)


class _State(NamedTuple):
    alpha: jax.Array
    f: jax.Array
    n_iter: jax.Array
    b_up: jax.Array
    b_low: jax.Array
    active: jax.Array   # (n,) bool adaptive-shrinking active set
    done: jax.Array     # () bool convergence decided (post un-shrunk check)
    checks: jax.Array   # () int32 outer convergence checks run
    cache: object       # engine row-cache state (None for dense)


def _selection(f, alpha, y, mask, c):
    """Working-set selection: (b_up, i_up, b_low, i_low).

    This is the reduction stage — CUDA block-reduce in the paper, a masked
    min/argmax on the vector unit here (or the Pallas ``kkt_select``
    kernel when routed through ``repro.kernels.ops``).

    Membership epsilon is RELATIVE to C: f32 residues (alpha ~ 1e-8 left
    over from a clipped update) must not count as movable, or the solver
    can cycle on a box-blocked maximal-violating pair forever.
    """
    eps = 1e-6 * c
    pos, neg = y > 0, y <= 0
    not_upper = alpha < c - eps    # can increase
    not_lower = alpha > eps        # can decrease
    up_mask = mask & ((pos & not_upper) | (neg & not_lower))
    low_mask = mask & ((pos & not_lower) | (neg & not_upper))
    f_up = jnp.where(up_mask, f, _BIG)
    f_low = jnp.where(low_mask, f, -_BIG)
    i_up = jnp.argmin(f_up)
    i_low = jnp.argmax(f_low)
    return f_up[i_up], i_up, f_low[i_low], i_low


def _shrink_active(f, alpha, y, mask, b_up, b_low, cfg: SMOConfig):
    """Samples that may still join a violating pair (LIBSVM-style).

    Freeze i when alpha_i is pinned at a bound AND its f lies beyond the
    current [b_up, b_low] corridor on its non-violating side (slack in
    units of tol): an I_up-only member with f > b_low has no I_low
    partner to violate with (it is KEPT while f <= b_low + slack), and
    symmetrically an I_low-only member is frozen once f < b_up - slack.
    Free (0 < a < C) samples are in both index sets and never frozen.
    """
    c = cfg.C
    eps = 1e-6 * c
    slack = cfg.shrink_slack * cfg.tol
    pos, neg = y > 0, y <= 0
    not_upper = alpha < c - eps
    not_lower = alpha > eps
    in_up = (pos & not_upper) | (neg & not_lower)
    in_low = (pos & not_lower) | (neg & not_upper)
    free = not_upper & not_lower
    keep_up = in_up & (f <= b_low + slack)
    keep_low = in_low & (f >= b_up - slack)
    return mask & (free | keep_up | keep_low)


def _smo_iteration(state: _State, *, y, mask, engine: KE.KernelEngine,
                   cfg: SMOConfig, diag=None, shrink: bool = False):
    """One working-set pair update + f-cache refresh over the active set.

    selection="first": maximal violating pair (the paper's GPU solver).
    selection="second" (WSS2, Fan et al. 2005): i = argmin_{I_up} f, then
    j maximizes the guaranteed objective gain (f_j - f_i)^2 / (2 eta_ij)
    over I_low — pays one already-needed kernel row, typically converges
    in ~2x fewer iterations.
    """
    alpha, f = state.alpha, state.f
    c = cfg.C
    sel_mask = (mask & state.active) if shrink else mask
    b_up, i_up, b_low, i_low = _selection(f, alpha, y, sel_mask, c)
    step_live = b_low > b_up + 2.0 * cfg.tol  # not yet converged

    j = i_up
    row_j, cache = engine.row(j, state.cache)
    k_jj = row_j[j]

    if cfg.selection == "second":
        # gain_l = (f_l - b_up)^2 / (2 eta_lj) over valid I_low partners
        eps = 1e-6 * c
        pos, neg = y > 0, y <= 0
        low_mask = sel_mask & ((pos & (alpha > eps))
                               | (neg & (alpha < c - eps)))
        eta_all = jnp.maximum(diag + k_jj - 2.0 * row_j, 1e-12)
        df = f - b_up
        gain = jnp.where(low_mask & (df > 0.0), df * df / eta_all, -jnp.inf)
        i = jnp.argmax(gain)
    else:
        i = i_low

    y_i, y_j = y[i], y[j]
    a_i, a_j = alpha[i], alpha[j]

    row_i, cache = engine.row(i, cache)
    k_ii = row_i[i]
    k_ij = row_i[j]
    # recompute the pair's violation for the update step size
    b_low_pair = f[i]
    b_up_pair = f[j]
    eta = jnp.maximum(k_ii + k_jj - 2.0 * k_ij, 1e-12)

    # unconstrained step on a_j, then clip to the box segment
    # (pair's own violation: == b_low - b_up under first-order selection)
    a_j_new = a_j + y_j * (b_low_pair - b_up_pair) / eta
    same = y_i == y_j
    lo = jnp.where(same, jnp.maximum(0.0, a_i + a_j - c), jnp.maximum(0.0, a_j - a_i))
    hi = jnp.where(same, jnp.minimum(c, a_i + a_j), jnp.minimum(c, c + a_j - a_i))
    a_j_new = jnp.clip(a_j_new, lo, hi)
    a_i_new = a_i + y_i * y_j * (a_j - a_j_new)

    # snap to exact bounds: f32 residues near 0/C would otherwise keep
    # dead multipliers inside I_up/I_low and stall working-set selection
    snap = 1e-6 * c
    a_j_new = jnp.where(a_j_new < snap, 0.0,
                        jnp.where(a_j_new > c - snap, c, a_j_new))
    a_i_new = jnp.where(a_i_new < snap, 0.0,
                        jnp.where(a_i_new > c - snap, c, a_i_new))

    d_i = jnp.where(step_live, a_i_new - a_i, 0.0)
    d_j = jnp.where(step_live, a_j_new - a_j, 0.0)

    alpha = alpha.at[i].add(d_i)
    alpha = alpha.at[j].add(d_j)
    # the "one thread per sample" stage: every active sample updates its
    # f entry (shrinking restricts the update to the active set; frozen
    # entries are reconstructed exactly at the un-shrink check). NOTE:
    # the float association (f + a) + b is load-bearing — it must match
    # across vmapped/sequential/sharded dispatch for bit-compatibility.
    if shrink:
        upd = d_i * y_i * row_i + d_j * y_j * row_j
        f = jnp.where(state.active, f + upd, f)
    else:
        f = f + d_i * y_i * row_i + d_j * y_j * row_j

    return state._replace(alpha=alpha,
                          f=f,
                          n_iter=state.n_iter + step_live.astype(jnp.int32),
                          b_up=b_up,
                          b_low=b_low,
                          cache=cache)


def _resolve_engine(x, kernel: K.KernelParams, cfg: SMOConfig,
                    engine, gram, row_fn) -> KE.KernelEngine:
    """Engine resolution incl. the legacy gram=/row_fn=/use_pallas shims."""
    if isinstance(engine, KE.KernelEngine):
        return engine
    if gram is not None or row_fn is not None:
        base = engine if isinstance(engine, KE.EngineConfig) else (
            KE.EngineConfig(backend=engine) if isinstance(engine, str)
            else KE.EngineConfig())
        return KE.make_engine(x, kernel, base, gram=gram, row_fn=row_fn)
    if engine is not None:  # EngineConfig or backend name
        return KE.make_engine(x, kernel, engine)
    # legacy SMOConfig flags
    if cfg.use_pallas and kernel.name == "rbf":
        if cfg.precompute_gram:
            from repro.kernels import ops as pallas_ops
            return KE.DenseKernelEngine(
                x, kernel, gram=pallas_ops.rbf_gram(x, x,
                                                    gamma=kernel.gamma))
        return KE.PallasKernelEngine(x, kernel)
    backend = "dense" if cfg.precompute_gram else "chunked"
    return KE.make_engine(x, kernel, KE.EngineConfig(backend=backend))


def binary_smo(x: jax.Array,
               y: jax.Array,
               mask: Optional[jax.Array] = None,
               *,
               cfg: SMOConfig = SMOConfig(),
               kernel: K.KernelParams = K.KernelParams(),
               engine: Optional[KE.KernelEngine | KE.EngineConfig | str] = None,
               gram: Optional[jax.Array] = None,
               row_fn: Optional[Callable] = None) -> SMOResult:
    """Solve one binary soft-margin SVM dual with parallel SMO.

    Args:
      x: (n, d) float training samples.
      y: (n,) labels in {+1, -1} (float or int).
      mask: (n,) bool validity mask — padded entries are never selected and
        keep alpha = 0 (used by the distributed OvO layer).
      engine: a bound ``KernelEngine``, an ``EngineConfig``, or a backend
        name ("dense" | "chunked" | "pallas" | "auto"). Owns all Gram
        computation.
      gram: DEPRECATED shim — precomputed (n, n) Gram; forces the dense
        engine backend.
      row_fn: DEPRECATED shim — ``(X, z) -> K(X, z)`` row override; forces
        the chunked engine backend.
    """
    n = x.shape[0]
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if mask is None:
        mask = jnp.ones((n,), dtype=bool)
    mask = mask & (jnp.abs(y) > 0.5)  # padded labels may be 0

    eng = _resolve_engine(x, kernel, cfg, engine, gram, row_fn)
    shrink = cfg.shrink_every > 0

    f0 = -y  # alpha = 0  =>  f_i = -y_i
    state0 = _State(alpha=jnp.zeros((n,), jnp.float32), f=f0,
                    n_iter=jnp.zeros((), jnp.int32),
                    b_up=jnp.asarray(-1.0, jnp.float32),
                    b_low=jnp.asarray(1.0, jnp.float32),
                    active=mask,
                    done=jnp.asarray(False),
                    checks=jnp.zeros((), jnp.int32),
                    cache=eng.init_cache())

    diag = eng.diag() if cfg.selection == "second" else None
    iteration = partial(_smo_iteration, y=y, mask=mask, engine=eng,
                        cfg=cfg, diag=diag, shrink=shrink)

    def cond(state: _State):
        return (~state.done) & (state.n_iter < cfg.max_iter)

    def body(state: _State):
        # paper Fig. 3: run `check_every` device iterations between checks
        state = jax.lax.fori_loop(0, cfg.check_every,
                                  lambda _, s: iteration(s), state)
        conv_active = state.b_low <= state.b_up + 2.0 * cfg.tol
        if not shrink:
            return state._replace(done=conv_active)
        state = state._replace(checks=state.checks + 1)

        def unshrink(s: _State):
            # exact gradient for ALL samples via one chunked matvec, then
            # the un-shrunk KKT re-check; resume on the full set if the
            # shrunk optimum does not survive it
            f_full = eng.matvec(s.alpha * y) - y
            b_up, _, b_low, _ = _selection(f_full, s.alpha, y, mask, cfg.C)
            return s._replace(f=f_full, active=mask,
                              done=b_low <= b_up + 2.0 * cfg.tol,
                              b_up=b_up, b_low=b_low)

        def maybe_shrink(s: _State):
            do = (s.checks % cfg.shrink_every) == 0
            shrunk = _shrink_active(s.f, s.alpha, y, mask, s.b_up,
                                    s.b_low, cfg) & s.active
            return s._replace(active=jnp.where(do, shrunk, s.active))

        return jax.lax.cond(conv_active, unshrink, maybe_shrink, state)

    state = jax.lax.while_loop(cond, body, state0)
    # final selection for the reported gap / bias — on the UN-shrunk set
    # (shrinking may leave frozen entries with a stale f if the iteration
    # cap fired mid-phase; reconstruct before reporting)
    f_final = eng.matvec(state.alpha * y) - y if shrink else state.f
    b_up, _, b_low, _ = _selection(f_final, state.alpha, y, mask, cfg.C)
    b = -(b_up + b_low) / 2.0
    n_active = jnp.sum((state.active & mask).astype(jnp.int32))
    return SMOResult(alpha=state.alpha * mask, b=b, n_iter=state.n_iter,
                     converged=b_low <= b_up + 2.0 * cfg.tol,
                     gap=b_low - b_up, n_active=n_active)


def decision_function(x_train, y_train, alpha, b, x_test, *,
                      kernel: K.KernelParams = K.KernelParams(),
                      gram_fn: Optional[Callable] = None,
                      engine: Optional[KE.KernelEngine | KE.EngineConfig | str]
                      = None) -> jax.Array:
    """f(z) = sum_i alpha_i y_i K(x_i, z) + b for each test row z.

    With ``engine`` the evaluation streams over test-row chunks through
    ``engine.decide`` (never materializing the (n_test, n_train) block
    for chunked backends); otherwise the legacy full cross-Gram path.
    """
    coef = alpha * y_train.astype(jnp.float32)
    if engine is not None:
        if not isinstance(engine, KE.KernelEngine):
            engine = KE.make_engine(
                jnp.asarray(x_train, jnp.float32), kernel, engine)
        return engine.decide(x_test, coef, b)
    if gram_fn is None:
        gram_fn = K.make_gram_fn(kernel)
    kmat = gram_fn(x_test.astype(jnp.float32), x_train.astype(jnp.float32))
    return kmat @ coef + b


def dual_objective(y, alpha, gram) -> jax.Array:
    """W(alpha) = 1'a - 1/2 a' (yy' * K) a — maximized by the dual SVM."""
    ay = alpha * y
    return jnp.sum(alpha) - 0.5 * ay @ (gram @ ay)
