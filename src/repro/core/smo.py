"""Parallel binary SMO — the paper's CUDA solver, adapted to TPU/JAX.

The paper (Fig. 3) launches one CUDA thread per training sample so that
every data-parallel stage of SMO runs on the device, and performs
convergence checks "on the host for every set of iterations on the
device". The TPU-native adaptation:

* the per-sample axis is vectorized (VPU lanes / Pallas VMEM tiles)
  instead of SIMT threads;
* working-set selection (the block-reduce argmax in CUDA) is a masked
  max/argmax reduction — optionally the fused Pallas ``kkt_select``
  kernel;
* the host-side convergence check becomes the predicate of a
  ``lax.while_loop`` whose body runs ``check_every`` SMO iterations
  (``lax.fori_loop``), mirroring the paper's device-iterations-between-
  checks structure without host round-trips (free scalar check on-chip).

The algorithm is first-order working-set selection SMO (Keerthi
modification 2, the same family as the GPU SVM implementations the paper
builds on):

  f_i = sum_j alpha_j y_j K_ij - y_i                (optimality gradient)
  I_up  = {i: (y_i=+1, a_i<C) or (y_i=-1, a_i>0)}
  I_low = {i: (y_i=+1, a_i>0) or (y_i=-1, a_i<C)}
  b_up = min_{I_up} f_i ;  b_low = max_{I_low} f_i
  converged  <=>  b_low <= b_up + 2 tol

Each iteration updates the maximal-violating pair (i_low, i_up) and then
updates the WHOLE f-cache with two kernel rows — the fully data-parallel
"one thread per sample" stage.

All Gram access goes through a ``repro.core.kernel_engine.KernelEngine``
(dense precomputed, chunked on-the-fly with an LRU row cache, or
Pallas-tiled); the old ``gram=`` / ``row_fn=`` / ``use_pallas`` plumbing
survives as deprecation shims that resolve to an engine. With
``cfg.shrink_every > 0`` the solver runs mask-aware adaptive shrinking:
bound-pinned samples outside the violation corridor are frozen out of
selection and f-cache updates, and a final un-shrunk KKT re-check (one
chunked ``engine.matvec``) gates the reported convergence.

Everything is mask-aware so that one ``vmap``/``shard_map`` program can
drive many padded one-vs-one tasks (the MPI layer in ``core.dist``).

``sharded_binary_smo`` is the complementary axis of parallelism: ONE
binary problem data-parallel across the mesh (samples sharded, selection
made globally exact by ``combine_selection`` — the paper's per-rank
block-reduce + MPI_Allreduce), for the single large QP that task
parallelism cannot help with.

The generalized QP core
-----------------------
Classification is just one instance of the box-constrained dual QP

    min_a  1/2 a' Q a + p' a    s.t.  sum_i y_i a_i = 0,  lo <= a <= hi

with Q_ij = y_i y_j K(x_i, x_j) (y is a sign vector, not necessarily a
class label). ``solve_qp`` / ``sharded_solve_qp`` take the explicit spec
``(p, lo, hi)``; ``binary_smo`` is the classification instance
(p = -1, box [0, C]) and ``svr_smo`` the epsilon-SVR instance via the
standard doubled-variable layout (Smola & Schoelkopf; LIBSVM): variables
beta = [alpha; alpha*] over the doubled sample matrix [x; x], signs
s = [+1; -1], linear term p = [eps - y; eps + y], box [0, C]. The
doubled Gram IS the Gram of the doubled sample matrix, so every
``KernelEngine`` backend (dense / chunked / pallas / sharded) and the
whole selection / pair-update / shrinking / sharded-collective machinery
serve regression unchanged. All internal stages work on the optimality
vector f_i = y_i * ((Q a)_i + p_i), which for classification reduces to
the familiar ``sum_j a_j y_j K_ij - y_i``.

``kkt_violation`` is the solver-independent optimality certificate over
the same spec: the smallest max per-sample KKT violation over all
choices of the equality multiplier (== half the (b_low - b_up) gap).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import kernel_engine as KE
from repro.core import kernels as K

_EPS = 1e-8
_BIG = jnp.inf


@dataclasses.dataclass(frozen=True)
class SMOConfig:
    """Solver hyper-parameters (box constraint + stopping rule)."""

    C: float = 1.0
    tol: float = 1e-3
    max_iter: int = 100_000       # hard cap on SMO pair updates
    check_every: int = 32         # device iterations per convergence check
    precompute_gram: bool = True  # legacy shim -> dense/chunked backend
    use_pallas: bool = False      # legacy shim -> pallas backend
    selection: str = "first"      # first (paper) | second (WSS2, beyond-
                                  # paper: maximal-gain partner choice)
    shrink_every: int = 0         # convergence checks between adaptive-
                                  # shrinking passes; 0 disables shrinking
    shrink_slack: float = 1.0     # freeze corridor slack, in units of tol


class SMOResult(NamedTuple):
    alpha: jax.Array      # (n,) Lagrange multipliers
    b: jax.Array          # () bias, decision = sum a_i y_i K(x_i, .) + b
    n_iter: jax.Array     # () pair updates actually applied
    converged: jax.Array  # () bool
    gap: jax.Array        # () final b_low - b_up duality-violation gap
    n_active: jax.Array   # () samples still active at exit (== n valid
                          # when shrinking is off)


class _State(NamedTuple):
    alpha: jax.Array
    f: jax.Array
    n_iter: jax.Array
    b_up: jax.Array
    b_low: jax.Array
    active: jax.Array   # (n,) bool adaptive-shrinking active set
    done: jax.Array     # () bool convergence decided (post un-shrunk check)
    checks: jax.Array   # () int32 outer convergence checks run
    cache: object       # engine row-cache state (None for dense)


def _selection(f, alpha, y, mask, lo, hi):
    """Working-set selection: (b_up, i_up, b_low, i_low).

    This is the reduction stage — CUDA block-reduce in the paper, a masked
    min/argmax on the vector unit here (or the Pallas ``kkt_select``
    kernel when routed through ``repro.kernels.ops``).

    ``lo`` / ``hi`` are the (broadcastable, possibly per-sample) box
    bounds of the QP spec. Membership epsilon is RELATIVE to the box
    width: f32 residues (alpha ~ 1e-8 left over from a clipped update)
    must not count as movable, or the solver can cycle on a box-blocked
    maximal-violating pair forever.
    """
    eps = 1e-6 * (hi - lo)
    pos, neg = y > 0, y <= 0
    not_upper = alpha < hi - eps    # can increase
    not_lower = alpha > lo + eps    # can decrease
    up_mask = mask & ((pos & not_upper) | (neg & not_lower))
    low_mask = mask & ((pos & not_lower) | (neg & not_upper))
    f_up = jnp.where(up_mask, f, _BIG)
    f_low = jnp.where(low_mask, f, -_BIG)
    i_up = jnp.argmin(f_up)
    i_low = jnp.argmax(f_low)
    return f_up[i_up], i_up, f_low[i_low], i_low


def _pair_update(a_i, a_j, y_i, y_j, f_i, f_j, k_ii, k_jj, k_ij,
                 lo_i, hi_i, lo_j, hi_j):
    """Scalar two-multiplier update for the working pair (i, j).

    Unconstrained Newton step on a_j along the pair's violation
    (f_i - f_j == b_low - b_up under first-order selection), clipped to
    the segment the equality constraint cuts out of the box
    [lo_i, hi_i] x [lo_j, hi_j], with exact-bound snapping: f32 residues
    near the bounds would otherwise keep dead multipliers inside
    I_up/I_low and stall working-set selection. Shared verbatim by the
    single-device and sharded iterations — this is what keeps their
    numerics identical. (At the classification box [0, C] every
    expression below reduces bit-for-bit to the pre-QP-spec form.)
    """
    eta = jnp.maximum(k_ii + k_jj - 2.0 * k_ij, 1e-12)
    a_j_new = a_j + y_j * (f_i - f_j) / eta
    same = y_i == y_j
    # same sign: a_i + a_j is conserved; opposite: a_j - a_i is conserved
    lo_seg = jnp.where(same, jnp.maximum(lo_j, a_i + a_j - hi_i),
                       jnp.maximum(lo_j, lo_i + a_j - a_i))
    hi_seg = jnp.where(same, jnp.minimum(hi_j, a_i + a_j - lo_i),
                       jnp.minimum(hi_j, hi_i + a_j - a_i))
    a_j_new = jnp.clip(a_j_new, lo_seg, hi_seg)
    a_i_new = a_i + y_i * y_j * (a_j - a_j_new)

    snap_i = 1e-6 * (hi_i - lo_i)
    snap_j = 1e-6 * (hi_j - lo_j)
    a_j_new = jnp.where(a_j_new < lo_j + snap_j, lo_j,
                        jnp.where(a_j_new > hi_j - snap_j, hi_j, a_j_new))
    a_i_new = jnp.where(a_i_new < lo_i + snap_i, lo_i,
                        jnp.where(a_i_new > hi_i - snap_i, hi_i, a_i_new))
    return a_i_new, a_j_new


def _shrink_active(f, alpha, y, mask, b_up, b_low, lo, hi, cfg: SMOConfig):
    """Samples that may still join a violating pair (LIBSVM-style).

    Freeze i when alpha_i is pinned at a bound AND its f lies beyond the
    current [b_up, b_low] corridor on its non-violating side (slack in
    units of tol): an I_up-only member with f > b_low has no I_low
    partner to violate with (it is KEPT while f <= b_low + slack), and
    symmetrically an I_low-only member is frozen once f < b_up - slack.
    Free (lo < a < hi) samples are in both index sets and never frozen.
    """
    eps = 1e-6 * (hi - lo)
    slack = cfg.shrink_slack * cfg.tol
    pos, neg = y > 0, y <= 0
    not_upper = alpha < hi - eps
    not_lower = alpha > lo + eps
    in_up = (pos & not_upper) | (neg & not_lower)
    in_low = (pos & not_lower) | (neg & not_upper)
    free = not_upper & not_lower
    keep_up = in_up & (f <= b_low + slack)
    keep_low = in_low & (f >= b_up - slack)
    return mask & (free | keep_up | keep_low)


def kkt_violation(alpha, y, f, lo, hi, tol: float = 0.0, mask=None,
                  r=None):
    """Max per-sample KKT violation of the box QP at ``alpha`` — the
    solver-independent optimality certificate.

    ``f`` is the optimality vector f_i = y_i * ((Q alpha)_i + p_i)
    (recompute it from scratch — e.g. ``K @ (alpha * y) + y * p`` — to
    certify a solver rather than trust its own bookkeeping). KKT with
    equality multiplier r requires f_i >= r on I_up and f_i <= r on
    I_low; the returned scalar is the smallest achievable max violation

        min_r max_i [ (r - f_i)_+ on I_up,  (f_i - r)_+ on I_low ]
          == max(0, (b_low - b_up) / 2)

    so a solve that stopped at duality gap <= 2*tol certifies at <= tol.
    ``tol`` loosens the bound-membership epsilon (as a fraction of the
    box width) for solutions not exactly snapped to their bounds, e.g.
    projected GD; 0 keeps the solver's own 1e-6 relative rule. Returns 0
    when either index set is empty (any r beyond the occupied side
    certifies).

    ``r`` PINS the equality multiplier instead of minimizing over it:
    the violation becomes ``max((r - b_up)_+, (b_low - r)_+)``. This is
    the certificate for box QPs WITHOUT an equality constraint — the
    dual coordinate descent of ``repro.core.linear``, whose
    augmented-bias formulation absorbs the offset into the features, is
    optimal iff the r = 0 conditions hold.
    """
    alpha = jnp.asarray(alpha, jnp.float32)
    f = jnp.asarray(f, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), alpha.shape)
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), alpha.shape)
    if mask is None:
        mask = jnp.ones(alpha.shape, bool)
    eps = jnp.maximum(1e-6, tol) * (hi - lo)
    pos, neg = y > 0, y <= 0
    not_upper = alpha < hi - eps
    not_lower = alpha > lo + eps
    up_mask = mask & ((pos & not_upper) | (neg & not_lower))
    low_mask = mask & ((pos & not_lower) | (neg & not_upper))
    b_up = jnp.min(jnp.where(up_mask, f, _BIG))
    b_low = jnp.max(jnp.where(low_mask, f, -_BIG))
    if r is None:
        return jnp.maximum(0.0, (b_low - b_up) / 2.0)
    r = jnp.float32(r)
    return jnp.maximum(0.0, jnp.maximum(r - b_up, b_low - r))


def _smo_iteration(state: _State, *, y, mask, lo, hi,
                   engine: KE.KernelEngine, cfg: SMOConfig, diag=None,
                   shrink: bool = False):
    """One working-set pair update + f-cache refresh over the active set.

    selection="first": maximal violating pair (the paper's GPU solver).
    selection="second" (WSS2, Fan et al. 2005): i = argmin_{I_up} f, then
    j maximizes the guaranteed objective gain (f_j - f_i)^2 / (2 eta_ij)
    over I_low — pays one already-needed kernel row, typically converges
    in ~2x fewer iterations.
    """
    alpha, f = state.alpha, state.f
    sel_mask = (mask & state.active) if shrink else mask
    b_up, i_up, b_low, i_low = _selection(f, alpha, y, sel_mask, lo, hi)
    step_live = b_low > b_up + 2.0 * cfg.tol  # not yet converged

    j = i_up
    row_j, cache = engine.row(j, state.cache)
    k_jj = row_j[j]

    if cfg.selection == "second":
        # gain_l = (f_l - b_up)^2 / (2 eta_lj) over valid I_low partners
        eps = 1e-6 * (hi - lo)
        pos, neg = y > 0, y <= 0
        low_mask = sel_mask & ((pos & (alpha > lo + eps))
                               | (neg & (alpha < hi - eps)))
        eta_all = jnp.maximum(diag + k_jj - 2.0 * row_j, 1e-12)
        df = f - b_up
        gain = jnp.where(low_mask & (df > 0.0), df * df / eta_all, -jnp.inf)
        i = jnp.argmax(gain)
    else:
        i = i_low

    y_i, y_j = y[i], y[j]
    a_i, a_j = alpha[i], alpha[j]

    row_i, cache = engine.row(i, cache)
    k_ii = row_i[i]
    k_ij = row_i[j]
    a_i_new, a_j_new = _pair_update(a_i, a_j, y_i, y_j, f[i], f[j],
                                    k_ii, k_jj, k_ij,
                                    lo[i], hi[i], lo[j], hi[j])

    d_i = jnp.where(step_live, a_i_new - a_i, 0.0)
    d_j = jnp.where(step_live, a_j_new - a_j, 0.0)

    alpha = alpha.at[i].add(d_i)
    alpha = alpha.at[j].add(d_j)
    # the "one thread per sample" stage: every active sample updates its
    # f entry (shrinking restricts the update to the active set; frozen
    # entries are reconstructed exactly at the un-shrink check). NOTE:
    # the float association (f + a) + b is load-bearing — it must match
    # across vmapped/sequential/sharded dispatch for bit-compatibility.
    if shrink:
        upd = d_i * y_i * row_i + d_j * y_j * row_j
        f = jnp.where(state.active, f + upd, f)
    else:
        f = f + d_i * y_i * row_i + d_j * y_j * row_j

    return state._replace(alpha=alpha,
                          f=f,
                          n_iter=state.n_iter + step_live.astype(jnp.int32),
                          b_up=b_up,
                          b_low=b_low,
                          cache=cache)


def _resolve_engine(x, kernel: K.KernelParams, cfg: SMOConfig,
                    engine, gram, row_fn) -> KE.KernelEngine:
    """Engine resolution incl. the legacy gram=/row_fn=/use_pallas shims."""
    if isinstance(engine, KE.KernelEngine):
        return engine
    if gram is not None or row_fn is not None:
        base = engine if isinstance(engine, KE.EngineConfig) else (
            KE.EngineConfig(backend=engine) if isinstance(engine, str)
            else KE.EngineConfig())
        return KE.make_engine(x, kernel, base, gram=gram, row_fn=row_fn)
    if engine is not None:  # EngineConfig or backend name
        return KE.make_engine(x, kernel, engine)
    # legacy SMOConfig flags
    if cfg.use_pallas and kernel.name == "rbf":
        if cfg.precompute_gram:
            from repro.kernels import ops as pallas_ops
            return KE.DenseKernelEngine(
                x, kernel, gram=pallas_ops.rbf_gram(x, x,
                                                    gamma=kernel.gamma))
        return KE.PallasKernelEngine(x, kernel)
    backend = "dense" if cfg.precompute_gram else "chunked"
    return KE.make_engine(x, kernel, KE.EngineConfig(backend=backend))


def solve_qp(x: jax.Array,
             y: jax.Array,
             p: jax.Array,
             lo: jax.Array | float,
             hi: jax.Array | float,
             mask: Optional[jax.Array] = None,
             *,
             cfg: SMOConfig = SMOConfig(),
             kernel: K.KernelParams = K.KernelParams(),
             engine: Optional[KE.KernelEngine | KE.EngineConfig | str] = None,
             gram: Optional[jax.Array] = None,
             row_fn: Optional[Callable] = None,
             alpha0: Optional[jax.Array] = None) -> SMOResult:
    """Solve the general box-constrained dual QP with parallel SMO:

        min_a 1/2 a'Qa + p'a   s.t. sum_i y_i a_i = 0, lo <= a <= hi

    with Q_ij = y_i y_j K(x_i, x_j). ``binary_smo`` (classification:
    p = -1, box [0, C]) and ``svr_smo`` (epsilon-SVR via the doubled
    layout) are instances; every stage — selection, pair update,
    shrinking, engine-backed Gram access — is shared.

    Args:
      x: (n, d) float training samples.
      y: (n,) sign vector in {+1, -1} (float or int; 0 marks padding).
      p: (n,) linear term of the QP.
      lo / hi: box bounds, scalar or (n,) per-sample arrays.
      mask: (n,) bool validity mask — padded entries are never selected
        and keep alpha = 0 (used by the distributed OvO layer).
      engine: a bound ``KernelEngine``, an ``EngineConfig``, or a backend
        name ("dense" | "chunked" | "pallas" | "auto"). Owns all Gram
        computation.
      gram / row_fn: DEPRECATED shims — precomputed (n, n) Gram (forces
        the dense backend) / row override (forces chunked).
      alpha0: (n,) warm-start multipliers (e.g. a previous cascade
        round's solution). Clipped to the box and zeroed on masked
        entries; the f-cache is reconstructed with one engine matvec.
        The CALLER must keep the equality constraint's initial residue
        ``sum_i y_i alpha0_i`` at ~0: pair updates preserve it, so a
        biased start converges to a biased "optimum". None keeps the
        cold alpha = 0 start (bit-identical to the pre-warm-start
        solver).
    """
    n = x.shape[0]
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    p = jnp.asarray(p, jnp.float32)
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), (n,))
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), (n,))
    # the solver starts at alpha = 0 (f0 = y*p is only the gradient
    # there), so 0 must be inside the box; a box excluding 0 would
    # silently return an infeasible "optimum". Validate whenever the
    # bounds are concrete (they are for every shipped spec, even under
    # jit — constants created inside a trace stay concrete).
    if not (isinstance(lo, jax.core.Tracer)
            or isinstance(hi, jax.core.Tracer)):
        if bool(jnp.any((lo > 0.0) | (hi < 0.0))):
            raise ValueError(
                "solve_qp initializes alpha = 0, which must be feasible: "
                "need lo <= 0 <= hi elementwise (shift the variables to "
                "move the box)")
    if mask is None:
        mask = jnp.ones((n,), dtype=bool)
    mask = mask & (jnp.abs(y) > 0.5)  # padded signs may be 0

    eng = _resolve_engine(x, kernel, cfg, engine, gram, row_fn)
    shrink = cfg.shrink_every > 0

    if alpha0 is None:
        a0 = jnp.zeros((n,), jnp.float32)
        f0 = y * p  # alpha = 0  =>  f_i = y_i p_i (classification: -y_i)
    else:
        a0 = jnp.clip(jnp.asarray(alpha0, jnp.float32), lo, hi) * mask
        f0 = eng.matvec(a0 * y) + y * p
    state0 = _State(alpha=a0, f=f0,
                    n_iter=jnp.zeros((), jnp.int32),
                    b_up=jnp.asarray(-1.0, jnp.float32),
                    b_low=jnp.asarray(1.0, jnp.float32),
                    active=mask,
                    done=jnp.asarray(False),
                    checks=jnp.zeros((), jnp.int32),
                    cache=eng.init_cache())

    diag = eng.diag() if cfg.selection == "second" else None
    iteration = partial(_smo_iteration, y=y, mask=mask, lo=lo, hi=hi,
                        engine=eng, cfg=cfg, diag=diag, shrink=shrink)

    def cond(state: _State):
        return (~state.done) & (state.n_iter < cfg.max_iter)

    def body(state: _State):
        # paper Fig. 3: run `check_every` device iterations between checks
        state = jax.lax.fori_loop(0, cfg.check_every,
                                  lambda _, s: iteration(s), state)
        conv_active = state.b_low <= state.b_up + 2.0 * cfg.tol
        if not shrink:
            return state._replace(done=conv_active)
        state = state._replace(checks=state.checks + 1)

        def unshrink(s: _State):
            # exact gradient for ALL samples via one chunked matvec, then
            # the un-shrunk KKT re-check; resume on the full set if the
            # shrunk optimum does not survive it
            f_full = eng.matvec(s.alpha * y) + y * p
            b_up, _, b_low, _ = _selection(f_full, s.alpha, y, mask,
                                           lo, hi)
            return s._replace(f=f_full, active=mask,
                              done=b_low <= b_up + 2.0 * cfg.tol,
                              b_up=b_up, b_low=b_low)

        def maybe_shrink(s: _State):
            do = (s.checks % cfg.shrink_every) == 0
            shrunk = _shrink_active(s.f, s.alpha, y, mask, s.b_up,
                                    s.b_low, lo, hi, cfg) & s.active
            return s._replace(active=jnp.where(do, shrunk, s.active))

        return jax.lax.cond(conv_active, unshrink, maybe_shrink, state)

    state = jax.lax.while_loop(cond, body, state0)
    # final selection for the reported gap / bias — on the UN-shrunk set
    # (shrinking may leave frozen entries with a stale f if the iteration
    # cap fired mid-phase; reconstruct before reporting)
    f_final = eng.matvec(state.alpha * y) + y * p if shrink else state.f
    b_up, _, b_low, _ = _selection(f_final, state.alpha, y, mask, lo, hi)
    b = -(b_up + b_low) / 2.0
    n_active = jnp.sum((state.active & mask).astype(jnp.int32))
    return SMOResult(alpha=state.alpha * mask, b=b, n_iter=state.n_iter,
                     converged=b_low <= b_up + 2.0 * cfg.tol,
                     gap=b_low - b_up, n_active=n_active)


def _classification_spec(y, c):
    """(p, lo, hi) of the soft-margin classification dual: maximize
    1'a - 1/2 a'Qa over the box [0, C] — i.e. p = -1."""
    n = y.shape[0]
    return (jnp.full((n,), -1.0, jnp.float32),
            jnp.zeros((n,), jnp.float32),
            jnp.full((n,), c, jnp.float32))


def _svr_spec(y, epsilon, c):
    """Doubled-variable epsilon-SVR spec over [x; x]: beta = [alpha;
    alpha*], signs s = [+1; -1], p = [eps - y; eps + y], box [0, C].
    The combined regression coefficient is alpha - alpha*."""
    y = jnp.asarray(y, jnp.float32)
    n = y.shape[0]
    s = jnp.concatenate([jnp.ones((n,), jnp.float32),
                         -jnp.ones((n,), jnp.float32)])
    p = jnp.concatenate([epsilon - y, epsilon + y])
    lo = jnp.zeros((2 * n,), jnp.float32)
    hi = jnp.full((2 * n,), c, jnp.float32)
    return s, p, lo, hi


def binary_smo(x: jax.Array,
               y: jax.Array,
               mask: Optional[jax.Array] = None,
               *,
               cfg: SMOConfig = SMOConfig(),
               kernel: K.KernelParams = K.KernelParams(),
               engine: Optional[KE.KernelEngine | KE.EngineConfig | str] = None,
               gram: Optional[jax.Array] = None,
               row_fn: Optional[Callable] = None,
               alpha0: Optional[jax.Array] = None) -> SMOResult:
    """Solve one binary soft-margin SVM dual with parallel SMO — the
    classification instance of ``solve_qp``.

    Args:
      x: (n, d) float training samples.
      y: (n,) labels in {+1, -1} (float or int).
      mask: (n,) bool validity mask — padded entries are never selected and
        keep alpha = 0 (used by the distributed OvO layer).
      engine: a bound ``KernelEngine``, an ``EngineConfig``, or a backend
        name ("dense" | "chunked" | "pallas" | "auto"). Owns all Gram
        computation.
      gram: DEPRECATED shim — precomputed (n, n) Gram; forces the dense
        engine backend.
      row_fn: DEPRECATED shim — ``(X, z) -> K(X, z)`` row override; forces
        the chunked engine backend.
      alpha0: (n,) warm-start multipliers (see ``solve_qp``); None is
        the cold start.
    """
    y = y.astype(jnp.float32)
    p, lo, hi = _classification_spec(y, cfg.C)
    return solve_qp(x, y, p, lo, hi, mask, cfg=cfg, kernel=kernel,
                    engine=engine, gram=gram, row_fn=row_fn,
                    alpha0=alpha0)


class SVRResult(NamedTuple):
    beta: jax.Array       # (n,) alpha - alpha*: K(x_i, .) coefficients
    b: jax.Array          # () bias, prediction = sum_i beta_i K(x_i,.) + b
    alpha: jax.Array      # (2n,) raw doubled multipliers [alpha; alpha*]
    n_iter: jax.Array
    converged: jax.Array
    gap: jax.Array
    n_active: jax.Array


def _svr_result(r: SMOResult, n: int) -> SVRResult:
    return SVRResult(beta=r.alpha[:n] - r.alpha[n:], b=r.b, alpha=r.alpha,
                     n_iter=r.n_iter, converged=r.converged, gap=r.gap,
                     n_active=r.n_active)


def svr_smo(x: jax.Array,
            y: jax.Array,
            mask: Optional[jax.Array] = None,
            *,
            epsilon: float = 0.1,
            cfg: SMOConfig = SMOConfig(),
            kernel: K.KernelParams = K.KernelParams(),
            engine: Optional[KE.EngineConfig | str] = None,
            alpha0: Optional[jax.Array] = None) -> SVRResult:
    """Solve one epsilon-SVR dual with parallel SMO (doubled-variable
    instance of ``solve_qp``; see the module docstring).

    Args:
      x: (n, d) float training samples.
      y: (n,) real-valued targets.
      mask: (n,) bool validity mask, doubled internally.
      epsilon: half-width of the insensitive tube.
      engine: an ``EngineConfig`` or backend name; the engine is built on
        the DOUBLED (2n, d) sample matrix, so a pre-bound (n-row)
        ``KernelEngine`` is rejected.
      alpha0: (2n,) raw doubled warm-start multipliers [alpha; alpha*]
        (the layout of ``SVRResult.alpha``; build one from beta as
        ``[max(beta, 0); max(-beta, 0)]``). See ``solve_qp`` — the
        caller keeps ``sum_i beta0_i ~ 0``.
    """
    if isinstance(engine, KE.KernelEngine):
        raise ValueError(
            "svr_smo solves the doubled 2n-variable QP and must build its "
            "engine on [x; x]; pass an EngineConfig or backend name, not "
            f"a bound engine ({type(engine).__name__})")
    n = x.shape[0]
    x = jnp.asarray(x, jnp.float32)
    s, p, lo, hi = _svr_spec(y, epsilon, cfg.C)
    x2 = jnp.concatenate([x, x], axis=0)
    m2 = None
    if mask is not None:
        m2 = jnp.concatenate([mask, mask])
    r = solve_qp(x2, s, p, lo, hi, m2, cfg=cfg, kernel=kernel,
                 engine=engine, alpha0=alpha0)
    return _svr_result(r, n)


# --------------------------------------------------------------------------
# Sharded single-problem SMO: data-parallel over the SAMPLE axis.
#
# The paper's MPI-CUDA solver is data-parallel WITHIN one QP: every rank
# owns a row block of the Gram matrix, working-set selection is a per-rank
# block-reduce followed by an MPI_Allreduce, and the f-cache update is
# embarrassingly parallel over the rank's samples. The JAX analog below
# shards x / y / alpha / f over a mesh axis via shard_map:
#
#   per-rank block-reduce   ->  masked min/argmin on the LOCAL shard
#   MPI_Allreduce           ->  all_gather of P (value, global index)
#                               pairs + an identical local reduction
#                               (combine_selection) on every shard
#   Gram row block          ->  ShardedKernelEngine.row — x is replicated
#                               (all-gathered once), rows are local compute
#   scalar pair state       ->  one psum of owner-masked picks per step
#
# The combine preserves FIRST-OCCURRENCE argmin/argmax semantics (shards
# are contiguous sample blocks in axis order), so the selected violating
# pair — and therefore the whole optimization trajectory — is bit-for-bit
# the single-device one.
# --------------------------------------------------------------------------
def _combine_min(vals, idxs):
    s = jnp.argmin(vals)
    return vals[s], idxs[s]


def _combine_max(vals, idxs):
    s = jnp.argmax(vals)
    return vals[s], idxs[s]


def combine_selection(b_up_shards, i_up_shards, b_low_shards, i_low_shards):
    """Cross-shard WSS reduction: per-shard extrema (+ GLOBAL argindices),
    ordered by shard, -> global (b_up, i_up, b_low, i_low).

    Bit-exact vs. the unsharded ``_selection``: ``argmin`` over per-shard
    minima picks the FIRST shard attaining the global min, and the local
    ``argmin`` inside that shard picked its first local attainer, so the
    composed index is the first GLOBAL attainer — identical tie-breaking
    to ``jnp.argmin`` over the concatenated array (and symmetrically for
    the max side). This is the correctness-critical collective kernel;
    it is tested in isolation in ``tests/test_sharded_smo.py``.
    """
    b_up, i_up = _combine_min(b_up_shards, i_up_shards)
    b_low, i_low = _combine_max(b_low_shards, i_low_shards)
    return b_up, i_up, b_low, i_low


def _sharded_selection(f, alpha, y, mask, lo, hi, axis):
    """Globally-exact working-set selection from (n_local,) shards.

    One local ``_selection`` + two small all_gathers (P values, P global
    indices per side) + the replicated ``combine_selection`` — the
    MPI_Allreduce stage of the paper's Fig. 3, returning GLOBAL indices.
    """
    n_local = f.shape[0]
    b_up_l, i_up_l, b_low_l, i_low_l = _selection(f, alpha, y, mask,
                                                  lo, hi)
    base = jax.lax.axis_index(axis) * n_local
    vals = jax.lax.all_gather(jnp.stack([b_up_l, b_low_l]), axis)
    idxs = jax.lax.all_gather(jnp.stack([base + i_up_l, base + i_low_l]),
                              axis)
    return combine_selection(vals[:, 0], idxs[:, 0], vals[:, 1], idxs[:, 1])


def _owner_pick(vec, g, me):
    """Owner-masked entry of a sharded vector at GLOBAL index g: the
    owner shard contributes its value, everyone else 0 — summing the
    picks across shards (one stacked psum) replicates the scalar."""
    n_local = vec.shape[0]
    return jnp.where((g // n_local) == me, vec[g % n_local], 0.0)


def _sharded_smo_iteration(state: _State, *, y, mask, lo, hi,
                           engine: KE.ShardedKernelEngine, cfg: SMOConfig,
                           diag=None, shrink: bool = False):
    """One pair update with all per-sample state sharded over engine.axis.

    Mirrors ``_smo_iteration`` stage for stage; every divergence is a
    collective: selection all-gathers per-shard extrema, the pair's
    scalars (f, alpha, y, box bounds, kernel entries at i and j) arrive
    via ONE stacked psum of owner-masked picks, and the f-cache update
    applies the shared ``_pair_update`` deltas to the local slice of the
    two kernel rows.
    """
    axis = engine.axis
    alpha, f = state.alpha, state.f
    me = jax.lax.axis_index(axis)
    n_local = y.shape[0]
    sel_mask = (mask & state.active) if shrink else mask
    b_up, i_up, b_low, i_low = _sharded_selection(f, alpha, y, sel_mask,
                                                  lo, hi, axis)
    step_live = b_low > b_up + 2.0 * cfg.tol

    j = i_up  # global index
    row_j, cache = engine.row(j, state.cache)
    k_jj = jax.lax.psum(_owner_pick(row_j, j, me), axis)

    if cfg.selection == "second":
        # local gain block-reduce + the same first-occurrence combine
        eps = 1e-6 * (hi - lo)
        pos, neg = y > 0, y <= 0
        low_mask = sel_mask & ((pos & (alpha > lo + eps))
                               | (neg & (alpha < hi - eps)))
        eta_all = jnp.maximum(diag + k_jj - 2.0 * row_j, 1e-12)
        df = f - b_up
        gain = jnp.where(low_mask & (df > 0.0), df * df / eta_all, -jnp.inf)
        li = jnp.argmax(gain)
        _, i = _combine_max(jax.lax.all_gather(gain[li], axis),
                            jax.lax.all_gather(me * n_local + li, axis))
    else:
        i = i_low

    row_i, cache = engine.row(i, cache)
    # every scalar the update needs, in one collective
    picks = jnp.stack([
        _owner_pick(f, i, me), _owner_pick(f, j, me),
        _owner_pick(alpha, i, me), _owner_pick(alpha, j, me),
        _owner_pick(y, i, me), _owner_pick(y, j, me),
        _owner_pick(row_i, i, me), _owner_pick(row_i, j, me),
        _owner_pick(lo, i, me), _owner_pick(hi, i, me),
        _owner_pick(lo, j, me), _owner_pick(hi, j, me),
    ])
    (f_i, f_j, a_i, a_j, y_i, y_j, k_ii, k_ij,
     lo_i, hi_i, lo_j, hi_j) = jax.lax.psum(picks, axis)
    a_i_new, a_j_new = _pair_update(a_i, a_j, y_i, y_j, f_i, f_j,
                                    k_ii, k_jj, k_ij,
                                    lo_i, hi_i, lo_j, hi_j)

    d_i = jnp.where(step_live, a_i_new - a_i, 0.0)
    d_j = jnp.where(step_live, a_j_new - a_j, 0.0)

    alpha = alpha.at[i % n_local].add(
        jnp.where((i // n_local) == me, d_i, 0.0))
    alpha = alpha.at[j % n_local].add(
        jnp.where((j // n_local) == me, d_j, 0.0))
    # the "one thread per sample" stage, on this shard's samples only;
    # float association matches _smo_iteration branch for branch
    if shrink:
        upd = d_i * y_i * row_i + d_j * y_j * row_j
        f = jnp.where(state.active, f + upd, f)
    else:
        f = f + d_i * y_i * row_i + d_j * y_j * row_j

    return state._replace(alpha=alpha,
                          f=f,
                          n_iter=state.n_iter + step_live.astype(jnp.int32),
                          b_up=b_up,
                          b_low=b_low,
                          cache=cache)


def _sharded_smo_solve(x, y, p, lo, hi, mask, *, cfg: SMOConfig,
                       kernel: K.KernelParams, ecfg: KE.EngineConfig):
    """shard_map body: ``solve_qp`` with (n_local,) shards of
    x/y/p/lo/hi/mask.

    Scalars (b, n_iter, converged, gap, n_active) come out replicated;
    alpha comes out sharded. Structured like ``solve_qp`` — same
    while/fori convergence loop, same shrinking state machine — with the
    sharded iteration/selection and a psum'd n_active.
    """
    axis = ecfg.shard_axis
    y = y.astype(jnp.float32)
    mask = mask & (jnp.abs(y) > 0.5)  # padded signs are 0

    eng = KE.ShardedKernelEngine(x.astype(jnp.float32), kernel, ecfg)
    shrink = cfg.shrink_every > 0
    n_local = y.shape[0]

    f0 = y * p
    state0 = _State(alpha=jnp.zeros((n_local,), jnp.float32), f=f0,
                    n_iter=jnp.zeros((), jnp.int32),
                    b_up=jnp.asarray(-1.0, jnp.float32),
                    b_low=jnp.asarray(1.0, jnp.float32),
                    active=mask,
                    done=jnp.asarray(False),
                    checks=jnp.zeros((), jnp.int32),
                    cache=eng.init_cache())

    diag = eng.diag() if cfg.selection == "second" else None
    iteration = partial(_sharded_smo_iteration, y=y, mask=mask, lo=lo,
                        hi=hi, engine=eng, cfg=cfg, diag=diag,
                        shrink=shrink)

    def cond(state: _State):
        return (~state.done) & (state.n_iter < cfg.max_iter)

    def body(state: _State):
        state = jax.lax.fori_loop(0, cfg.check_every,
                                  lambda _, s: iteration(s), state)
        # b_up/b_low are replicated, so every shard takes the same branch
        conv_active = state.b_low <= state.b_up + 2.0 * cfg.tol
        if not shrink:
            return state._replace(done=conv_active)
        state = state._replace(checks=state.checks + 1)

        def unshrink(s: _State):
            f_full = eng.matvec(s.alpha * y) + y * p
            b_up, _, b_low, _ = _sharded_selection(f_full, s.alpha, y,
                                                   mask, lo, hi, axis)
            return s._replace(f=f_full, active=mask,
                              done=b_low <= b_up + 2.0 * cfg.tol,
                              b_up=b_up, b_low=b_low)

        def maybe_shrink(s: _State):
            do = (s.checks % cfg.shrink_every) == 0
            shrunk = _shrink_active(s.f, s.alpha, y, mask, s.b_up,
                                    s.b_low, lo, hi, cfg) & s.active
            return s._replace(active=jnp.where(do, shrunk, s.active))

        return jax.lax.cond(conv_active, unshrink, maybe_shrink, state)

    state = jax.lax.while_loop(cond, body, state0)
    f_final = eng.matvec(state.alpha * y) + y * p if shrink else state.f
    b_up, _, b_low, _ = _sharded_selection(f_final, state.alpha, y, mask,
                                           lo, hi, axis)
    b = -(b_up + b_low) / 2.0
    n_active = jax.lax.psum(
        jnp.sum((state.active & mask).astype(jnp.int32)), axis)
    return SMOResult(alpha=state.alpha * mask, b=b, n_iter=state.n_iter,
                     converged=b_low <= b_up + 2.0 * cfg.tol,
                     gap=b_low - b_up, n_active=n_active)


@lru_cache(maxsize=64)
def _sharded_smo_program(mesh: Mesh, axis: str, cfg: SMOConfig,
                         kernel: K.KernelParams, ecfg: KE.EngineConfig):
    """Jitted shard_map program, cached per (mesh, configs): rebuilding
    the wrapper per call would retrace on every solve (jit keys its cache
    on the callable object)."""
    body = partial(_sharded_smo_solve, cfg=cfg, kernel=kernel, ecfg=ecfg)
    spec, rep = P(axis), P()
    return jax.jit(KE.shard_map_compat(
        body, mesh, (spec,) * 6,
        SMOResult(spec, rep, rep, rep, rep, rep)))


def _resolve_sharded_cfg(engine, axis: str) -> KE.EngineConfig:
    if engine is None:
        return KE.EngineConfig(backend="sharded", shard_axis=axis)
    if isinstance(engine, str):
        engine = KE.EngineConfig(backend=engine)
    if isinstance(engine, KE.EngineConfig):
        # keep the tuning knobs (chunk, cache_slots, ...); the backend is
        # necessarily "sharded" inside the shard_map body
        return dataclasses.replace(engine, backend="sharded",
                                   shard_axis=axis)
    raise ValueError(
        "sharded_binary_smo builds its engine inside the shard_map body; "
        "pass an EngineConfig or backend name, not a bound engine "
        f"({type(engine).__name__})")


def sharded_solve_qp(x: jax.Array,
                     y: jax.Array,
                     p: jax.Array,
                     lo: jax.Array | float,
                     hi: jax.Array | float,
                     mask: Optional[jax.Array] = None,
                     *,
                     mesh: Mesh,
                     axis: str = "shards",
                     cfg: SMOConfig = SMOConfig(),
                     kernel: K.KernelParams = K.KernelParams(),
                     engine: Optional[KE.EngineConfig | str] = None
                     ) -> SMOResult:
    """Solve ONE box-constrained dual QP (the ``solve_qp`` problem) with
    the sample axis sharded over ``mesh.shape[axis]`` devices — the
    paper's data-parallel-within-one-QP MPI-CUDA configuration, for
    problems a single device can't hold (or can't hold fast enough).

    x / y / p / lo / hi / mask / alpha / f are sharded as equal
    contiguous blocks (n is zero-padded to a multiple of the shard
    count; padded rows are masked out and their alphas are identically
    0). Working-set selection is globally exact: the cross-shard
    reduction (``combine_selection``) is bit-identical to the unsharded
    argmin/argmax, so any divergence from single-device ``solve_qp``
    comes only from compiler-level float contraction differences in the
    Gram rows (the SPMD partitioner may fuse dots differently). In
    practice that means the SOLUTION matches — same support set,
    |delta b| well under tol, identical predictions (enforced by
    tests/test_sharded_smo.py) — while the iteration-by-iteration
    trajectory can occasionally differ by a few pair updates on its way
    to the same optimum.

    Scalar-jit semantics apply per shard: adaptive shrinking
    (``cfg.shrink_every``) and the LRU row cache both work here, unlike
    the vmapped task-parallel path.

    Returns a host-layout SMOResult with alpha trimmed back to (n,).
    """
    n = x.shape[0]
    n_shards = int(mesh.shape[axis])
    pad = (-n) % n_shards
    x = jnp.pad(jnp.asarray(x, jnp.float32), ((0, pad), (0, 0)))
    y = jnp.pad(jnp.asarray(y, jnp.float32), ((0, pad),))
    p = jnp.pad(jnp.asarray(p, jnp.float32), ((0, pad),))
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), (n,))
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), (n,))
    if bool(jnp.any((lo > 0.0) | (hi < 0.0))):  # see solve_qp
        raise ValueError(
            "sharded_solve_qp initializes alpha = 0, which must be "
            "feasible: need lo <= 0 <= hi elementwise")
    lo = jnp.pad(lo, ((0, pad),))
    hi = jnp.pad(hi, ((0, pad),))
    m = (jnp.ones((n,), bool) if mask is None
         else jnp.asarray(mask, bool))
    m = jnp.pad(m, ((0, pad),))
    ecfg = _resolve_sharded_cfg(engine, axis)
    fit = _sharded_smo_program(mesh, axis, cfg, kernel, ecfg)
    r = fit(x, y, p, lo, hi, m)
    return r._replace(alpha=r.alpha[:n])


def sharded_binary_smo(x: jax.Array,
                       y: jax.Array,
                       mask: Optional[jax.Array] = None,
                       *,
                       mesh: Mesh,
                       axis: str = "shards",
                       cfg: SMOConfig = SMOConfig(),
                       kernel: K.KernelParams = K.KernelParams(),
                       engine: Optional[KE.EngineConfig | str] = None
                       ) -> SMOResult:
    """Solve ONE binary SVM dual data-parallel over the mesh — the
    classification instance of ``sharded_solve_qp`` (see there for the
    sharding layout and exactness guarantees)."""
    y = jnp.asarray(y, jnp.float32)
    p, lo, hi = _classification_spec(y, cfg.C)
    return sharded_solve_qp(x, y, p, lo, hi, mask, mesh=mesh, axis=axis,
                            cfg=cfg, kernel=kernel, engine=engine)


def sharded_svr_smo(x: jax.Array,
                    y: jax.Array,
                    mask: Optional[jax.Array] = None,
                    *,
                    epsilon: float = 0.1,
                    mesh: Mesh,
                    axis: str = "shards",
                    cfg: SMOConfig = SMOConfig(),
                    kernel: K.KernelParams = K.KernelParams(),
                    engine: Optional[KE.EngineConfig | str] = None
                    ) -> SVRResult:
    """Solve ONE epsilon-SVR dual data-parallel over the mesh: the
    doubled 2n-variable QP of ``svr_smo`` through ``sharded_solve_qp``
    (the doubled sample axis is what gets sharded, so alpha and alpha*
    of the same sample may live on different shards — the collective
    machinery is index-agnostic and the selection stays globally
    exact)."""
    n = x.shape[0]
    x = jnp.asarray(x, jnp.float32)
    s, p, lo, hi = _svr_spec(y, epsilon, cfg.C)
    x2 = jnp.concatenate([x, x], axis=0)
    m2 = None
    if mask is not None:
        m2 = jnp.concatenate([mask, mask])
    r = sharded_solve_qp(x2, s, p, lo, hi, m2, mesh=mesh, axis=axis,
                         cfg=cfg, kernel=kernel, engine=engine)
    return _svr_result(r, n)


def decision_function(x_train, y_train, alpha, b, x_test, *,
                      kernel: K.KernelParams = K.KernelParams(),
                      gram_fn: Optional[Callable] = None,
                      engine: Optional[KE.KernelEngine | KE.EngineConfig | str]
                      = None) -> jax.Array:
    """f(z) = sum_i alpha_i y_i K(x_i, z) + b for each test row z.

    With ``engine`` the evaluation streams over test-row chunks through
    ``engine.decide`` (never materializing the (n_test, n_train) block
    for chunked backends); otherwise the legacy full cross-Gram path.
    """
    coef = alpha * y_train.astype(jnp.float32)
    if engine is not None:
        if not isinstance(engine, KE.KernelEngine):
            engine = KE.make_engine(
                jnp.asarray(x_train, jnp.float32), kernel, engine)
        return engine.decide(x_test, coef, b)
    if gram_fn is None:
        gram_fn = K.make_gram_fn(kernel)
    kmat = gram_fn(x_test.astype(jnp.float32), x_train.astype(jnp.float32))
    return kmat @ coef + b


def dual_objective(y, alpha, gram) -> jax.Array:
    """W(alpha) = 1'a - 1/2 a' (yy' * K) a — maximized by the dual SVM."""
    ay = alpha * y
    return jnp.sum(alpha) - 0.5 * ay @ (gram @ ay)


def qp_objective(alpha, y, p, gram) -> jax.Array:
    """W(a) = -(1/2 (ya)'K(ya) + p'a) — the maximized dual objective of
    the general box QP (``dual_objective`` is the p = -1 instance; for
    the SVR doubled layout pass the (2n, 2n) Gram of [x; x], i.e. K
    tiled 2x2)."""
    ay = alpha * y
    return -(0.5 * ay @ (gram @ ay) + p @ alpha)
