"""Multiclass strategy layer: task builders + the size-bucketed scheduler.

The paper's MPI layer (Fig. 4) statically partitions C = m(m-1)/2
one-vs-one subproblems over P workers, N = C/P each. The original
reproduction went one step further in the wrong direction: it padded
*every* task to the widest class pair and vmapped one giant stacked
program, so on imbalanced datasets most FLOPs are spent multiplying
zeros — the load-imbalance limiter that *Parallel Support Vector
Machines in Practice* (arXiv:1404.1066) identifies, attacked here the
way *Fast SVMs Using Parallel Adaptive Shrinking* (arXiv:1406.5161)
attacks it: work-aware distribution.

This module owns two orthogonal pieces:

Strategies (``MulticlassStrategy``)
    Turn an (x, y) multiclass problem into a ``TaskSet`` of independent
    binary subproblems, and turn the stacked binary decision values back
    into class predictions.

    * ``OneVsOneStrategy``  — C = m(m-1)/2 pairwise tasks; predict by
      majority ``vote`` (LIBSVM convention) or summed-``margin``.
    * ``OneVsRestStrategy`` — m tasks, class c vs the rest; predict by
      argmax of the decision values.

Scheduler (``build_schedule``)
    Group the variable-length binary tasks into a small number of shape
    buckets (next-power-of-two task lengths by default), so each bucket
    is vmapped at its own width instead of everything padding to the
    global max, and lay tasks out over mesh workers with a greedy
    longest-processing-time (LPT) assignment instead of blind ``C/P``
    striping. ``schedule_stats`` reports how many of the scheduled
    FLOPs are padding — the number the bucketed scheduler drives down.

``repro.core.dist.fit_taskset`` consumes (TaskSet, Schedule) and runs
one vmapped / shard_mapped solver program per bucket.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import numpy as np
import jax.numpy as jnp


# --------------------------------------------------------------------- tasks
class BinaryTask(NamedTuple):
    """One binary subproblem: samples, ±1 labels, and vote routing.

    ``pos``/``neg`` are indices into ``TaskSet.classes``: a positive
    decision credits ``pos``, a negative one credits ``neg`` (−1 for the
    OvR "rest" pseudo-class, which never receives credit).

    ``indices`` maps task rows back to the ORIGINAL training matrix
    (``x == X[indices]`` row for row). The low-rank multiclass path
    uses it to transform the full X once and gather each task's feature
    rows instead of re-running the feature map per overlapping subset.
    None (e.g. legacy ``taskset_from_ovo`` conversions, hand-built
    tasks) falls back to per-task transforms.
    """

    x: np.ndarray    # (k, d) float32
    y: np.ndarray    # (k,)   float32 in {+1, -1}
    pos: int
    neg: int
    indices: Optional[np.ndarray] = None   # (k,) int64 rows into X

    @property
    def size(self) -> int:
        return self.x.shape[0]


class TaskSet(NamedTuple):
    """Strategy-agnostic bundle of binary tasks (the unit ``fit_taskset``
    consumes). Tasks are variable-length; padding is the *scheduler's*
    decision, not the builder's."""

    tasks: tuple[BinaryTask, ...]
    classes: np.ndarray   # (m,) sorted unique labels
    strategy: str         # "ovo" | "ovr"

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    @property
    def sizes(self) -> np.ndarray:
        return np.array([t.size for t in self.tasks], np.int64)

    @property
    def pairs(self) -> np.ndarray:
        """(C, 2) class-index array: column 0 credited on decision > 0,
        column 1 on decision < 0 (−1 = no credit)."""
        return np.array([(t.pos, t.neg) for t in self.tasks], np.int64)


# ----------------------------------------------------------------- strategies
class MulticlassStrategy:
    """Interface: build the TaskSet, then decide classes from stacked
    binary decision values."""

    name = "base"

    def build_taskset(self, x: np.ndarray, y: np.ndarray) -> TaskSet:
        raise NotImplementedError

    def decide(self, df: jnp.ndarray, taskset: TaskSet,
               decision: str = "vote") -> jnp.ndarray:
        """df: (C, n_test) decision values -> (n_test,) class indices."""
        raise NotImplementedError


def _classes_and_members(x, y):
    x = np.asarray(x, np.float32)
    y = np.asarray(y)
    classes = np.unique(y)
    if len(classes) < 2:
        raise ValueError("need at least 2 classes")
    members = {i: np.where(y == c)[0] for i, c in enumerate(classes)}
    return x, classes, members


class OneVsOneStrategy(MulticlassStrategy):
    """C = m(m-1)/2 pairwise tasks (the paper's decomposition)."""

    name = "ovo"

    def build_taskset(self, x, y) -> TaskSet:
        x, classes, members = _classes_and_members(x, y)
        tasks = []
        m = len(classes)
        for a in range(m):
            for b in range(a + 1, m):
                ia, ib = members[a], members[b]
                xt = np.concatenate([x[ia], x[ib]], axis=0)
                yt = np.concatenate([np.ones(len(ia), np.float32),
                                     -np.ones(len(ib), np.float32)])
                tasks.append(BinaryTask(x=xt, y=yt, pos=a, neg=b,
                                        indices=np.concatenate([ia, ib])))
        return TaskSet(tasks=tuple(tasks), classes=classes,
                       strategy=self.name)

    def decide(self, df, taskset, decision="vote"):
        return decide_from_pairs(df, taskset.pairs, len(taskset.classes),
                                 self.name, decision)


class OneVsRestStrategy(MulticlassStrategy):
    """m tasks, class c (+1) vs all others (−1); argmax decision."""

    name = "ovr"

    def build_taskset(self, x, y) -> TaskSet:
        x, classes, members = _classes_and_members(x, y)
        tasks = []
        for c in range(len(classes)):
            yt = -np.ones(x.shape[0], np.float32)
            yt[members[c]] = 1.0
            tasks.append(BinaryTask(x=x, y=yt, pos=c, neg=-1,
                                    indices=np.arange(x.shape[0])))
        return TaskSet(tasks=tuple(tasks), classes=classes,
                       strategy=self.name)

    def decide(self, df, taskset, decision="vote"):
        return decide_from_pairs(df, taskset.pairs, len(taskset.classes),
                                 self.name, decision)


_STRATEGIES = {"ovo": OneVsOneStrategy, "ovr": OneVsRestStrategy}


def get_strategy(name: str | MulticlassStrategy) -> MulticlassStrategy:
    if isinstance(name, MulticlassStrategy):
        return name
    try:
        return _STRATEGIES[name]()
    except KeyError:
        raise ValueError(f"unknown multiclass strategy {name!r}; "
                         f"expected one of {sorted(_STRATEGIES)}") from None


# ------------------------------------------------------------ vote decisions
def decide_from_pairs(df: jnp.ndarray, pairs: np.ndarray, m: int,
                      strategy: str, decision: str = "vote") -> jnp.ndarray:
    """Class indices from stacked decision values + the (C, 2) credit
    table alone — the TaskSet-free decision shared by the strategies and
    the serving layer (``repro.serve``), which carries ``pairs`` in the
    packed artifact instead of the training-side TaskSet.

    OvR has one decision value per class (tasks are built in class
    order), so argmax IS the decision and ``decision`` is ignored there
    (it is an OvO concept).
    """
    if strategy == "ovr":
        return jnp.argmax(jnp.asarray(df), axis=0)
    if decision == "margin":
        return margin_decision(df, pairs, m)
    if decision == "vote":
        return vote_decision(df, pairs, m)
    raise ValueError(f"unknown OvO decision {decision!r}; "
                     "expected 'vote' or 'margin'")


def vote_decision(df: jnp.ndarray, pairs: np.ndarray, m: int) -> jnp.ndarray:
    """Vectorized majority vote: one pair of (t, C) @ (C, m) matmuls
    instead of a Python loop of C scatter-adds.

    df: (C, t) decision values; pairs: (C, 2) class indices.
    A tiny tanh(margin) term breaks ties toward the larger margin
    (LIBSVM-style stability); ``neg = -1`` rows (OvR) drop out of the
    one-hot.
    """
    df = jnp.asarray(df, jnp.float32)
    pos = (df > 0).astype(jnp.float32)            # (C, t)
    one_pos = _one_hot(pairs[:, 0], m)            # (C, m)
    one_neg = _one_hot(pairs[:, 1], m)
    # small integer counts — exact in f32 (the old loop mixed the 1e-6
    # tie term into the same accumulator, where it fell below f32 eps)
    votes = pos.T @ one_pos + (1.0 - pos).T @ one_neg       # (t, m)
    tie = jnp.tanh(df).T @ (one_pos - one_neg)              # (t, m)
    # lexicographic argmax: most votes first, largest tie-break margin
    # among the leaders second, lowest class index last (LIBSVM order)
    lead = votes >= jnp.max(votes, axis=1, keepdims=True) - 0.5
    return jnp.argmax(jnp.where(lead, tie, -jnp.inf), axis=1)


def margin_decision(df: jnp.ndarray, pairs: np.ndarray,
                    m: int) -> jnp.ndarray:
    """Summed-margin decision: each task contributes tanh(df) to its
    positive class and −tanh(df) to its negative class; argmax wins.
    Softer than voting — informative on ambiguous regions where vote
    counts tie."""
    df = jnp.asarray(df, jnp.float32)
    w = jnp.tanh(df)                              # (C, t)
    score = w.T @ _one_hot(pairs[:, 0], m) - w.T @ _one_hot(pairs[:, 1], m)
    return jnp.argmax(score, axis=1)


def _one_hot(idx: np.ndarray, m: int) -> jnp.ndarray:
    """(C,) class indices -> (C, m) one-hot; idx = -1 maps to all-zeros."""
    idx = np.asarray(idx, np.int64)
    out = np.zeros((len(idx), m), np.float32)
    valid = idx >= 0
    out[np.arange(len(idx))[valid], idx[valid]] = 1.0
    return jnp.asarray(out)


# ------------------------------------------------------------------ schedule
@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """Size-bucketing + worker-layout policy.

    bucket_by: "pow2" rounds each task length up to the next power of
               two (>= min_width) and groups equal widths — a handful of
               compiled programs, bounded <2x sample padding per task.
               "none" is the legacy layout: one bucket, every task
               padded to the global max (or ``pad_width``).
    min_width: floor on bucket widths, so tiny tasks share one program
               instead of compiling log2(min) distinct shapes.
    n_workers: mesh worker count the layout targets (1 = single device).
    pad_width: bucket_by="none" only — force the single bucket's width
               (the OvOTasks shims pass the pre-padded task width).
    """

    bucket_by: str = "pow2"
    min_width: int = 32
    n_workers: int = 1
    pad_width: int | None = None


class Bucket(NamedTuple):
    """One shape bucket: every task in it runs at sample-width ``width``.

    ``task_ids`` is the (n_workers, slots_per_worker) layout grid — row
    p lists the TaskSet indices worker p executes for this bucket, −1
    marking dummy slots (fully masked solves that only equalize the
    SPMD slot count)."""

    width: int
    task_ids: np.ndarray

    @property
    def n_slots(self) -> int:
        return self.task_ids.size


class Schedule(NamedTuple):
    buckets: tuple[Bucket, ...]
    n_workers: int


def bucket_width(size: int, cfg: ScheduleConfig) -> int:
    if cfg.bucket_by == "none":
        raise ValueError("bucket_by='none' has a single explicit width")
    if cfg.bucket_by != "pow2":
        raise ValueError(f"unknown bucket_by {cfg.bucket_by!r}; "
                         "expected 'pow2' or 'none'")
    return max(cfg.min_width, 1 << (max(size, 1) - 1).bit_length())


def task_cost(width: int) -> float:
    """Relative cost of one scheduled slot. SMO iteration count scales
    ~linearly with task size and each iteration pays O(width) kernel-row
    work, so width^2 is the standing estimate (exact constants don't
    matter — LPT only needs relative order)."""
    return float(width) ** 2


def build_schedule(sizes: Sequence[int],
                   cfg: ScheduleConfig = ScheduleConfig()) -> Schedule:
    """Bucket tasks by padded width, then greedy-LPT the layout.

    Buckets are processed largest-first; within the current bucket each
    task goes to the least-loaded worker (load = summed slot cost), so
    the heaviest work levels first and light buckets fill the cracks —
    the classic LPT 4/3-approximation, vs. the old blind C/P striping
    that could stack every wide pair on one worker.
    """
    sizes = np.asarray(sizes, np.int64)
    if sizes.ndim != 1 or len(sizes) == 0:
        raise ValueError("sizes must be a non-empty 1-D sequence")
    p = max(1, cfg.n_workers)

    if cfg.bucket_by == "none":
        width = int(cfg.pad_width if cfg.pad_width is not None
                    else sizes.max())
        if width < sizes.max():
            raise ValueError(f"pad_width {width} < max task size "
                             f"{sizes.max()}")
        by_width = {width: list(range(len(sizes)))}
    else:
        # cap at the global max task size: rounding the WIDEST task up to
        # the next power of two (or up to min_width, when every task is
        # tiny) would schedule more padding than the legacy pad-to-max
        # layout this replaces
        cap = int(sizes.max())
        by_width: dict[int, list[int]] = {}
        for t, s in enumerate(sizes):
            w = min(bucket_width(int(s), cfg), cap)
            by_width.setdefault(w, []).append(t)

    loads = np.zeros(p, np.float64)  # repro: noqa[R002] -- host-side LPT load accounting, never enters jit
    buckets = []
    for width in sorted(by_width, reverse=True):
        ids = sorted(by_width[width], key=lambda t: -sizes[t])
        per_worker: list[list[int]] = [[] for _ in range(p)]
        for t in ids:
            w = int(np.argmin(loads))
            per_worker[w].append(t)
            loads[w] += task_cost(width)
        slots = max(len(g) for g in per_worker)
        grid = np.full((p, slots), -1, np.int64)
        for w, g in enumerate(per_worker):
            grid[w, :len(g)] = g
            # dummy slots still execute a masked solve in SPMD lockstep
            loads[w] += task_cost(width) * (slots - len(g))
        buckets.append(Bucket(width=width, task_ids=grid))
    return Schedule(buckets=tuple(buckets), n_workers=p)


def schedule_stats(sizes: Sequence[int], schedule: Schedule) -> dict:
    """Padding accounting for a schedule: how much of the scheduled cost
    is real work vs. pad-to-width / dummy-slot waste."""
    sizes = np.asarray(sizes, np.int64)
    real = float(sum(task_cost(int(s)) for s in sizes))
    scheduled = 0.0
    for b in schedule.buckets:
        scheduled += task_cost(b.width) * b.n_slots
    return {
        "n_tasks": int(len(sizes)),
        "n_buckets": len(schedule.buckets),
        "bucket_widths": [int(b.width) for b in schedule.buckets],
        "scheduled_cost": scheduled,
        "real_cost": real,
        "padded_flop_fraction": 1.0 - real / scheduled if scheduled else 0.0,
    }
