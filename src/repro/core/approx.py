"""Low-rank kernel approximations: Nyström landmarks + random Fourier
features, behind the KernelEngine interface.

Exact SMO is O(n^2) in Gram work no matter how well the rows are tiled,
cached, or sharded (PRs 1-6); Tyree et al. (*Parallel SVMs in
Practice*) conclude that at scale approximate kernel methods dominate
exact parallel solvers. This module is that tier: both approximations
map the kernel problem to an EXPLICIT feature space ``Φ ∈ (n, k)``
with ``K ≈ Φ Φ^T``, after which training is a linear SVM solved by the
O(n·k) dual coordinate descent in ``repro.core.linear`` — nothing of
size (n, n) is ever materialized.

Nyström (any PSD kernel)
    Pick k landmark rows L (uniform subsample or k-means++ D^2-weighted
    seeding), form ``C = K(X, L)`` and ``W = K(L, L)``, and take
    ``Φ = C · U diag(clip(e)^{-1/2})`` from the eigendecomposition
    ``W = U diag(e) U^T`` — the spectral clip zeroes directions below
    ``e_max * 1e-6`` so a rank-deficient landmark set yields the
    pseudo-inverse map instead of noise blow-up. With landmarks == all
    points, ``Φ Φ^T = K K^+ K = K`` (exactly, up to the clip), the
    approximation-limit identity the tests pin.

RFF (RBF kernel only; Rahimi & Recht 2007)
    ``φ(z) = sqrt(2/k) cos(z Ω + b)`` with ``Ω ~ N(0, 2γ I)`` and
    ``b ~ U[0, 2π)``; ``E[φ(x)·φ(z)] = exp(-γ|x-z|^2)`` with
    O(1/sqrt(k)) Monte-Carlo error. The transform is one (n, d)x(d, k)
    matmul + cos — on TPU it runs through the fused Pallas feature-map
    kernel (``repro.kernels.ops.rff_features``, same tiling/autotune
    machinery as ``rbf_gram``); elsewhere the jnp path is used.

``LowRankKernelEngine`` exposes Φ through every KernelEngine method
(row/block/matvec/cross/decide are O(n k) matmuls against Φ), so the
exact solvers and the KKT-certificate harness run unchanged against the
APPROXIMATE Gram — ``engine="nystrom"|"rff"`` is a drop-in backend.
Note ``diag()`` is the feature-space diagonal ``|φ_i|^2`` (NOT exactly
1 for RBF): the engine represents K̃ = Φ Φ^T faithfully, approximation
error included.

All construction is jit-safe: landmark choice / frequency sampling use
``jax.random`` keyed on ``EngineConfig.seed``, so a fit is exactly
reproducible and an engine may be built on tracers inside a jitted
solver.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import kernel_engine as KE
from repro.core import kernels as K

# spectral clip for the Nyström eigenscale, relative to the largest
# eigenvalue of W: directions below it are dropped (pseudo-inverse)
EIG_CLIP_REL = 1e-6

LANDMARK_METHODS = ("uniform", "kmeans++")


# ---------------------------------------------------------- feature maps
class NystromMap:
    """``φ(z) = K(z, L) · proj`` with ``proj = U diag(clip(e)^{-1/2})``."""

    kind = "nystrom"

    def __init__(self, kernel: K.KernelParams, landmarks: jax.Array,
                 proj: jax.Array, *, gram_dtype: str = "fp32"):
        self.kernel = kernel
        self.landmarks = jnp.asarray(landmarks, jnp.float32)  # (k, d)
        self.proj = jnp.asarray(proj, jnp.float32)            # (k, k)
        self._gram_fn = K.make_gram_fn(kernel, compute_dtype=gram_dtype)

    @property
    def rank(self) -> int:
        return self.proj.shape[1]

    @property
    def n_features(self) -> int:
        return self.landmarks.shape[1]

    @property
    def arrays(self):
        """(a, b) serialization pair — see ``serve.artifact``."""
        return self.landmarks, self.proj

    def transform(self, z: jax.Array) -> jax.Array:
        z = jnp.asarray(z, jnp.float32)
        return self._gram_fn(z, self.landmarks) @ self.proj


class RFFMap:
    """``φ(z) = sqrt(2/k) cos(z Ω + phase)`` — RBF only.

    ``fused=None`` routes the transform through the Pallas feature-map
    kernel on TPU and the jnp reference path elsewhere (the Pallas
    interpreter on CPU is a correctness tool, not a fast path);
    ``True``/``False`` force it either way.
    """

    kind = "rff"

    def __init__(self, kernel: K.KernelParams, omega: jax.Array,
                 phase: jax.Array, *, gram_dtype: str = "fp32",
                 fused: bool | None = None):
        self.kernel = kernel
        self.omega = jnp.asarray(omega, jnp.float32)  # (d, k)
        self.phase = jnp.asarray(phase, jnp.float32)  # (k,)
        self.gram_dtype = gram_dtype
        self.fused = fused

    @property
    def rank(self) -> int:
        return self.omega.shape[1]

    @property
    def n_features(self) -> int:
        return self.omega.shape[0]

    @property
    def arrays(self):
        return self.omega, self.phase

    @property
    def scale(self) -> float:
        return math.sqrt(2.0 / self.rank)

    def transform(self, z: jax.Array) -> jax.Array:
        z = jnp.asarray(z, jnp.float32)
        fused = self.fused
        if fused is None:
            fused = jax.default_backend() == "tpu"
        if fused:
            from repro.kernels import ops
            return ops.rff_features(z, self.omega, self.phase,
                                    scale=self.scale,
                                    compute_dtype=self.gram_dtype)
        return self.scale * jnp.cos(z @ self.omega + self.phase)


def map_from_arrays(kind: str, kernel: K.KernelParams, a, b,
                    *, gram_dtype: str = "fp32"):
    """Rebuild a feature map from its serialized ``(kind, a, b)`` triple
    (the ``serve.artifact`` low-rank payload)."""
    if kind == "nystrom":
        return NystromMap(kernel, a, b, gram_dtype=gram_dtype)
    if kind == "rff":
        return RFFMap(kernel, a, b, gram_dtype=gram_dtype)
    raise ValueError(f"unknown feature-map kind {kind!r}; "
                     f"expected 'nystrom' or 'rff'")


# ------------------------------------------------------------- landmarks
def _sqdist_to(x: jax.Array, c: jax.Array) -> jax.Array:
    d = x - c[None, :]
    return jnp.sum(d * d, axis=1)


def select_landmarks(x: jax.Array, k: int, method: str,
                     key: jax.Array) -> jax.Array:
    """(k,) landmark row indices: "uniform" subsample or "kmeans++"
    D^2-weighted seeding (each next landmark drawn with probability
    proportional to its squared distance to the chosen set — the
    spread-out seeding that keeps W well-conditioned on clustered
    data). Both are jit-safe."""
    n = x.shape[0]
    if method == "uniform":
        return jax.random.permutation(key, n)[:k]
    if method != "kmeans++":
        raise ValueError(f"unknown landmark method {method!r}; "
                         f"expected one of {LANDMARK_METHODS}")
    k0, kloop = jax.random.split(key)
    i0 = jax.random.randint(k0, (), 0, n)
    idx0 = jnp.zeros((k,), jnp.int32).at[0].set(i0.astype(jnp.int32))
    d0 = _sqdist_to(x, x[i0])

    def body(j, carry):
        idx, d2, kk = carry
        kk, sub = jax.random.split(kk)
        # D^2 sampling via inverse-CDF; an all-zero d2 (k >= #distinct
        # points) degrades to picking the last index — harmless, the
        # spectral clip absorbs duplicate landmarks
        cum = jnp.cumsum(d2)
        u = jax.random.uniform(sub, (), jnp.float32) * cum[-1]
        nxt = jnp.clip(jnp.searchsorted(cum, u), 0, n - 1).astype(jnp.int32)
        idx = idx.at[j].set(nxt)
        return idx, jnp.minimum(d2, _sqdist_to(x, x[nxt])), kk

    idx, _, _ = jax.lax.fori_loop(1, k, body, (idx0, d0, kloop))
    return idx


# ---------------------------------------------------------- construction
def make_feature_map(x: jax.Array, kernel: K.KernelParams,
                     cfg: KE.EngineConfig):
    """Resolve ``EngineConfig(backend="nystrom"|"rff", rank, landmarks,
    seed)`` into a fitted feature map for sample matrix ``x``."""
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    key = jax.random.PRNGKey(cfg.seed)
    if cfg.backend == "rff":
        if kernel.name != "rbf":
            raise ValueError(
                f"engine='rff' approximates the RBF kernel only, got "
                f"kernel={kernel.name!r}; use engine='nystrom' for "
                f"arbitrary PSD kernels")
        kw, kp = jax.random.split(key)
        omega = (math.sqrt(2.0 * kernel.gamma)
                 * jax.random.normal(kw, (d, cfg.rank), jnp.float32))
        phase = jax.random.uniform(kp, (cfg.rank,), jnp.float32,
                                   0.0, 2.0 * math.pi)
        return RFFMap(kernel, omega, phase, gram_dtype=cfg.gram_dtype)
    if cfg.backend != "nystrom":
        raise ValueError(f"make_feature_map: not a low-rank backend "
                         f"{cfg.backend!r}; expected one of "
                         f"{KE.LOWRANK_BACKENDS}")
    k = min(cfg.rank, n)
    idx = select_landmarks(x, k, cfg.landmarks, key)
    landmarks = x[idx]
    gram_fn = K.make_gram_fn(kernel, compute_dtype=cfg.gram_dtype)
    w = gram_fn(landmarks, landmarks)
    e, u = jnp.linalg.eigh(w)
    clip = jnp.maximum(e[-1], 0.0) * EIG_CLIP_REL
    inv_sqrt = jnp.where(e > clip,
                         1.0 / jnp.sqrt(jnp.maximum(e, clip)), 0.0)
    proj = u * inv_sqrt[None, :]
    return NystromMap(kernel, landmarks, proj, gram_dtype=cfg.gram_dtype)


# ---------------------------------------------------------------- engine
class LowRankKernelEngine(KE.KernelEngine):
    """K̃ = Φ Φ^T behind the full KernelEngine interface.

    Every method is an O(n k) (or O(t k)) matmul against the resident
    feature matrix ``Φ (n, k)`` — no (n, n) object exists anywhere, so
    the exact solvers (SMO included) and the KKT-certificate harness
    run unchanged against the approximate Gram. The intended fast path
    for TRAINING is ``repro.core.linear`` directly on ``engine.phi``.
    """

    backend = "lowrank"

    def __init__(self, x, kernel, cfg: KE.EngineConfig = KE.EngineConfig()):
        super().__init__(x, kernel, cfg)
        self.fmap = make_feature_map(self.x, kernel, cfg)
        self.phi = self.fmap.transform(self.x)     # (n, k) resident

    @property
    def rank(self) -> int:
        return self.phi.shape[1]

    def full(self):
        if self.n > self.cfg.dense_limit:
            raise RuntimeError(
                f"LowRankKernelEngine.full(): refusing to materialize a "
                f"({self.n}, {self.n}) approximate Gram (dense_limit="
                f"{self.cfg.dense_limit}); use row()/block()/matvec()")
        return self.phi @ self.phi.T

    def diag(self):
        # the APPROXIMATE diagonal |phi_i|^2, not the exact K(x_i, x_i):
        # the engine represents K-tilde faithfully (module docstring)
        return jnp.sum(self.phi * self.phi, axis=1)

    def row(self, i, cache=None):
        return self.phi @ self.phi[i], cache

    def block(self, rows, cols):
        return self.phi[rows] @ self.phi[cols].T

    def cross(self, z):
        return self.fmap.transform(z) @ self.phi.T

    def matvec(self, v):
        return self.phi @ (self.phi.T @ v)

    def decide(self, z, coef, b=0.0):
        return self.fmap.transform(z) @ (self.phi.T @ coef) + b
