"""Public SVM API — sklearn-flavoured front end over the parallel solvers.

    clf = SVC(kernel="rbf", C=1.0, solver="smo")      # paper's CUDA path
    clf = SVC(kernel="rbf", C=1.0, solver="gd")       # paper's TF baseline
    clf = SVC(engine="chunked", shrink_every=4)       # n >> 8k training
    clf = SVC(engine="nystrom", rank=512)             # low-rank approx
    clf = SVC(engine="rff", rank=1024)                # random features
    clf = SVC(strategy="ovr")                         # one-vs-rest
    clf = SVC(decision="margin")                      # OvO summed margins
    clf = SVC(mesh=mesh, shard="data")                # samples sharded
    clf = SVC(mesh=mesh, shard="auto")                # hybrid per bucket
    clf = SVC(shard="cascade", cascade_shards=8)      # hierarchical cascade
    clf.fit(X, y)                                     # binary OR multiclass
    clf.predict(Xt); clf.score(Xt, yt)

    reg = SVR(kernel="rbf", C=1.0, epsilon=0.1)       # epsilon-SVR
    reg = SVR(solver="gd")                            # projected-GD dual
    reg = SVR(engine="chunked", shrink_every=4)       # large-n regression
    reg = SVR(engine="nystrom", rank=512)             # low-rank approx
    reg = SVR(mesh=mesh, shard="data")                # doubled axis sharded
    reg.fit(X, y).predict(Xt); reg.score(Xt, yt)      # R^2

``SVR`` rides the exact same stack as binary ``SVC``: the generalized
QP core (``smo.solve_qp`` with the doubled-variable epsilon-SVR spec),
every ``KernelEngine`` backend, adaptive shrinking, and the
data-parallel sharded solver — the regression solve is ONE QP over the
doubled (2n) sample axis, so ``shard="data"`` shards that axis over the
mesh. Serving is compacted exactly like binary SVC: only rows with
|alpha - alpha*| > 0 are kept.

``engine="nystrom"`` / ``engine="rff"`` switch BOTH classes onto the
approximate-kernel tier: an explicit low-rank feature map Φ (n, rank)
(``repro.core.approx``) feeds the O(n·rank) linear dual coordinate
descent (``repro.core.linear``) instead of the kernel SMO, so training
memory is O(n·rank) — never (n, n) — and million-sample fits are
feasible on one device. ``rank`` / ``landmarks`` / ``seed`` tune the
map; this path always runs locally (``solver``/``mesh``/``shard`` are
ignored) and serving packs the map arrays plus linear weights instead
of a support-vector bank.

Multiclass fits go through the strategy layer (``repro.core.multiclass``):
``strategy`` picks the decomposition ("ovo" pairwise, "ovr" one-vs-rest),
``decision`` the OvO aggregation ("vote" majority, "margin" summed
tanh-margins; OvR always argmaxes). The size-bucketed scheduler solves
each shape bucket at its own width (``schedule="bucketed"``) instead of
padding every task to the widest class pair (``schedule="padded"``, the
legacy layout). ``mesh``/``worker_axes`` shard each bucket's task axis
over the distributed (shard_map) "MPI" layer with a greedy LPT worker
layout; without a mesh the buckets are vmapped on the local device
(single-GPU configuration of the paper).

``shard`` picks WHICH axis of parallelism the mesh carries: ``"task"``
(default) distributes independent binary tasks, ``"data"`` shards the
SAMPLE axis of every solve (``smo.sharded_binary_smo`` — one big QP
across all devices, binary fits included), and ``"auto"`` chooses per
serving bucket: wide-and-few tasks go data-parallel, small-and-many stay
task-parallel. ``shard="cascade"`` trains hierarchically instead
(``repro.core.cascade``): the data is partitioned into
``cascade_shards`` sub-SVMs solved independently (task-parallel over
the mesh when one is given), support-vector unions merge up a binary
reduction tree, and feedback rounds (max ``cascade_rounds``) repeat
until the full-dataset KKT certificate passes at the solver tol —
``converged_`` reports the CERTIFICATE, and ``cascade_rounds_`` /
``cascade_kkt_`` / ``cascade_history_`` expose the trail. The serving
state is identical in shape to every other path, so ``serve.pack`` and
``Predictor`` work unchanged; on the low-rank backends the cascade runs
over row slices of the one shared feature map.

All Gram computation — training AND serving — flows through
``repro.core.kernel_engine``; ``engine`` picks the backend ("auto" |
"dense" | "chunked" | "pallas" or a full ``EngineConfig``). After ``fit``
the model keeps only the support vectors (alpha > 0): per serving bucket
for multiclass, so ``decision_function`` cost scales with #SV, not with
the training-set size.

Serving routes through ``repro.serve``: ``predict`` /
``decision_function`` pack the compacted SV bank into an immutable
``serve.PackedModel`` once and answer every subsequent call through a
cached ``serve.Predictor`` (device-resident SV bank, one jitted decide
program per bucket/batch-bucket shape — the pallas backend uses the
fused multi-task decision kernel). The packed artifact is also the
export format: ``serve.save(path, serve.pack(clf))``. The pre-predictor
per-call engine path is kept as ``_decision_function_engine`` /
``SVR._predict_engine`` — the reference implementation the serve path
is tested bit-identical against.

Binary decision values follow the sklearn sign convention: ``fit`` maps
``classes_[1]`` to +1, so a POSITIVE margin predicts ``classes_[1]``
(before PR 5 the orientation was inverted: ``classes_[0]`` mapped to
+1). The support threshold is RELATIVE to the box: alpha (|beta| for
SVR) counts as a support vector above ``1e-8 * C``, so small-C models
keep their support set instead of collapsing to a constant-bias
predictor.
"""
from __future__ import annotations

from functools import lru_cache
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import approx, dist, gd, kernel_engine as KE, kernels as K
from repro.core import cascade as cascade_mod
from repro.core import linear
from repro.core import multiclass as MC
from repro.core import smo
from repro import serve

# Support threshold, RELATIVE to the box constraint: alpha > _SV_EPS * C
# counts as a support vector. An absolute cutoff drops EVERY SV once
# C < eps (all alpha <= C), collapsing the model to its constant bias.
_SV_EPS = 1e-8


def _sv_threshold(C: float) -> float:
    return _SV_EPS * float(C)


def _resolve_fit_inputs(kernel_cfg: K.KernelParams,
                        x) -> tuple[np.ndarray, K.KernelParams]:
    """Shared SVC/SVR fit-entry plumbing: f32-cast the training matrix
    and re-resolve the gamma<=0 "scale" sentinel from THIS data, so a
    refit on new data recomputes gamma (sklearn semantics) instead of
    reusing the first fit's value."""
    x = np.asarray(x, np.float32)
    return x, K.resolve_gamma(kernel_cfg, jnp.asarray(x))


@lru_cache(maxsize=64)
def _jitted_binary_fit(solver: str, cfg, kernel, ecfg):
    """Jitted binary solver, cached per static config: jit keys its
    cache on the callable object, so wrapping a fresh lambda per ``fit``
    would retrace and recompile every call (cf.
    ``smo._sharded_smo_program``) — a warm-up fit would warm nothing."""
    fn = smo.binary_smo if solver == "smo" else gd.binary_gd
    return jax.jit(lambda xx, yv: fn(xx, yv, cfg=cfg, kernel=kernel,
                                     engine=ecfg))


@lru_cache(maxsize=64)
def _jitted_svr_fit(solver: str, epsilon: float, cfg, kernel, ecfg):
    """Jitted epsilon-SVR solver, cached per static config (see
    ``_jitted_binary_fit``)."""
    fn = smo.svr_smo if solver == "smo" else gd.svr_gd
    return jax.jit(lambda xx, yv: fn(xx, yv, epsilon=epsilon, cfg=cfg,
                                     kernel=kernel, engine=ecfg))


# serving-side engine resolution lives with the serving subsystem now
_serving_cfg = serve.serving_config


def _cached_predictor(model) -> "serve.Predictor":
    """Shared SVC/SVR predictor cache: one ``serve.Predictor`` per
    serving engine config, packed lazily; ``fit`` resets the cache so a
    refit repacks."""
    assert model._fitted
    scfg = _serving_cfg(model.engine_cfg)
    pred = model._predictors.get(scfg)
    if pred is None:
        pred = serve.Predictor(serve.pack(model), engine=scfg)
        model._predictors[scfg] = pred
    return pred


class _ServingBucket(NamedTuple):
    """One compacted serving group: tasks whose SV counts round to the
    same pow2 width, stacked for a single vmapped engine.decide."""

    task_ids: np.ndarray  # (Cb,) TaskSet indices
    sv_x: np.ndarray      # (Cb, w, d) support vectors, zero-padded
    sv_coef: np.ndarray   # (Cb, w) alpha_i * y_i, 0 on padding
    b: np.ndarray         # (Cb,)


class SVC:
    def __init__(self, *, kernel: str = "rbf", C: float = 1.0,
                 gamma: float = -1.0, degree: int = 3, coef0: float = 0.0,
                 tol: float = 1e-3, max_iter: int = 100_000,
                 solver: str = "smo", gd_lr: float = 0.01,
                 gd_steps: int = 300,
                 engine: str | KE.EngineConfig = "auto",
                 rank: int = 256, landmarks: str = "uniform",
                 seed: int = 0,
                 shrink_every: int = 0,
                 strategy: str | MC.MulticlassStrategy = "ovo",
                 decision: str = "vote",
                 schedule: str = "bucketed",
                 mesh: Optional[Mesh] = None,
                 worker_axes: tuple[str, ...] = ("workers",),
                 shard: str = "task",
                 cascade_shards: int = 4,
                 cascade_rounds: int = 8):
        # the constructor's params keep the gamma<=0 "scale" sentinel;
        # fit() re-resolves from THEM each call, so a refit on new data
        # recomputes gamma (sklearn semantics) instead of reusing the
        # value resolved from the first fit's data
        self._kernel_cfg = K.KernelParams(name=kernel, gamma=gamma,
                                          degree=degree, coef0=coef0)
        self.kernel_params = self._kernel_cfg
        self.smo_cfg = smo.SMOConfig(C=C, tol=tol, max_iter=max_iter,
                                     shrink_every=shrink_every)
        self.gd_cfg = gd.GDConfig(C=C, lr=gd_lr, steps=gd_steps)
        self.solver = solver
        # rank/landmarks/seed only matter for the approximate backends
        # ("nystrom" | "rff"); they ride in EngineConfig so an explicit
        # EngineConfig instance carries its own values
        self.engine_cfg = (engine if isinstance(engine, KE.EngineConfig)
                           else KE.EngineConfig(backend=engine, rank=rank,
                                                landmarks=landmarks,
                                                seed=seed))
        # max_iter bounds BOTH solvers: SMO pair updates and (as epochs)
        # the low-rank DCD sweeps — it used to be silently dropped here
        self.dcd_cfg = linear.DCDConfig(C=C, tol=tol, max_epochs=max_iter)
        self.strategy = MC.get_strategy(strategy)
        if decision not in ("vote", "margin"):
            raise ValueError(f"unknown OvO decision {decision!r}; "
                             "expected 'vote' or 'margin'")
        self.decision = decision
        if schedule not in ("bucketed", "padded"):
            raise ValueError(f"unknown schedule {schedule!r}; "
                             "expected 'bucketed' or 'padded'")
        self.schedule = schedule
        self.mesh = mesh
        self.worker_axes = worker_axes
        if shard not in ("task", "data", "auto", "cascade"):
            raise ValueError(f"unknown shard mode {shard!r}; expected "
                             "'task', 'data', 'auto' or 'cascade'")
        self.shard = shard
        self.cascade_cfg = cascade_mod.CascadeConfig(
            shards=cascade_shards, rounds=cascade_rounds)
        self._fitted = False

    def _serving_cfg(self) -> KE.EngineConfig:
        return _serving_cfg(self.engine_cfg)

    def _serving_engine(self, sv: jax.Array) -> KE.KernelEngine:
        return KE.make_engine(sv, self.kernel_params, self._serving_cfg())

    # ------------------------------------------------------------------ fit
    def fit(self, x: np.ndarray, y: np.ndarray) -> "SVC":
        x, self.kernel_params = _resolve_fit_inputs(self._kernel_cfg, x)
        y = np.asarray(y)
        classes = np.unique(y)
        if len(classes) < 2:
            raise ValueError(
                f"SVC.fit needs >= 2 classes in y, got {len(classes)} "
                f"({classes.tolist()}); a single-class problem has no "
                f"decision boundary to learn")
        self.classes_ = classes
        self._predictors: dict = {}
        self._feature_map = None
        lowrank = self.engine_cfg.backend in KE.LOWRANK_BACKENDS
        if len(classes) == 2:
            if lowrank:
                self._fit_binary_lowrank(x, y, classes)
            else:
                self._fit_binary(x, y, classes)
        elif lowrank:
            self._fit_multiclass_lowrank(x, y)
        else:
            self._fit_multiclass(x, y)
        self._fitted = True
        return self

    def _use_data_parallel_binary(self, n: int) -> bool:
        """The sharded single-problem path: explicit shard="data"
        (validated hard by the shared ``dist.validate_data_shard`` —
        no mesh / GD / multi-axis raises instead of silently fitting
        locally), or "auto" once the problem is wide enough to amortize
        the per-iteration collectives."""
        if self.shard == "data":
            dist.validate_data_shard(self.mesh, self.worker_axes,
                                     self.solver)
            return True
        if self.mesh is None or self.shard in ("task", "cascade"):
            return False
        # auto: mirror _wants_data_parallel's guards — never route a
        # single-worker mesh through the collective program (worker-axis
        # resolution validates the axes against the mesh up front)
        n_workers = dist.resolve_worker_count(self.mesh,
                                              tuple(self.worker_axes))
        return (self.solver == "smo" and len(self.worker_axes) == 1
                and n_workers > 1 and n >= dist.DATA_PARALLEL_MIN_WIDTH)

    def _fit_binary(self, x, y, classes) -> None:
        # sklearn orientation: classes_[1] maps to +1, so a positive
        # decision margin predicts classes_[1]
        yy = np.where(y == classes[1], 1.0, -1.0).astype(np.float32)
        ecfg = self.engine_cfg
        if self.shard == "cascade":
            cascade_mod.validate_cascade(self.solver, self.cascade_cfg)
            r = cascade_mod.cascade_binary(
                x, yy, smo_cfg=self.smo_cfg, kernel=self.kernel_params,
                engine=ecfg, cascade=self.cascade_cfg, mesh=self.mesh,
                worker_axes=self.worker_axes)
            self.n_iter_ = int(r.n_iter)
            # the cascade's convergence IS the certificate: kkt_violation
            # over the full dataset <= tol, recomputed in float64
            self.converged_ = bool(r.converged)
            self.cascade_rounds_ = int(r.rounds)
            self.cascade_kkt_ = float(r.kkt)
            self.cascade_history_ = r.history
        elif self._use_data_parallel_binary(x.shape[0]):
            r = smo.sharded_binary_smo(
                jnp.asarray(x), jnp.asarray(yy), mesh=self.mesh,
                axis=self.worker_axes[0], cfg=self.smo_cfg,
                kernel=self.kernel_params, engine=ecfg)
            self.n_iter_ = int(r.n_iter)
            self.converged_ = bool(r.converged)
        elif self.solver == "smo":
            r = _jitted_binary_fit("smo", self.smo_cfg,
                                   self.kernel_params, ecfg)(
                jnp.asarray(x), jnp.asarray(yy))
            self.n_iter_ = int(r.n_iter)
            self.converged_ = bool(r.converged)
        else:
            r = _jitted_binary_fit("gd", self.gd_cfg,
                                   self.kernel_params, ecfg)(
                jnp.asarray(x), jnp.asarray(yy))
            self.n_iter_ = int(r.n_iter)
            self.converged_ = True
        self._binary = True
        self.alpha_, self.b_ = np.asarray(r.alpha), float(r.b)
        # serving state: compacted support-vector set only
        sv = self.alpha_ > _sv_threshold(self.smo_cfg.C)
        self.support_ = np.where(sv)[0]
        self.n_support_ = int(sv.sum())
        self.support_vectors_ = x[sv]
        self.dual_coef_ = (self.alpha_ * yy)[sv].astype(np.float32)

    def _fit_binary_lowrank(self, x, y, classes) -> None:
        """Approximate-kernel binary fit: explicit low-rank features
        (Nystrom landmarks / random Fourier features,
        ``repro.core.approx``) + the O(n k) dual coordinate descent
        (``repro.core.linear``) — no (n, n) object is ever formed, so n
        is bounded by O(n·rank) memory, not the Gram. The linear path
        always runs locally and ignores ``solver``/``mesh``/``shard``."""
        yy = np.where(y == classes[1], 1.0, -1.0).astype(np.float32)
        xj = jnp.asarray(x)
        fmap = approx.make_feature_map(xj, self.kernel_params,
                                       self.engine_cfg)
        phi = fmap.transform(xj)
        if self.shard == "cascade":
            # cascade over row slices of the ONE shared feature map; the
            # solver knob is ignored on this path, so don't validate it
            cascade_mod.validate_cascade(None, self.cascade_cfg)
            r = cascade_mod.cascade_dcd(phi, yy, dcd_cfg=self.dcd_cfg,
                                        cascade=self.cascade_cfg)
            self.cascade_rounds_ = int(r.rounds)
            self.cascade_kkt_ = float(r.kkt)
            self.cascade_history_ = r.history
        else:
            r = linear.fit_linear_svc(self.dcd_cfg)(phi, jnp.asarray(yy))
        self._binary = True
        self._feature_map = fmap
        self.alpha_, self.b_ = np.asarray(r.alpha), float(r.b)
        self.w_ = np.asarray(r.w)
        self.n_iter_ = int(r.n_iter)
        self.converged_ = bool(r.converged)
        sv = self.alpha_ > _sv_threshold(self.smo_cfg.C)
        self.support_ = np.where(sv)[0]
        self.n_support_ = int(sv.sum())
        self.support_vectors_ = x[sv]
        self.dual_coef_ = (self.alpha_ * yy)[sv].astype(np.float32)

    def _fit_multiclass_lowrank(self, x, y) -> None:
        """Multiclass over ONE feature map shared by every binary task:
        each task is a linear DCD solve over its slice of the SAME
        low-rank feature space, so serving is one feature transform
        followed by a (n_tasks, rank) matmul — no per-task SV banks."""
        taskset = self.strategy.build_taskset(x, y)
        fmap = approx.make_feature_map(jnp.asarray(x), self.kernel_params,
                                       self.engine_cfg)
        # transform the full X ONCE and gather each task's rows — OvO
        # tasks overlap heavily (every class appears in m-1 pairs), so
        # per-task transforms recompute the same feature rows m-1 times
        phi = fmap.transform(jnp.asarray(x))
        fit = linear.fit_linear_svc(self.dcd_cfg)
        use_cascade = self.shard == "cascade"
        if use_cascade:
            cascade_mod.validate_cascade(None, self.cascade_cfg)
            rounds = np.zeros(taskset.n_tasks, np.int64)
            kkt = np.zeros(taskset.n_tasks, np.float64)  # repro: noqa[R002] -- host-side store of the f64 cascade certificate values
        n_tasks = taskset.n_tasks
        task_w = np.zeros((n_tasks, fmap.rank), np.float32)
        task_b = np.zeros((n_tasks,), np.float32)
        n_support = np.zeros(n_tasks, np.int64)
        n_iter = np.zeros(n_tasks, np.int64)
        converged = np.ones(n_tasks, bool)
        alphas = []
        thr = _sv_threshold(self.smo_cfg.C)
        for t, task in enumerate(taskset.tasks):
            phi_t = (phi[jnp.asarray(task.indices)]
                     if task.indices is not None
                     else fmap.transform(jnp.asarray(task.x)))
            if use_cascade:
                r = cascade_mod.cascade_dcd(phi_t, task.y,
                                            dcd_cfg=self.dcd_cfg,
                                            cascade=self.cascade_cfg)
                rounds[t] = r.rounds
                kkt[t] = r.kkt
            else:
                r = fit(phi_t, jnp.asarray(task.y))
            a = np.asarray(r.alpha)
            alphas.append(a)
            task_w[t] = np.asarray(r.w)
            task_b[t] = float(r.b)
            n_support[t] = int((a > thr).sum())
            n_iter[t] = int(r.n_iter)
            converged[t] = bool(r.converged)
        if use_cascade:
            self.cascade_rounds_ = rounds
            self.cascade_kkt_ = kkt
        self._binary = False
        self._feature_map = fmap
        self._taskset = taskset
        self._task_alpha = alphas
        self.task_w_ = task_w
        self.task_b_ = task_b
        self.n_support_ = n_support
        self.n_iter_ = int(n_iter.max())
        self.converged_ = bool(converged.all())

    def _fit_taskset_cascade(self, taskset: MC.TaskSet) -> dist.TaskSetFit:
        """Each binary task trained by its own hierarchical cascade
        (shard leaves distribute task-parallel over the mesh inside each
        cascade level); results come back in TaskSetFit layout so the
        standard serving compaction applies unchanged. ``converged``
        entries report the per-task global KKT certificate."""
        c = taskset.n_tasks
        sizes = taskset.sizes
        alpha = np.zeros((c, int(sizes.max())), np.float32)
        b = np.zeros(c, np.float32)
        n_iter = np.zeros(c, np.int64)
        converged = np.zeros(c, bool)
        rounds = np.zeros(c, np.int64)
        kkt = np.zeros(c, np.float64)  # repro: noqa[R002] -- host-side store of the f64 cascade certificate values
        for t, task in enumerate(taskset.tasks):
            r = cascade_mod.cascade_binary(
                task.x, task.y, smo_cfg=self.smo_cfg,
                kernel=self.kernel_params, engine=self.engine_cfg,
                cascade=self.cascade_cfg, mesh=self.mesh,
                worker_axes=self.worker_axes)
            alpha[t, :task.size] = r.alpha
            b[t] = r.b
            n_iter[t] = r.n_iter
            converged[t] = r.converged
            rounds[t] = r.rounds
            kkt[t] = r.kkt
        self.cascade_rounds_ = rounds
        self.cascade_kkt_ = kkt
        return dist.TaskSetFit(alpha=alpha, b=b, n_iter=n_iter,
                               converged=converged, sizes=sizes)

    def _fit_multiclass(self, x, y) -> None:
        taskset = self.strategy.build_taskset(x, y)
        if self.shard == "cascade":
            cascade_mod.validate_cascade(self.solver, self.cascade_cfg)
            sched = None
            fit = self._fit_taskset_cascade(taskset)
        else:
            n_workers = dist.resolve_worker_count(self.mesh,
                                                  tuple(self.worker_axes))
            bucket_by = "pow2" if self.schedule == "bucketed" else "none"
            sched = MC.build_schedule(
                taskset.sizes,
                MC.ScheduleConfig(bucket_by=bucket_by,
                                  n_workers=n_workers))
            fit = dist.fit_taskset(
                taskset, sched, mesh=self.mesh,
                worker_axes=self.worker_axes, solver=self.solver,
                smo_cfg=self.smo_cfg, gd_cfg=self.gd_cfg,
                kernel=self.kernel_params, engine=self.engine_cfg,
                shard=self.shard)
        self._binary = False
        self._taskset = taskset
        self._schedule = sched
        self._fit = fit
        self.n_iter_ = int(np.max(fit.n_iter))
        self.converged_ = bool(np.all(fit.converged))
        self._compact_tasks()

    def _compact_tasks(self) -> None:
        """Per-bucket SV compaction: keep only alpha > 0 rows of each
        task, grouped into pow2 SV-width serving buckets — one vmapped
        ``engine.decide`` program per bucket at #SV cost, instead of one
        program padded to the widest task."""
        taskset, fit = self._taskset, self._fit
        sv_counts = np.zeros(taskset.n_tasks, np.int64)
        sv_idx = []
        for t, task in enumerate(taskset.tasks):
            idx = np.flatnonzero(fit.alpha[t, :task.size]
                                 > _sv_threshold(self.smo_cfg.C))
            sv_idx.append(idx)
            sv_counts[t] = len(idx)
        self.n_support_ = sv_counts

        sched = MC.build_schedule(
            np.maximum(sv_counts, 1),
            MC.ScheduleConfig(bucket_by="pow2", min_width=8, n_workers=1))
        d = taskset.tasks[0].x.shape[1]
        groups = []
        for bucket in sched.buckets:
            ids = bucket.task_ids.reshape(-1)
            ids = ids[ids >= 0]
            # pow2 groups the tasks; the stack width is the exact max SV
            # count inside the group (never wider than any member task)
            width = max(1, int(sv_counts[ids].max()))
            sv_x = np.zeros((len(ids), width, d), np.float32)
            sv_coef = np.zeros((len(ids), width), np.float32)
            for s, t in enumerate(ids):
                idx = sv_idx[t]
                task = taskset.tasks[t]
                sv_x[s, :len(idx)] = task.x[idx]
                sv_coef[s, :len(idx)] = (fit.alpha[t, idx]
                                         * task.y[idx]).astype(np.float32)
            groups.append(_ServingBucket(task_ids=ids, sv_x=sv_x,
                                         sv_coef=sv_coef, b=fit.b[ids]))
        self._serving_buckets = groups

    # ------------------------------------------------------------- predict
    def predictor(self) -> "serve.Predictor":
        """The cached batched serving engine for this fit (one per
        serving engine config — the SV bank stays resident on device and
        decide programs jit-cache across calls). Repacked on refit."""
        return _cached_predictor(self)

    def decision_function(self, xt: np.ndarray) -> np.ndarray:
        """(n_test,) margins for binary (positive => ``classes_[1]``,
        the sklearn orientation), (n_tasks, n_test) stacked binary
        decisions for multiclass (OvO: m(m-1)/2 rows, OvR: m rows)."""
        return self.predictor().decision_function(xt)

    def _decision_function_engine(self, xt: np.ndarray) -> np.ndarray:
        """Pre-predictor reference path: rebuilds a ``KernelEngine`` and
        loops serving buckets in Python on every call. Kept as the
        fallback the serve path is tested bit-identical against (and as
        the baseline ``benchmarks/bench_serving.py`` measures)."""
        assert self._fitted
        xt = jnp.asarray(np.asarray(xt, np.float32))
        if self._feature_map is not None:
            # low-rank linear path: one feature transform, then w (or the
            # stacked task_w matrix) — no SV bank, no kernel engine
            phi_t = self._feature_map.transform(xt)
            if self._binary:
                return np.asarray(phi_t @ jnp.asarray(self.w_) + self.b_)
            df = phi_t @ jnp.asarray(self.task_w_).T
            return (np.asarray(df).T
                    + self.task_b_[:, None]).astype(np.float32)
        if self._binary:
            if self.n_support_ == 0:  # degenerate fit: constant decision
                return np.full(xt.shape[0], self.b_, np.float32)
            eng = self._serving_engine(jnp.asarray(self.support_vectors_))
            df = eng.decide(xt, jnp.asarray(self.dual_coef_), self.b_)
            return np.asarray(df)
        # (C, n_test) stacked binary decisions, one vmapped engine-backed
        # program per serving bucket (respects engine="pallas"/"chunked")
        scfg = self._serving_cfg()
        kp = self.kernel_params

        def one(sv, coef, b):
            return KE.make_engine(sv, kp, scfg).decide(xt, coef, b)

        df = np.zeros((self._taskset.n_tasks, xt.shape[0]), np.float32)
        for g in self._serving_buckets:
            out = jax.vmap(one)(jnp.asarray(g.sv_x), jnp.asarray(g.sv_coef),
                                jnp.asarray(g.b))
            df[g.task_ids] = np.asarray(out)
        return df

    def predict(self, xt: np.ndarray) -> np.ndarray:
        return self.predictor().predict(xt)

    def score(self, xt: np.ndarray, yt: np.ndarray) -> float:
        return float(np.mean(self.predict(xt) == np.asarray(yt)))


class SVR:
    """epsilon-insensitive Support Vector Regression on the generalized
    SMO core — one doubled-variable QP through the same engine /
    shrinking / sharding stack as binary ``SVC`` (module docstring)."""

    def __init__(self, *, kernel: str = "rbf", C: float = 1.0,
                 epsilon: float = 0.1,
                 gamma: float = -1.0, degree: int = 3, coef0: float = 0.0,
                 tol: float = 1e-3, max_iter: int = 100_000,
                 solver: str = "smo", gd_lr: float = 0.01,
                 gd_steps: int = 300,
                 engine: str | KE.EngineConfig = "auto",
                 rank: int = 256, landmarks: str = "uniform",
                 seed: int = 0,
                 shrink_every: int = 0,
                 mesh: Optional[Mesh] = None,
                 worker_axes: tuple[str, ...] = ("workers",),
                 shard: str = "task",
                 cascade_shards: int = 4,
                 cascade_rounds: int = 8):
        # gamma "scale" sentinel kept; re-resolved per fit (see SVC)
        self._kernel_cfg = K.KernelParams(name=kernel, gamma=gamma,
                                          degree=degree, coef0=coef0)
        self.kernel_params = self._kernel_cfg
        self.smo_cfg = smo.SMOConfig(C=C, tol=tol, max_iter=max_iter,
                                     shrink_every=shrink_every)
        self.gd_cfg = gd.GDConfig(C=C, lr=gd_lr, steps=gd_steps)
        self.epsilon = float(epsilon)
        self.solver = solver
        # approximate-backend knobs ride in EngineConfig (see SVC)
        self.engine_cfg = (engine if isinstance(engine, KE.EngineConfig)
                           else KE.EngineConfig(backend=engine, rank=rank,
                                                landmarks=landmarks,
                                                seed=seed))
        # max_iter bounds BOTH solvers: SMO pair updates and (as epochs)
        # the low-rank DCD sweeps — it used to be silently dropped here
        self.dcd_cfg = linear.DCDConfig(C=C, tol=tol, max_epochs=max_iter)
        self.mesh = mesh
        self.worker_axes = worker_axes
        if shard not in ("task", "data", "auto", "cascade"):
            raise ValueError(f"unknown shard mode {shard!r}; expected "
                             "'task', 'data', 'auto' or 'cascade'")
        self.shard = shard
        self.cascade_cfg = cascade_mod.CascadeConfig(
            shards=cascade_shards, rounds=cascade_rounds)
        self._fitted = False

    def _use_data_parallel(self, n: int) -> bool:
        """Mirrors ``SVC._use_data_parallel_binary`` on the DOUBLED
        sample axis (the sharded program sees 2n rows)."""
        if self.shard == "data":
            dist.validate_data_shard(self.mesh, self.worker_axes,
                                     self.solver)
            return True
        if self.mesh is None or self.shard in ("task", "cascade"):
            return False
        n_workers = dist.resolve_worker_count(self.mesh,
                                              tuple(self.worker_axes))
        return (self.solver == "smo" and len(self.worker_axes) == 1
                and n_workers > 1
                and 2 * n >= dist.DATA_PARALLEL_MIN_WIDTH)

    # ------------------------------------------------------------------ fit
    def fit(self, x: np.ndarray, y: np.ndarray) -> "SVR":
        x, self.kernel_params = _resolve_fit_inputs(self._kernel_cfg, x)
        y = np.asarray(y, np.float32)
        self._feature_map = None
        eps, ecfg = self.epsilon, self.engine_cfg
        if ecfg.backend in KE.LOWRANK_BACKENDS:
            # approximate-kernel path: low-rank features + linear DCD on
            # the doubled epsilon-SVR QP (see SVC._fit_binary_lowrank)
            xj = jnp.asarray(x)
            fmap = approx.make_feature_map(xj, self.kernel_params, ecfg)
            phi = fmap.transform(xj)
            if self.shard == "cascade":
                cascade_mod.validate_cascade(None, self.cascade_cfg)
                r = cascade_mod.cascade_dcd_svr(
                    phi, y, epsilon=eps, dcd_cfg=self.dcd_cfg,
                    cascade=self.cascade_cfg)
                self.cascade_rounds_ = int(r.rounds)
                self.cascade_kkt_ = float(r.kkt)
                self.cascade_history_ = r.history
            else:
                r = linear.fit_linear_svr(eps, self.dcd_cfg)(
                    phi, jnp.asarray(y))
            self._feature_map = fmap
            self.w_ = np.asarray(r.w)
            self.n_iter_ = int(r.n_iter)
            self.converged_ = bool(r.converged)
        elif self.shard == "cascade":
            cascade_mod.validate_cascade(self.solver, self.cascade_cfg)
            r = cascade_mod.cascade_svr(
                x, y, epsilon=eps, smo_cfg=self.smo_cfg,
                kernel=self.kernel_params, engine=ecfg,
                cascade=self.cascade_cfg, mesh=self.mesh,
                worker_axes=self.worker_axes)
            self.n_iter_ = int(r.n_iter)
            self.converged_ = bool(r.converged)   # certified (see SVC)
            self.cascade_rounds_ = int(r.rounds)
            self.cascade_kkt_ = float(r.kkt)
            self.cascade_history_ = r.history
        elif self._use_data_parallel(x.shape[0]):
            r = smo.sharded_svr_smo(
                jnp.asarray(x), jnp.asarray(y), epsilon=eps,
                mesh=self.mesh, axis=self.worker_axes[0],
                cfg=self.smo_cfg, kernel=self.kernel_params, engine=ecfg)
            self.n_iter_ = int(r.n_iter)
            self.converged_ = bool(r.converged)
        elif self.solver == "smo":
            r = _jitted_svr_fit("smo", eps, self.smo_cfg,
                                self.kernel_params, ecfg)(
                jnp.asarray(x), jnp.asarray(y))
            self.n_iter_ = int(r.n_iter)
            self.converged_ = bool(r.converged)
        else:
            r = _jitted_svr_fit("gd", eps, self.gd_cfg,
                                self.kernel_params, ecfg)(
                jnp.asarray(x), jnp.asarray(y))
            self.n_iter_ = int(r.n_iter)
            self.converged_ = True
            self.loss_curve_ = np.asarray(r.loss_curve)
        if isinstance(r, cascade_mod.CascadeResult):
            # cascade layout: alpha IS the per-sample beta, alpha_raw the
            # (2n,) doubled scatter of the root solve
            self.beta_ = np.asarray(r.alpha)
            self.b_ = float(r.b)
            self.alpha_raw_ = np.asarray(r.alpha_raw)
        else:
            self.beta_ = np.asarray(r.beta)
            self.b_ = float(r.b)
            self.alpha_raw_ = np.asarray(r.alpha)  # (2n,) [alpha; alpha*]
        # serving state: compacted support-vector set only
        sv = np.abs(self.beta_) > _sv_threshold(self.smo_cfg.C)
        self.support_ = np.where(sv)[0]
        self.n_support_ = int(sv.sum())
        self.support_vectors_ = x[sv]
        self.dual_coef_ = self.beta_[sv].astype(np.float32)
        self._predictors: dict = {}
        self._fitted = True
        return self

    # ------------------------------------------------------------- predict
    def predictor(self) -> "serve.Predictor":
        """The cached batched serving engine for this fit (see
        ``SVC.predictor``)."""
        return _cached_predictor(self)

    def predict(self, xt: np.ndarray) -> np.ndarray:
        return self.predictor().predict(xt)

    def _predict_engine(self, xt: np.ndarray) -> np.ndarray:
        """Pre-predictor reference path (see
        ``SVC._decision_function_engine``)."""
        assert self._fitted
        xt = jnp.asarray(np.asarray(xt, np.float32))
        if self._feature_map is not None:
            phi_t = self._feature_map.transform(xt)
            return np.asarray(phi_t @ jnp.asarray(self.w_) + self.b_)
        if self.n_support_ == 0:   # every sample inside the tube
            return np.full(xt.shape[0], self.b_, np.float32)
        eng = KE.make_engine(jnp.asarray(self.support_vectors_),
                             self.kernel_params,
                             _serving_cfg(self.engine_cfg))
        pred = eng.decide(xt, jnp.asarray(self.dual_coef_), self.b_)
        return np.asarray(pred)

    def score(self, xt: np.ndarray, yt: np.ndarray) -> float:
        """Coefficient of determination R^2 (sklearn convention)."""
        yt = np.asarray(yt, np.float64)  # repro: noqa[R002] -- host-side R^2 accumulation, never enters jit
        resid = yt - np.asarray(self.predict(xt), np.float64)  # repro: noqa[R002] -- host-side R^2 accumulation, never enters jit
        ss_res = float(np.sum(resid ** 2))
        ss_tot = float(np.sum((yt - yt.mean()) ** 2))
        if ss_tot == 0.0:
            return 1.0 if ss_res == 0.0 else 0.0
        return 1.0 - ss_res / ss_tot
