"""Public SVM API — sklearn-flavoured front end over the parallel solvers.

    clf = SVC(kernel="rbf", C=1.0, solver="smo")      # paper's CUDA path
    clf = SVC(kernel="rbf", C=1.0, solver="gd")       # paper's TF baseline
    clf.fit(X, y)                                     # binary OR multiclass
    clf.predict(Xt); clf.score(Xt, yt)

Multiclass fits use one-vs-one. ``mesh``/``worker_axes`` route the task
axis through the distributed (shard_map) "MPI" layer; without a mesh the
tasks are vmapped on the local device (single-GPU configuration of the
paper).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import dist, gd, kernels as K, ovo, smo


class SVC:
    def __init__(self, *, kernel: str = "rbf", C: float = 1.0,
                 gamma: float = -1.0, degree: int = 3, coef0: float = 0.0,
                 tol: float = 1e-3, max_iter: int = 100_000,
                 solver: str = "smo", gd_lr: float = 0.01,
                 gd_steps: int = 300,
                 mesh: Optional[Mesh] = None,
                 worker_axes: tuple[str, ...] = ("workers",)):
        self.kernel_params = K.KernelParams(name=kernel, gamma=gamma,
                                            degree=degree, coef0=coef0)
        self.smo_cfg = smo.SMOConfig(C=C, tol=tol, max_iter=max_iter)
        self.gd_cfg = gd.GDConfig(C=C, lr=gd_lr, steps=gd_steps)
        self.solver = solver
        self.mesh = mesh
        self.worker_axes = worker_axes
        self._fitted = False

    # ------------------------------------------------------------------ fit
    def fit(self, x: np.ndarray, y: np.ndarray) -> "SVC":
        x = np.asarray(x, np.float32)
        y = np.asarray(y)
        self.kernel_params = K.resolve_gamma(self.kernel_params,
                                             jnp.asarray(x))
        classes = np.unique(y)
        self.classes_ = classes
        if len(classes) == 2:
            yy = np.where(y == classes[0], 1.0, -1.0).astype(np.float32)
            if self.solver == "smo":
                r = jax.jit(
                    lambda xx, yv: smo.binary_smo(
                        xx, yv, cfg=self.smo_cfg, kernel=self.kernel_params)
                )(jnp.asarray(x), jnp.asarray(yy))
                self.n_iter_ = int(r.n_iter)
                self.converged_ = bool(r.converged)
            else:
                r = jax.jit(
                    lambda xx, yv: gd.binary_gd(
                        xx, yv, cfg=self.gd_cfg, kernel=self.kernel_params)
                )(jnp.asarray(x), jnp.asarray(yy))
                self.n_iter_ = int(r.n_iter)
                self.converged_ = True
            self._binary = True
            self._x, self._y = x, yy
            self.alpha_, self.b_ = np.asarray(r.alpha), float(r.b)
            self.support_ = np.where(self.alpha_ > 1e-8)[0]
        else:
            n_workers = 1
            if self.mesh is not None:
                n_workers = int(np.prod([self.mesh.shape[a]
                                         for a in self.worker_axes]))
            tasks = ovo.build_tasks(x, y, pad_tasks_to=n_workers)
            if self.mesh is not None:
                fit = dist.distributed_ovo_fit(
                    tasks, self.mesh, self.worker_axes, solver=self.solver,
                    smo_cfg=self.smo_cfg, gd_cfg=self.gd_cfg,
                    kernel=self.kernel_params)
            else:
                fit = dist.vmapped_ovo_fit(
                    tasks, solver=self.solver, smo_cfg=self.smo_cfg,
                    gd_cfg=self.gd_cfg, kernel=self.kernel_params)
            self._binary = False
            self._tasks = tasks
            self._fit = jax.tree.map(np.asarray, fit)
            self.n_iter_ = int(np.max(self._fit.n_iter))
            self.converged_ = bool(np.all(
                self._fit.converged[:ovo.n_binary_tasks(len(classes))]))
        self._fitted = True
        return self

    # ------------------------------------------------------------- predict
    def decision_function(self, xt: np.ndarray) -> np.ndarray:
        assert self._fitted
        xt = jnp.asarray(np.asarray(xt, np.float32))
        if self._binary:
            df = smo.decision_function(
                jnp.asarray(self._x), jnp.asarray(self._y),
                jnp.asarray(self.alpha_), self.b_, xt,
                kernel=self.kernel_params)
            return np.asarray(df)
        # (C, n_test) stacked binary decisions
        gram_fn = K.make_gram_fn(self.kernel_params)

        def one(xtask, ytask, alpha, b):
            kmat = gram_fn(xt, xtask)
            return kmat @ (alpha * ytask) + b

        df = jax.vmap(one)(jnp.asarray(self._tasks.x),
                           jnp.asarray(self._tasks.y),
                           jnp.asarray(self._fit.alpha),
                           jnp.asarray(self._fit.b))
        return np.asarray(df)

    def predict(self, xt: np.ndarray) -> np.ndarray:
        df = self.decision_function(xt)
        if self._binary:
            return np.where(df > 0, self.classes_[0], self.classes_[1])
        c_real = ovo.n_binary_tasks(len(self.classes_))
        idx = ovo.vote(jnp.asarray(df), self._tasks.pairs,
                       self._tasks.classes, c_real)
        return self.classes_[np.asarray(idx)]

    def score(self, xt: np.ndarray, yt: np.ndarray) -> float:
        return float(np.mean(self.predict(xt) == np.asarray(yt)))
