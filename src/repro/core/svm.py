"""Public SVM API — sklearn-flavoured front end over the parallel solvers.

    clf = SVC(kernel="rbf", C=1.0, solver="smo")      # paper's CUDA path
    clf = SVC(kernel="rbf", C=1.0, solver="gd")       # paper's TF baseline
    clf = SVC(engine="chunked", shrink_every=4)       # n >> 8k training
    clf.fit(X, y)                                     # binary OR multiclass
    clf.predict(Xt); clf.score(Xt, yt)

Multiclass fits use one-vs-one. ``mesh``/``worker_axes`` route the task
axis through the distributed (shard_map) "MPI" layer; without a mesh the
tasks are vmapped on the local device (single-GPU configuration of the
paper).

All Gram computation flows through ``repro.core.kernel_engine`` —
``engine`` picks the backend ("auto" | "dense" | "chunked" | "pallas" or
a full ``EngineConfig``). After ``fit`` the model keeps only the support
vectors (alpha > 0) for serving: ``decision_function`` cost scales with
#SV, not with the training-set size.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import dist, gd, kernel_engine as KE, kernels as K, ovo, smo

_SV_EPS = 1e-8


class SVC:
    def __init__(self, *, kernel: str = "rbf", C: float = 1.0,
                 gamma: float = -1.0, degree: int = 3, coef0: float = 0.0,
                 tol: float = 1e-3, max_iter: int = 100_000,
                 solver: str = "smo", gd_lr: float = 0.01,
                 gd_steps: int = 300,
                 engine: str | KE.EngineConfig = "auto",
                 shrink_every: int = 0,
                 mesh: Optional[Mesh] = None,
                 worker_axes: tuple[str, ...] = ("workers",)):
        self.kernel_params = K.KernelParams(name=kernel, gamma=gamma,
                                            degree=degree, coef0=coef0)
        self.smo_cfg = smo.SMOConfig(C=C, tol=tol, max_iter=max_iter,
                                     shrink_every=shrink_every)
        self.gd_cfg = gd.GDConfig(C=C, lr=gd_lr, steps=gd_steps)
        self.solver = solver
        self.engine_cfg = (engine if isinstance(engine, KE.EngineConfig)
                           else KE.EngineConfig(backend=engine))
        self.mesh = mesh
        self.worker_axes = worker_axes
        self._fitted = False

    def _serving_engine(self, sv: jax.Array) -> KE.KernelEngine:
        """Engine bound to the compacted SV set; serving never needs the
        (sv, sv) training Gram, so dense/auto degrade to chunked."""
        backend = ("pallas" if self.engine_cfg.backend == "pallas"
                   else "chunked")
        return KE.make_engine(
            sv, self.kernel_params,
            dataclasses.replace(self.engine_cfg, backend=backend))

    # ------------------------------------------------------------------ fit
    def fit(self, x: np.ndarray, y: np.ndarray) -> "SVC":
        x = np.asarray(x, np.float32)
        y = np.asarray(y)
        self.kernel_params = K.resolve_gamma(self.kernel_params,
                                             jnp.asarray(x))
        classes = np.unique(y)
        self.classes_ = classes
        if len(classes) == 2:
            yy = np.where(y == classes[0], 1.0, -1.0).astype(np.float32)
            ecfg = self.engine_cfg
            if self.solver == "smo":
                r = jax.jit(
                    lambda xx, yv: smo.binary_smo(
                        xx, yv, cfg=self.smo_cfg, kernel=self.kernel_params,
                        engine=ecfg)
                )(jnp.asarray(x), jnp.asarray(yy))
                self.n_iter_ = int(r.n_iter)
                self.converged_ = bool(r.converged)
            else:
                r = jax.jit(
                    lambda xx, yv: gd.binary_gd(
                        xx, yv, cfg=self.gd_cfg, kernel=self.kernel_params,
                        engine=ecfg)
                )(jnp.asarray(x), jnp.asarray(yy))
                self.n_iter_ = int(r.n_iter)
                self.converged_ = True
            self._binary = True
            self.alpha_, self.b_ = np.asarray(r.alpha), float(r.b)
            # serving state: compacted support-vector set only
            sv = self.alpha_ > _SV_EPS
            self.support_ = np.where(sv)[0]
            self.n_support_ = int(sv.sum())
            self.support_vectors_ = x[sv]
            self.dual_coef_ = (self.alpha_ * yy)[sv].astype(np.float32)
        else:
            n_workers = 1
            if self.mesh is not None:
                n_workers = int(np.prod([self.mesh.shape[a]
                                         for a in self.worker_axes]))
            tasks = ovo.build_tasks(x, y, pad_tasks_to=n_workers)
            if self.mesh is not None:
                fit = dist.distributed_ovo_fit(
                    tasks, self.mesh, self.worker_axes, solver=self.solver,
                    smo_cfg=self.smo_cfg, gd_cfg=self.gd_cfg,
                    kernel=self.kernel_params, engine=self.engine_cfg)
            else:
                fit = dist.vmapped_ovo_fit(
                    tasks, solver=self.solver, smo_cfg=self.smo_cfg,
                    gd_cfg=self.gd_cfg, kernel=self.kernel_params,
                    engine=self.engine_cfg)
            self._binary = False
            self._tasks = tasks
            self._fit = jax.tree.map(np.asarray, fit)
            self.n_iter_ = int(np.max(self._fit.n_iter))
            self.converged_ = bool(np.all(
                self._fit.converged[:ovo.n_binary_tasks(len(classes))]))
            self._compact_tasks()
        self._fitted = True
        return self

    def _compact_tasks(self) -> None:
        """Per-task SV compaction: keep only alpha > 0 rows (padded with
        coef = 0 rows up to the widest task, so one vmapped program serves
        every task at #SV cost instead of n_task cost)."""
        alpha = self._fit.alpha                       # (C, n_task)
        coef = (alpha * self._tasks.y * self._tasks.mask).astype(np.float32)
        sv_mask = (alpha > _SV_EPS) & self._tasks.mask
        width = max(1, int(sv_mask.sum(axis=1).max()))
        c_total, _, d = self._tasks.x.shape
        sv_x = np.zeros((c_total, width, d), np.float32)
        sv_coef = np.zeros((c_total, width), np.float32)
        for t in range(c_total):
            idx = np.flatnonzero(sv_mask[t])
            sv_x[t, :len(idx)] = self._tasks.x[t, idx]
            sv_coef[t, :len(idx)] = coef[t, idx]
        self.n_support_ = sv_mask.sum(axis=1).astype(np.int64)
        self._sv_x, self._sv_coef = sv_x, sv_coef

    # ------------------------------------------------------------- predict
    def decision_function(self, xt: np.ndarray) -> np.ndarray:
        assert self._fitted
        xt = jnp.asarray(np.asarray(xt, np.float32))
        if self._binary:
            if self.n_support_ == 0:  # degenerate fit: constant decision
                return np.full(xt.shape[0], self.b_, np.float32)
            eng = self._serving_engine(jnp.asarray(self.support_vectors_))
            df = eng.decide(xt, jnp.asarray(self.dual_coef_), self.b_)
            return np.asarray(df)
        # (C, n_test) stacked binary decisions over compacted SV sets
        gram_fn = K.make_gram_fn(self.kernel_params)

        def one(sv, coef, b):
            kmat = gram_fn(xt, sv)
            return kmat @ coef + b

        df = jax.vmap(one)(jnp.asarray(self._sv_x),
                           jnp.asarray(self._sv_coef),
                           jnp.asarray(self._fit.b))
        return np.asarray(df)

    def predict(self, xt: np.ndarray) -> np.ndarray:
        df = self.decision_function(xt)
        if self._binary:
            return np.where(df > 0, self.classes_[0], self.classes_[1])
        c_real = ovo.n_binary_tasks(len(self.classes_))
        idx = ovo.vote(jnp.asarray(df), self._tasks.pairs,
                       self._tasks.classes, c_real)
        return self.classes_[np.asarray(idx)]

    def score(self, xt: np.ndarray, yt: np.ndarray) -> float:
        return float(np.mean(self.predict(xt) == np.asarray(yt)))
