"""One-vs-one multiclass decomposition (paper Sec. III, Fig. 4).

NOTE: this is the LEGACY padded-stack task builder, retained because its
fixed-shape ``OvOTasks`` layout is the input contract of the
``vmapped_ovo_fit`` / ``distributed_ovo_fit`` shims. New code should go
through the strategy layer — ``repro.core.multiclass.OneVsOneStrategy``
builds variable-length tasks that the size-bucketed scheduler runs
without pad-to-max waste (``repro.core.dist.fit_taskset``).

For m classes the problem splits into C = m(m-1)/2 *independent* binary
subproblems — the unit of distribution in the paper's MPI layer. Task
construction happens on the host (numpy), producing fixed-shape padded
arrays so one SPMD program (vmap'd / shard_map'd ``binary_smo``) can
drive every task:

  x_tasks   (C, n_task, d)   samples of the two classes, zero-padded
  y_tasks   (C, n_task)      +1 / -1, 0 on padding
  mask      (C, n_task)      validity
  pairs     (C, 2)           (class_a -> +1, class_b -> -1)

Prediction is majority voting over the C binary decisions, ties broken
toward the lower class index (LIBSVM convention).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp


class OvOTasks(NamedTuple):
    x: np.ndarray      # (C, n_task, d)
    y: np.ndarray      # (C, n_task)
    mask: np.ndarray   # (C, n_task)
    pairs: np.ndarray  # (C, 2) original class labels
    classes: np.ndarray  # (m,) sorted unique labels


def n_binary_tasks(m: int) -> int:
    return m * (m - 1) // 2


def build_tasks(x: np.ndarray, y: np.ndarray,
                pad_tasks_to: int | None = None) -> OvOTasks:
    """Host-side task construction. ``pad_tasks_to`` pads the TASK axis
    (with empty dummy tasks) so it divides the worker count evenly —
    the static partition ``N = C / P`` of the paper's Fig. 4."""
    x = np.asarray(x, dtype=np.float32)
    y = np.asarray(y)
    classes = np.unique(y)
    m = len(classes)
    if m < 2:
        raise ValueError("need at least 2 classes")
    pairs = [(a, b) for ai, a in enumerate(classes) for b in classes[ai + 1:]]
    n_task = 0
    members = {c: np.where(y == c)[0] for c in classes}
    for a, b in pairs:
        n_task = max(n_task, len(members[a]) + len(members[b]))

    c_total = len(pairs) if pad_tasks_to is None else max(
        len(pairs), -(-len(pairs) // pad_tasks_to) * pad_tasks_to)

    d = x.shape[1]
    xt = np.zeros((c_total, n_task, d), np.float32)
    yt = np.zeros((c_total, n_task), np.float32)
    mk = np.zeros((c_total, n_task), bool)
    pr = np.zeros((c_total, 2), y.dtype if y.dtype.kind in "if" else np.int64)
    for t, (a, b) in enumerate(pairs):
        ia, ib = members[a], members[b]
        k = len(ia) + len(ib)
        xt[t, :k] = np.concatenate([x[ia], x[ib]], axis=0)
        yt[t, :len(ia)] = 1.0
        yt[t, len(ia):k] = -1.0
        mk[t, :k] = True
        pr[t] = (a, b)
    return OvOTasks(x=xt, y=yt, mask=mk, pairs=pr, classes=classes)


def vote(decisions: jax.Array, pairs: np.ndarray, classes: np.ndarray,
         n_real_tasks: int) -> jax.Array:
    """Majority vote.  decisions: (C_padded, n_test) binary decision values.

    Vectorized: the old Python loop of C scatter-adds is now a
    precomputed (C, 2) class-index array + one pair of (n_test, C) @
    (C, m) matmuls in ``multiclass.vote_decision`` (with the same tiny
    tanh-margin tiebreaker, LIBSVM-style stability).

    Returns (n_test,) predicted class indices into ``classes``.
    """
    from repro.core import multiclass as MC  # local: avoid import cycle

    m = len(classes)
    cls_index = {c: i for i, c in enumerate(classes)}
    pair_idx = np.array(
        [[cls_index[a], cls_index[b]] for a, b in np.asarray(pairs)[:n_real_tasks]],
        np.int64)
    return MC.vote_decision(jnp.asarray(decisions)[:n_real_tasks],
                            pair_idx, m)
