"""Cascade SVM — hierarchical shard-solve-reduce training with a
certified global KKT exit (Graf et al., *Parallel Support Vector
Machines: The Cascade SVM*, NIPS 2004; the hierarchical tier the source
paper's MPI layer stops short of).

The data-parallel solver in ``repro.core.smo`` shards ONE QP's sample
axis — every worker still touches every SMO iteration. The cascade is
the orthogonal decomposition: partition the training set into S shards,
solve each shard's sub-SVM INDEPENDENTLY, and combine by support-vector
union up a binary reduction tree —

    shard 0   shard 1   shard 2   shard 3        round r
       \\        /          \\        /
        SV-union            SV-union             level 1
            \\                  /
             `----- SV-union -'
                     root                        level log2(S)

— then close the loop: non-SVs discarded at a leaf can re-emerge as
global SVs, so after the root solve the certificate is checked over the
FULL dataset and, if it fails, the surviving global SV set is fed back
into every shard for another round (each node warm-started from the
previous solution). Termination is *certified*, never assumed: a round
only declares convergence when ``smo.kkt_violation`` — recomputed from
scratch in float64, the same harness convention the KKT-certificate
tests pin — is <= tol over all n samples.

Four variants share one driver (``_run_cascade``):

* ``cascade_binary`` / ``cascade_svr`` — exact-kernel cascades. Leaves
  and multi-node merge levels run through ``dist.fit_taskset`` (the
  bucketed, optionally mesh-task-parallel vmapped machinery) with
  per-task ``alpha0`` warm starts; single-node levels — including the
  S = 1 degenerate cascade and every root — use a scalar jitted solve
  whose jit body is identical to ``svm._jitted_binary_fit``'s, so a
  one-shard cascade reproduces the unsharded solver bit for bit.
  Because pair-update SMO preserves its equality constraint invariant,
  every merged warm start is projected back onto ``sum_i y_i a_i = 0``
  (``_repair_equality``) before it seeds a node.
* ``cascade_dcd`` / ``cascade_dcd_svr`` — low-rank cascades over an
  ALREADY-TRANSFORMED feature matrix Φ (one shared feature map for the
  whole dataset — shards slice rows of Φ, they never refit landmarks).
  Nodes are jitted ``linear.linear_svc/svr`` solves with beta warm
  starts; the augmented-bias dual has no equality constraint, so no
  repair is needed, and the certificate pins r = 0.

Partitioning is deterministic round-robin (shard s owns rows
``s::S``) — no RNG, and label-sorted inputs still give every shard a
class mixture.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dist
from repro.core import kernel_engine as KE
from repro.core import kernels as K
from repro.core import linear
from repro.core import multiclass as MC
from repro.core import smo

# support threshold, relative to C (matches svm._sv_threshold; kept
# local — svm imports this module, not the other way around)
SV_EPS = 1e-8

# rows per float64 certificate block: bounds the live cross-Gram slab to
# CHUNK * n_sv floats regardless of n
CERT_CHUNK = 8192


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Cascade topology + termination knobs.

    shards: leaf count S (clamped to n); 1 degenerates to the plain
            unsharded solve (bit-identical to it on the exact path).
    rounds: max feedback rounds. Round 2+ re-solves every shard on
            ``partition ∪ global SVs`` warm-started from the previous
            solution; the loop exits early on certificate success or on
            a fixed point (identical support set AND violation — more
            rounds cannot make progress).
    tol:    global certificate tolerance; None inherits the solver tol.
    """

    shards: int = 4
    rounds: int = 8
    tol: Optional[float] = None


class CascadeResult(NamedTuple):
    """Global solution + certificate trail of one cascade run."""

    alpha: np.ndarray          # (n,) dual vector (per-sample beta for SVR)
    b: float
    n_iter: int                # solver iterations summed over all nodes
    converged: bool            # final certified violation <= tol
    kkt: float                 # final certified violation (f64 recompute)
    rounds: int                # feedback rounds actually run
    history: tuple             # per-round dicts: nodes, sv, kkt, n_iter
    alpha_raw: Optional[np.ndarray] = None   # (2n,) [alpha; alpha*] (SVR)
    w: Optional[np.ndarray] = None           # (k,) primal weights (low-rank)


def partition_indices(n: int, shards: int) -> list[np.ndarray]:
    """Deterministic round-robin partition: shard s owns rows ``s::S``.
    Interleaving keeps every shard class-mixed even when the caller's
    rows arrive sorted by label (the common dataset layout)."""
    s = max(1, min(int(shards), int(n)))
    return [np.arange(p, n, s, dtype=np.int64) for p in range(s)]


def validate_cascade(solver: Optional[str],
                     cascade: CascadeConfig) -> None:
    """Fail fast on configurations the cascade cannot honor. ``solver``
    is None on the low-rank path (which ignores the solver knob and
    always runs DCD nodes)."""
    if solver is not None and solver != "smo":
        raise ValueError(
            "shard='cascade' warm-starts sub-SVM solves and requires "
            f"solver='smo' (got solver={solver!r})")
    if cascade.shards < 1:
        raise ValueError(f"cascade_shards must be >= 1 "
                         f"(got {cascade.shards})")
    if cascade.rounds < 1:
        raise ValueError(f"cascade_rounds must be >= 1 "
                         f"(got {cascade.rounds})")


def _repair_equality(v: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Project a merged warm start back onto ``sum_i y_i v_i = 0`` by
    draining entries toward 0, largest first, on the offending sign
    side — every step stays inside the box (all boxes here contain 0 on
    the side being drained) and touches the fewest coordinates. Host
    float64: the residue being cancelled is itself a rounding-scale
    quantity and f32 arithmetic would leave a remainder.

    SVC passes v = alpha >= 0 with y the ±1 labels; SVR passes v = beta
    (signed) with y = 1, which makes the constraint ``sum beta = 0``."""
    v = np.asarray(v, np.float64).copy()
    c = np.asarray(y, np.float64) * v
    s = float(c.sum())
    if s == 0.0:
        return v.astype(np.float32)
    sign = 1.0 if s > 0.0 else -1.0
    excess = abs(s)
    idx = np.where(c * sign > 0.0)[0]
    for i in idx[np.argsort(-np.abs(v[idx]))]:
        take = min(abs(v[i]), excess)
        v[i] -= np.sign(v[i]) * take
        excess -= take
        if excess <= 0.0:
            break
    return v.astype(np.float32)


class _NodeFit(NamedTuple):
    """One solved cascade node (indices are GLOBAL row ids)."""

    idx: np.ndarray            # (k,) int64 rows of the node's samples
    alpha: np.ndarray          # (k,) per-sample dual (beta for SVR)
    b: float
    n_iter: int
    converged: bool
    raw: Optional[np.ndarray] = None   # (2k,) doubled [alpha; alpha*]
    w: Optional[np.ndarray] = None     # (k_feat,) DCD primal weights


# ------------------------------------------------------------- node solvers
@lru_cache(maxsize=128)
def _jitted_node_fit(kind: str, warm: bool, epsilon: float,
                     cfg: smo.SMOConfig, kernel: K.KernelParams, ecfg):
    """Scalar (single-node) jitted solves, cached per static config.

    The cold "svc" variant's lambda body is the same expression
    ``svm._jitted_binary_fit`` jits, so the S = 1 cascade replays the
    exact unsharded trace (bit-identical alphas/b); warm variants add
    only the alpha0 argument."""
    if kind == "svc":
        if warm:
            return jax.jit(lambda xx, yv, a0: smo.binary_smo(
                xx, yv, cfg=cfg, kernel=kernel, engine=ecfg, alpha0=a0))
        return jax.jit(lambda xx, yv: smo.binary_smo(
            xx, yv, cfg=cfg, kernel=kernel, engine=ecfg))
    if warm:
        return jax.jit(lambda xx, yv, a0: smo.svr_smo(
            xx, yv, epsilon=epsilon, cfg=cfg, kernel=kernel, engine=ecfg,
            alpha0=a0))
    return jax.jit(lambda xx, yv: smo.svr_smo(
        xx, yv, epsilon=epsilon, cfg=cfg, kernel=kernel, engine=ecfg))


@lru_cache(maxsize=64)
def _jitted_dcd(kind: str, warm: bool, epsilon: float, cfg: linear.DCDConfig):
    """Jitted low-rank node solves. The cold "svc" variant matches
    ``linear.fit_linear_svc``'s body (S = 1 bit-identity for the DCD
    path); warm variants thread the beta warm start."""
    if kind == "svc":
        if warm:
            return jax.jit(lambda ph, yv, a0: linear.linear_svc(
                ph, yv, cfg=cfg, alpha0=a0))
        return jax.jit(lambda ph, yv: linear.linear_svc(ph, yv, cfg=cfg))
    if warm:
        return jax.jit(lambda ph, yv, a0: linear.linear_svr(
            ph, yv, epsilon=epsilon, cfg=cfg, alpha0=a0))
    return jax.jit(lambda ph, yv: linear.linear_svr(
        ph, yv, epsilon=epsilon, cfg=cfg))


# --------------------------------------------------------- f64 certificates
def _cross_gram_apply(kernel: K.KernelParams, x: np.ndarray,
                      x_sv: np.ndarray, coef64: np.ndarray) -> np.ndarray:
    """g = K(x, x_sv) @ coef in float64, CERT_CHUNK rows at a time.
    Gram blocks come off the f32 device kernel (the precision the model
    itself lives in) and are accumulated in f64 — the same convention
    the KKT-certificate test harness uses."""
    n = x.shape[0]
    gram_fn = K.make_gram_fn(kernel)
    xs = jnp.asarray(x_sv, jnp.float32)
    out = np.empty((n,), np.float64)
    for s in range(0, n, CERT_CHUNK):
        e = min(s + CERT_CHUNK, n)
        blk = np.asarray(gram_fn(jnp.asarray(x[s:e], jnp.float32), xs),
                         np.float64)
        out[s:e] = blk @ coef64
    return out


# ----------------------------------------------------------------- adapters
class _ExactSVCAdapter:
    """Exact-kernel classification: shard samples, solve with SMO."""

    def __init__(self, x, yy, *, smo_cfg, kernel, engine, mesh,
                 worker_axes):
        self.x = np.asarray(x, np.float32)
        self.yy = np.asarray(yy, np.float32)
        self.yy64 = self.yy.astype(np.float64)
        self.cfg = smo_cfg
        self.kernel = kernel
        self.ecfg = (KE.EngineConfig(backend=engine)
                     if isinstance(engine, str) else engine)
        self.mesh = mesh
        self.worker_axes = tuple(worker_axes)
        self.thr = SV_EPS * smo_cfg.C

    kind = "svc"

    def is_sv(self, alpha: np.ndarray) -> np.ndarray:
        return alpha > self.thr

    def repair(self, idx: np.ndarray, v: np.ndarray) -> np.ndarray:
        return _repair_equality(v, self.yy[idx])

    def _solve_one(self, idx, a0):
        xx = jnp.asarray(self.x[idx])
        yv = jnp.asarray(self.yy[idx])
        if a0 is None:
            r = _jitted_node_fit(self.kind, False, 0.0, self.cfg,
                                 self.kernel, self.ecfg)(xx, yv)
        else:
            r = _jitted_node_fit(self.kind, True, 0.0, self.cfg,
                                 self.kernel, self.ecfg)(
                                     xx, yv, jnp.asarray(a0))
        return _NodeFit(idx=idx, alpha=np.asarray(r.alpha),
                        b=float(r.b), n_iter=int(r.n_iter),
                        converged=bool(r.converged))

    def _task_y(self, idx):
        return self.yy[idx]

    def _taskset_kwargs(self):
        return {}

    def solve_level(self, nodes):
        """nodes: [(idx, a0-or-None)] -> [_NodeFit], order preserved."""
        if len(nodes) == 1:
            idx, a0 = nodes[0]
            return [self._solve_one(idx, a0)]
        tasks = tuple(
            MC.BinaryTask(x=self.x[idx], y=self._task_y(idx), pos=1,
                          neg=0, indices=idx) for idx, _ in nodes)
        ts = MC.TaskSet(tasks=tasks, classes=np.array([-1.0, 1.0]),
                        strategy="cascade")
        sizes = ts.sizes
        a0m = None
        if any(a0 is not None for _, a0 in nodes):
            # zeros on cold slots reproduce the cold start: clip(0) = 0
            # and matvec(0) is an exact zero f-cache correction
            a0m = np.zeros((len(nodes), int(sizes.max())), np.float32)
            for t, (_, a0) in enumerate(nodes):
                if a0 is not None:
                    a0m[t, :len(a0)] = a0
        fit = dist.fit_taskset(
            ts, mesh=self.mesh, worker_axes=self.worker_axes,
            solver="smo", smo_cfg=self.cfg, kernel=self.kernel,
            engine=self.ecfg, shard="task", alpha0=a0m,
            **self._taskset_kwargs())
        return [
            _NodeFit(idx=nodes[t][0],
                     alpha=fit.alpha[t, :int(sizes[t])].copy(),
                     b=float(fit.b[t]), n_iter=int(fit.n_iter[t]),
                     converged=bool(fit.converged[t]))
            for t in range(len(nodes))
        ]

    def certify(self, alpha_full: np.ndarray, root: _NodeFit) -> float:
        sv = self.is_sv(alpha_full)
        if sv.any():
            coef = (alpha_full.astype(np.float64) * self.yy64)[sv]
            g = _cross_gram_apply(self.kernel, self.x, self.x[sv], coef)
        else:
            g = np.zeros((len(alpha_full),), np.float64)
        f = g - self.yy64
        return float(smo.kkt_violation(alpha_full, self.yy, f, 0.0,
                                       self.cfg.C))


class _ExactSVRAdapter(_ExactSVCAdapter):
    """Exact-kernel epsilon-SVR: duals are per-sample betas, the scalar
    root solve additionally yields the raw doubled multipliers the
    certificate (and ``alpha_raw_``) needs."""

    def __init__(self, x, y, *, epsilon, smo_cfg, kernel, engine, mesh,
                 worker_axes):
        super().__init__(x, np.asarray(y, np.float32), smo_cfg=smo_cfg,
                         kernel=kernel, engine=engine, mesh=mesh,
                         worker_axes=worker_axes)
        self.epsilon = float(epsilon)

    kind = "svr"

    def is_sv(self, beta: np.ndarray) -> np.ndarray:
        return np.abs(beta) > self.thr

    def repair(self, idx: np.ndarray, v: np.ndarray) -> np.ndarray:
        return _repair_equality(v, np.ones_like(v))

    def _solve_one(self, idx, a0):
        xx = jnp.asarray(self.x[idx])
        yv = jnp.asarray(self.yy[idx])
        if a0 is None:
            r = _jitted_node_fit(self.kind, False, self.epsilon, self.cfg,
                                 self.kernel, self.ecfg)(xx, yv)
        else:
            b0 = jnp.asarray(a0)
            a02 = jnp.concatenate([jnp.maximum(b0, 0.0),
                                   jnp.maximum(-b0, 0.0)])
            r = _jitted_node_fit(self.kind, True, self.epsilon, self.cfg,
                                 self.kernel, self.ecfg)(xx, yv, a02)
        return _NodeFit(idx=idx, alpha=np.asarray(r.beta),
                        b=float(r.b), n_iter=int(r.n_iter),
                        converged=bool(r.converged),
                        raw=np.asarray(r.alpha))

    def _taskset_kwargs(self):
        return {"svr_epsilon": self.epsilon}

    def certify(self, beta_full: np.ndarray, root: _NodeFit) -> float:
        n = len(beta_full)
        sv = self.is_sv(beta_full)
        if sv.any():
            g = _cross_gram_apply(self.kernel, self.x, self.x[sv],
                                  beta_full.astype(np.float64)[sv])
        else:
            g = np.zeros((n,), np.float64)
        f = np.concatenate([g + self.epsilon - self.yy64,
                            g - self.epsilon - self.yy64])
        s2 = np.concatenate([np.ones((n,), np.float32),
                             -np.ones((n,), np.float32)])
        a2 = self.scatter_raw(beta_full, root)
        return float(smo.kkt_violation(a2, s2, f, 0.0, self.cfg.C))

    def scatter_raw(self, beta_full: np.ndarray,
                    root: _NodeFit) -> np.ndarray:
        """(2n,) raw doubled multipliers from the root's actual solve
        (the root is always scalar-solved, so ``raw`` is present)."""
        n = len(beta_full)
        a2 = np.zeros((2 * n,), np.float32)
        k = len(root.idx)
        a2[root.idx] = root.raw[:k]
        a2[n + root.idx] = root.raw[k:]
        return a2


class _DCDSVCAdapter:
    """Low-rank classification over a SHARED feature matrix Φ: shards
    slice rows of Φ, nodes are augmented-bias DCD solves (no equality
    constraint — warm starts need no repair), the certificate pins
    r = 0 (the test-harness convention for the linear path)."""

    def __init__(self, phi, yy, *, dcd_cfg):
        self.phi = jnp.asarray(phi, jnp.float32)
        self.yy = np.asarray(yy, np.float32)
        self.yy64 = self.yy.astype(np.float64)
        self.cfg = dcd_cfg
        self.thr = SV_EPS * dcd_cfg.C
        # Phibar = [Phi, bias] in f64 once — the certificate operand
        n = self.phi.shape[0]
        self.phib64 = np.concatenate(
            [np.asarray(self.phi, np.float64),
             np.full((n, 1), dcd_cfg.bias, np.float64)], axis=1)

    kind = "svc"

    def is_sv(self, alpha: np.ndarray) -> np.ndarray:
        return alpha > self.thr

    def repair(self, idx: np.ndarray, v: np.ndarray) -> np.ndarray:
        return np.asarray(v, np.float32)   # no equality constraint

    def _solve_one(self, idx, a0):
        ph = self.phi[jnp.asarray(idx)]
        yv = jnp.asarray(self.yy[idx])
        if a0 is None:
            r = _jitted_dcd(self.kind, False, 0.0, self.cfg)(ph, yv)
        else:
            r = _jitted_dcd(self.kind, True, 0.0, self.cfg)(
                ph, yv, jnp.asarray(a0))
        return _NodeFit(idx=idx, alpha=np.asarray(r.alpha),
                        b=float(r.b), n_iter=int(r.n_iter),
                        converged=bool(r.converged), w=np.asarray(r.w))

    def solve_level(self, nodes):
        return [self._solve_one(idx, a0) for idx, a0 in nodes]

    def certify(self, alpha_full: np.ndarray, root: _NodeFit) -> float:
        wbar = self.phib64.T @ (alpha_full.astype(np.float64) * self.yy64)
        f = self.phib64 @ wbar - self.yy64
        return float(smo.kkt_violation(alpha_full, self.yy, f, 0.0,
                                       self.cfg.C, r=0.0))


class _DCDSVRAdapter(_DCDSVCAdapter):
    """Low-rank epsilon-SVR cascade (doubled DCD per node)."""

    def __init__(self, phi, y, *, epsilon, dcd_cfg):
        super().__init__(phi, np.asarray(y, np.float32), dcd_cfg=dcd_cfg)
        self.epsilon = float(epsilon)

    kind = "svr"

    def is_sv(self, beta: np.ndarray) -> np.ndarray:
        return np.abs(beta) > self.thr

    def _solve_one(self, idx, a0):
        ph = self.phi[jnp.asarray(idx)]
        yv = jnp.asarray(self.yy[idx])
        if a0 is None:
            r = _jitted_dcd(self.kind, False, self.epsilon, self.cfg)(
                ph, yv)
        else:
            r = _jitted_dcd(self.kind, True, self.epsilon, self.cfg)(
                ph, yv, jnp.asarray(a0))
        return _NodeFit(idx=idx, alpha=np.asarray(r.beta),
                        b=float(r.b), n_iter=int(r.n_iter),
                        converged=bool(r.converged),
                        raw=np.asarray(r.alpha), w=np.asarray(r.w))

    def scatter_raw(self, beta_full: np.ndarray,
                    root: _NodeFit) -> np.ndarray:
        n = len(beta_full)
        a2 = np.zeros((2 * n,), np.float32)
        k = len(root.idx)
        a2[root.idx] = root.raw[:k]
        a2[n + root.idx] = root.raw[k:]
        return a2

    def certify(self, beta_full: np.ndarray, root: _NodeFit) -> float:
        n = len(beta_full)
        wbar = self.phib64.T @ beta_full.astype(np.float64)
        g = self.phib64 @ wbar
        f = np.concatenate([g + self.epsilon - self.yy64,
                            g - self.epsilon - self.yy64])
        s2 = np.concatenate([np.ones((n,), np.float32),
                             -np.ones((n,), np.float32)])
        a2 = self.scatter_raw(beta_full, root)
        return float(smo.kkt_violation(a2, s2, f, 0.0, self.cfg.C,
                                       r=0.0))


# ------------------------------------------------------------------- driver
def _merge(a: _NodeFit, b: _NodeFit, adapter):
    """SV-union of two solved children -> (idx, warm start) for the
    parent. Duplicated rows (feedback rounds re-inject global SVs into
    every shard) resolve first-wins; the merged start is projected back
    onto the solver's equality constraint by ``adapter.repair``."""
    ka, kb = adapter.is_sv(a.alpha), adapter.is_sv(b.alpha)
    idx = np.concatenate([a.idx[ka], b.idx[kb]])
    vals = np.concatenate([a.alpha[ka], b.alpha[kb]])
    if len(idx) == 0:
        # degenerate children (e.g. single-class shards solved to
        # alpha = 0): hand the parent a token sample per child so the
        # solve stays non-empty
        idx = np.unique(np.concatenate([a.idx[:1], b.idx[:1]]))
        return idx, None
    uniq, first = np.unique(idx, return_index=True)
    return uniq, adapter.repair(uniq, vals[first])


def _run_cascade(n: int, adapter, cascade: CascadeConfig,
                 tol: float) -> tuple:
    """Shared round/tree driver; returns (alpha_full, root, n_iter,
    converged, kkt, rounds, history)."""
    parts = partition_indices(n, cascade.shards)
    prev_alpha = None      # (n,) last round's global scatter
    prev_sv = None
    prev_viol = None
    history = []
    total_iter = 0
    converged = False
    viol = float("inf")
    rnd = 0
    for rnd in range(1, max(1, cascade.rounds) + 1):
        if prev_alpha is None:
            leaves = [(p, None) for p in parts]
        else:
            sv_idx = np.flatnonzero(adapter.is_sv(prev_alpha))
            leaves = []
            for p in parts:
                idx = np.unique(np.concatenate([p, sv_idx]))
                leaves.append((idx, adapter.repair(idx, prev_alpha[idx])))
        solved = adapter.solve_level(leaves)
        total_iter += sum(s.n_iter for s in solved)
        n_nodes = len(solved)
        while len(solved) > 1:
            carry = None
            if len(solved) % 2:
                carry, solved = solved[-1], solved[:-1]
            to_solve = [_merge(solved[i], solved[i + 1], adapter)
                        for i in range(0, len(solved), 2)]
            new = adapter.solve_level(to_solve)
            total_iter += sum(s.n_iter for s in new)
            n_nodes += len(new)
            solved = new + ([carry] if carry is not None else [])
        root = solved[0]
        alpha_full = np.zeros((n,), np.float32)
        alpha_full[root.idx] = root.alpha
        viol = adapter.certify(alpha_full, root)
        sv_now = np.flatnonzero(adapter.is_sv(alpha_full))
        history.append({"round": rnd, "nodes": n_nodes,
                        "root_size": int(len(root.idx)),
                        "sv": int(len(sv_now)), "kkt": viol,
                        "n_iter": total_iter})
        prev_alpha = alpha_full
        if viol <= tol:
            converged = True
            break
        if (prev_sv is not None and prev_viol is not None
                and viol == prev_viol
                and np.array_equal(sv_now, prev_sv)):
            # fixed point: feedback reproduced the identical solution,
            # further rounds cannot move the certificate
            break
        prev_sv, prev_viol = sv_now, viol
    return (prev_alpha, root, total_iter, converged, viol, rnd,
            tuple(history))


# ------------------------------------------------------------- entry points
def cascade_binary(x, yy, *,
                   smo_cfg: smo.SMOConfig = smo.SMOConfig(),
                   kernel: K.KernelParams = K.KernelParams(),
                   engine=None,
                   cascade: CascadeConfig = CascadeConfig(),
                   mesh=None,
                   worker_axes: tuple[str, ...] = ("workers",)
                   ) -> CascadeResult:
    """Exact-kernel binary cascade. ``yy`` in {+1, -1}; with a mesh,
    each level's shard solves distribute task-parallel through
    ``dist.fit_taskset``."""
    adapter = _ExactSVCAdapter(x, yy, smo_cfg=smo_cfg, kernel=kernel,
                               engine=engine, mesh=mesh,
                               worker_axes=worker_axes)
    tol = smo_cfg.tol if cascade.tol is None else cascade.tol
    alpha, root, n_iter, conv, viol, rounds, hist = _run_cascade(
        len(adapter.yy), adapter, cascade, tol)
    return CascadeResult(alpha=alpha, b=root.b, n_iter=n_iter,
                         converged=conv, kkt=viol, rounds=rounds,
                         history=hist)


def cascade_svr(x, y, *,
                epsilon: float = 0.1,
                smo_cfg: smo.SMOConfig = smo.SMOConfig(),
                kernel: K.KernelParams = K.KernelParams(),
                engine=None,
                cascade: CascadeConfig = CascadeConfig(),
                mesh=None,
                worker_axes: tuple[str, ...] = ("workers",)
                ) -> CascadeResult:
    """Exact-kernel epsilon-SVR cascade; ``alpha`` is the per-sample
    beta vector, ``alpha_raw`` the (2n,) doubled scatter of the root
    solve."""
    adapter = _ExactSVRAdapter(x, y, epsilon=epsilon, smo_cfg=smo_cfg,
                               kernel=kernel, engine=engine, mesh=mesh,
                               worker_axes=worker_axes)
    tol = smo_cfg.tol if cascade.tol is None else cascade.tol
    beta, root, n_iter, conv, viol, rounds, hist = _run_cascade(
        len(adapter.yy), adapter, cascade, tol)
    return CascadeResult(alpha=beta, b=root.b, n_iter=n_iter,
                         converged=conv, kkt=viol, rounds=rounds,
                         history=hist,
                         alpha_raw=adapter.scatter_raw(beta, root))


def cascade_dcd(phi, yy, *,
                dcd_cfg: linear.DCDConfig = linear.DCDConfig(),
                cascade: CascadeConfig = CascadeConfig()
                ) -> CascadeResult:
    """Low-rank classification cascade over a shared feature matrix
    ``phi`` (transform the full X ONCE, then cascade over row slices).
    Returns the root's primal ``w`` for serving."""
    adapter = _DCDSVCAdapter(phi, yy, dcd_cfg=dcd_cfg)
    tol = dcd_cfg.tol if cascade.tol is None else cascade.tol
    alpha, root, n_iter, conv, viol, rounds, hist = _run_cascade(
        len(adapter.yy), adapter, cascade, tol)
    return CascadeResult(alpha=alpha, b=root.b, n_iter=n_iter,
                         converged=conv, kkt=viol, rounds=rounds,
                         history=hist, w=root.w)


def cascade_dcd_svr(phi, y, *,
                    epsilon: float = 0.1,
                    dcd_cfg: linear.DCDConfig = linear.DCDConfig(),
                    cascade: CascadeConfig = CascadeConfig()
                    ) -> CascadeResult:
    """Low-rank epsilon-SVR cascade over a shared feature matrix."""
    adapter = _DCDSVRAdapter(phi, y, epsilon=epsilon, dcd_cfg=dcd_cfg)
    tol = dcd_cfg.tol if cascade.tol is None else cascade.tol
    beta, root, n_iter, conv, viol, rounds, hist = _run_cascade(
        len(adapter.yy), adapter, cascade, tol)
    return CascadeResult(alpha=beta, b=root.b, n_iter=n_iter,
                         converged=conv, kkt=viol, rounds=rounds,
                         history=hist, w=root.w,
                         alpha_raw=adapter.scatter_raw(beta, root))
