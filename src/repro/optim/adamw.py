"""AdamW + SGD + schedules (pure-JAX pytree optimizer, optax-shaped).

Optimizer state shards exactly like the params (same logical specs), so
FSDP sharding covers the Adam moments too (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                          nu=zeros(params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        if self.grad_clip:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return p - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                             + self.weight_decay * p)
        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float = 0.01
    momentum: float = 0.0

    def init(self, params):
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(jnp.zeros_like, params), nu=None)

    def update(self, grads, state, params):
        step = state.step + 1
        if self.momentum:
            mu = jax.tree.map(lambda m, g: self.momentum * m + g,
                              state.mu, grads)
        else:
            mu = grads
        new_params = jax.tree.map(lambda p, g: p - self.lr * g, params, mu)
        return new_params, AdamWState(step=step, mu=mu, nu=None)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(*, peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return lr
