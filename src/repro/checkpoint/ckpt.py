"""Numpy-npz pytree checkpointing (offline container: no orbax).

Saves any pytree of arrays with its treedef; restore optionally
device_puts leaves with provided shardings (sharding-aware restore for
the launcher).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import numpy as np
import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(path: str, tree: Any, *, step: Optional[int] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    keys, vals, _ = _flatten_with_paths(tree)
    arrays = {f"arr_{i}": np.asarray(v) for i, v in enumerate(vals)}
    meta = {"keys": keys, "step": step}
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def restore(path: str, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz",
                   allow_pickle=False)
    keys, vals, treedef = _flatten_with_paths(like)
    n = len(vals)
    loaded = [data[f"arr_{i}"] for i in range(n)]
    for i, (ref, new) in enumerate(zip(vals, loaded)):
        if tuple(ref.shape) != tuple(new.shape):
            raise ValueError(f"shape mismatch for {keys[i]}: "
                             f"{ref.shape} vs {new.shape}")
    if shardings is not None:
        flat_sh = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        loaded = [jax.device_put(v, s) for v, s in zip(loaded, flat_sh)]
    return jax.tree_util.tree_unflatten(treedef, loaded)


def latest_step(path: str) -> Optional[int]:
    data = np.load(path if path.endswith(".npz") else path + ".npz",
                   allow_pickle=False)
    meta = json.loads(str(data["__meta__"]))
    return meta.get("step")
