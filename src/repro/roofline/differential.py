"""Depth-differential roofline probe.

XLA ``cost_analysis`` (and the HLO collective scan) count a
``lax.scan`` body ONCE, not per trip — so full-depth dry-run costs
undercount by ~n_layers. This probe compiles each (arch x shape) at two
shallow depths (L1, L2), recovers

    per_layer = (C(L2) - C(L1)) / (L2 - L1)
    total(L)  = C(L1) + per_layer * (L - L1)

for FLOPs, bytes accessed, and per-kind collective bytes, and writes the
corrected totals to JSONL for the report. Depth pairs respect each
family's structural period (gemma3 local:global groups of 6, zamba2
shared-attn period 6, deepseek-moe leading dense layer).

    PYTHONPATH=src python -m repro.roofline.differential \
        [--arch X --shape Y] [--multi-pod] [--out results/diff.jsonl]

The forced-host-device XLA env is applied in ``main()`` (via
``hillclimb.setup_env``), not at import time — importing this module
must not mutate the process's jax environment.
"""
import argparse
import json
import sys
import traceback

from repro.configs.base import ARCH_NAMES, INPUT_SHAPES, get_config

# (L1, L2) per arch — respecting structural periodicity
DEPTH_PAIRS = {
    "phi3_vision_4p2b": (4, 8),
    "mamba2_780m": (4, 8),
    "phi4_mini_3p8b": (4, 8),
    "gemma3_12b": (6, 12),
    "deepseek_moe_16b": (5, 9),
    "minicpm3_4b": (4, 8),
    "whisper_medium": (4, 8),
    "zamba2_1p2b": (6, 12),
    "qwen2_moe_a2p7b": (4, 8),
    "deepseek_67b": (4, 8),
}


def _extract(res: dict) -> dict:
    c = dict(res["cost"])
    c["collective_total"] = res["collectives"]["total_bytes"]
    for k, v in res["collectives"]["per_kind_bytes"].items():
        c[f"coll_{k}"] = v
    return c


def probe(arch: str, shape: str, *, multi_pod: bool) -> dict:
    from repro.launch.dryrun import lower_combo
    from repro.models import runtime as RT
    RT.set_unroll(True)   # scans lower as unrolled loops: true per-layer cost
    cfg = get_config(arch)
    l1, l2 = DEPTH_PAIRS[arch]
    r1 = lower_combo(arch, shape, multi_pod=multi_pod, n_layers=l1)
    if r1["status"] != "ok":
        return r1
    r2 = lower_combo(arch, shape, multi_pod=multi_pod, n_layers=l2)
    c1, c2 = _extract(r1), _extract(r2)
    full = {}
    for k in c1:
        per = (c2[k] - c1[k]) / (l2 - l1)
        full[k] = c1[k] + per * (cfg.n_layers - l1)
        full[f"per_layer_{k}"] = per
    return {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "ok", "n_devices": r1["n_devices"],
        "depth_pair": [l1, l2],
        "corrected": full,
        "shallow_flops": [c1["flops"], c2["flops"]],
    }


def main(argv=None):
    from repro.roofline.hillclimb import setup_env
    setup_env()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    combos = ([(a, s) for a in ARCH_NAMES for s in INPUT_SHAPES]
              if args.all else [(args.arch, args.shape)])
    out_f = open(args.out, "a") if args.out else None
    fails = 0
    for arch, shape in combos:
        try:
            res = probe(arch, shape, multi_pod=args.multi_pod)
            st = res["status"]
            if st == "ok":
                print(f"OK   {arch} x {shape}: corrected flops/dev = "
                      f"{res['corrected']['flops']:.3e} "
                      f"coll = {res['corrected']['collective_total']:.3e}B",
                      flush=True)
            else:
                print(f"SKIP {arch} x {shape}: {st}", flush=True)
        except Exception as e:
            fails += 1
            res = {"arch": arch, "shape": shape,
                   "status": f"FAIL: {type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-1500:]}
            print(f"FAIL {arch} x {shape}: {e}", flush=True)
        if out_f:
            out_f.write(json.dumps(res) + "\n")
            out_f.flush()
    if out_f:
        out_f.close()
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
