"""§Perf hillclimb driver: variant -> corrected roofline terms.

    PYTHONPATH=src python -m repro.roofline.hillclimb \
        --arch deepseek_67b --shape train_4k --variant bf16_scores \
        --out results/perf.jsonl

Each variant toggles runtime knobs (repro.models.runtime), then measures:
  * depth-differential corrected FLOPs / bytes / collective bytes
    (unrolled shallow compiles — true per-layer costs), and
  * full-depth compile temp/arg memory (peak per-device bytes — the
    "does it fit 16 GB HBM" check).

The 512-forced-host-device XLA environment is set up in ``main()``
(before any jax import), NOT at import time: other tooling (the SVM
kernel autotuner, ``inspect_hlo``) imports this module for its VARIANTS
table, and an import-time ``os.environ`` mutation would silently poison
every jax backend in the host process.
"""
import argparse
import json
import os
import sys


def setup_env(n_devices: int = 512) -> None:
    """Force the multi-host-device CPU platform for dry-run compiles.

    Must run before jax initializes its backends — i.e. first thing in
    a CLI entry point, never at module import.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_devices} "
            + flags).strip()

VARIANTS = {
    "baseline": {},
    "bf16_scores": {"scores_bf16": True},
    "remat_dots": {"remat_policy": "dots"},
    "remat_none": {"remat_policy": "none"},
    "chunk_attn_4k": {"chunked_threshold": 4096},
    "bf16+dots": {"scores_bf16": True, "remat_policy": "dots"},
    "bf16+chunk": {"scores_bf16": True, "chunked_threshold": 4096},
    "bf16+dots+chunk": {"scores_bf16": True, "remat_policy": "dots",
                        "chunked_threshold": 4096},
    "onehot_embed": {"embed_onehot": True},
    "moe_grouped": {"moe_grouped": True},
    "grouped+bf16": {"moe_grouped": True, "scores_bf16": True},
    "onehot+bf16": {"embed_onehot": True, "scores_bf16": True},
    "accum4": {"microbatches": 4},
    "fit4": {"scores_bf16": True, "chunked_threshold": 4096,
             "microbatches": 4},
    "fit8": {"scores_bf16": True, "chunked_threshold": 4096,
             "microbatches": 8},
    "grouped+accum4": {"moe_grouped": True, "microbatches": 4},
    "serve_tp": {"serve_pure_tp": True},
    "serve_tp+grouped": {"serve_pure_tp": True, "moe_grouped": True},
    "window_sp": {"window_cache_sp": True},
    "serve_tp+window_sp": {"serve_pure_tp": True, "window_cache_sp": True},
    "serve_tp+window_sp+onehot": {"serve_pure_tp": True,
                                  "window_cache_sp": True,
                                  "embed_onehot": True},
    "gather_w": {"gather_weights": True},
    "gather_w+accum4": {"gather_weights": True, "microbatches": 4},
    "gather_w+accum8": {"gather_weights": True, "microbatches": 8},
    "accum8": {"microbatches": 8},
    "accum16": {"microbatches": 16},
    "accum16+chunk": {"microbatches": 16, "chunked_threshold": 4096},
    "accum32": {"microbatches": 32},
    "xe_shard": {"moe_xe_shard": True},
    "xe_shard+cap1": {"moe_xe_shard": True},  # cap handled via cfg override
    "mla_pad": {"mla_pad_heads": True},
    "mla_pad+accum8": {"mla_pad_heads": True, "microbatches": 8},
}


def run(arch: str, shape: str, variant: str, *, multi_pod: bool = False,
        skip_full: bool = False) -> dict:
    from repro.models import runtime as RT
    RT.set_flags(**VARIANTS[variant])

    from repro.roofline.differential import probe
    from repro.roofline.collect import roofline_terms

    res = probe(arch, shape, multi_pod=multi_pod)
    if res["status"] != "ok":
        return res
    c = res["corrected"]
    # the gradient-accumulation scan body is counted once by
    # cost_analysis (like any scan); each microbatch is identical work,
    # so totals scale by MICROBATCHES
    m = RT.MICROBATCHES
    if m > 1:
        c = {k: v * m for k, v in c.items()}
        res["corrected"] = c
    terms = roofline_terms(flops=c["flops"], hbm_bytes=c["bytes_accessed"],
                           collective_bytes_total=c["collective_total"])

    full_mem = None
    if not skip_full:
        from repro.models import runtime as RT2
        RT2.set_unroll(False)      # full-depth compile uses scans
        from repro.launch.dryrun import lower_combo
        fr = lower_combo(arch, shape, multi_pod=multi_pod)
        full_mem = fr["memory"]

    return {
        "arch": arch, "shape": shape, "variant": variant,
        "status": "ok",
        "corrected": {k: v for k, v in c.items()
                      if not k.startswith("per_layer")},
        "terms": terms,
        "full_depth_memory": full_mem,
    }


def main(argv=None):
    setup_env()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--out", default="")
    ap.add_argument("--skip-full", action="store_true")
    args = ap.parse_args(argv)
    res = run(args.arch, args.shape, args.variant,
              skip_full=args.skip_full)
    if res["status"] == "ok":
        t = res["terms"]
        mem = res.get("full_depth_memory")
        mem_s = (f" temp={mem['temp_bytes'] / 2**30:.1f}GiB"
                 if mem else "")
        print(f"{args.arch} x {args.shape} [{args.variant}]: "
              f"compute={t['t_compute_s'] * 1e3:.1f}ms "
              f"memory={t['t_memory_s'] * 1e3:.1f}ms "
              f"coll={t['t_collective_s'] * 1e3:.1f}ms "
              f"dominant={t['dominant']}{mem_s}", flush=True)
    else:
        print(res["status"])
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(res) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
