"""SVM kernel autotune driver — the roofline machinery pointed at the
SVM hot loops instead of the transformer stack.

    PYTHONPATH=src python -m repro.roofline.svm_tune \
        --kernel rbf_gram --shape 1024x1024x128 \
        --dtype fp32 --budget 12 --out ~/.cache/repro/autotune.json

    # tune every SVM kernel at its default shape sweep:
    PYTHONPATH=src python -m repro.roofline.svm_tune --kernel all

Per (kernel, shape, dtype) this enumerates the feasible tile candidates
(``kernels.autotune.candidates``: pow2 ladders, VMEM-budget filtered),
hillclimbs from the hardcoded default via timed jitted calls and/or the
analytic roofline terms (``--objective``; see ``kernels.autotune``),
prints the per-config roofline breakdown, and merges the winner into
the versioned on-disk tuning cache that ``kernels.ops`` consults at
runtime. Existing cache entries for other keys are preserved.

Shapes are 'x'-separated per kernel:
    rbf_gram            NxMxD        (Gram block)
    rff_features        NxKxD        (samples x random features x dims)
    kkt_select          N            (sample count)
    decision            TxNxD        (test batch x train rows x features)
    multitask_decision  TASKSxTxWxD  (serving bucket)
"""
import argparse
import sys

# default tuning sweeps per kernel (training + serving shape regimes)
DEFAULT_SHAPES = {
    "rbf_gram": ["1024x1024x128", "4096x4096x128"],
    "rff_features": ["16384x256x128"],
    "kkt_select": ["4096", "16384"],
    "decision": ["256x2048x128"],
    "multitask_decision": ["8x256x512x128"],
}


def parse_shape(kernel: str, text: str) -> tuple:
    arity = {"rbf_gram": 3, "rff_features": 3, "kkt_select": 1,
             "decision": 3, "multitask_decision": 4}[kernel]
    parts = tuple(int(p) for p in text.lower().split("x"))
    if len(parts) != arity or any(p <= 0 for p in parts):
        raise ValueError(
            f"{kernel} expects {arity} positive 'x'-separated dims "
            f"(see module docstring), got {text!r}")
    return parts


def tune_one(kernel: str, shape: tuple, *, dtype: str, budget: int,
             objective: str, verbose: bool = True):
    from repro.kernels import autotune
    res = autotune.tune(kernel, shape, dtype=dtype, budget=budget,
                        objective=objective)
    if verbose:
        shape_s = "x".join(map(str, shape))
        print(f"{kernel} {shape_s} [{dtype}] objective={res.objective} "
              f"({len(res.trace)} configs evaluated)")
        for ev in sorted(res.trace, key=lambda e: e.score):
            mark = "*" if ev.config == res.best.config else " "
            wall = f"{ev.wall_s * 1e3:9.2f}ms" if ev.wall_s is not None \
                else "        —"
            print(f"  {mark} {ev.config}  roofline="
                  f"{ev.roofline_s * 1e6:8.1f}us  wall={wall}")
        d, b = res.default, res.best
        if d.wall_s and b.wall_s:
            print(f"  default -> tuned wall: {d.wall_s * 1e3:.2f}ms -> "
                  f"{b.wall_s * 1e3:.2f}ms ({d.wall_s / b.wall_s:.2f}x)")
        print(f"  default -> tuned roofline est: "
              f"{d.roofline_s * 1e6:.1f}us -> {b.roofline_s * 1e6:.1f}us")
    return res


def main(argv=None):
    from repro.kernels import autotune

    ap = argparse.ArgumentParser(
        description="Hillclimb Pallas tile configs for the SVM kernels "
                    "and persist them to the tuning cache.")
    ap.add_argument("--kernel", default="all",
                    choices=sorted(autotune.DEFAULTS) + ["all"])
    ap.add_argument("--shape", action="append", default=[],
                    help="kernel shape, e.g. 1024x1024x128 (repeatable; "
                         "defaults to a per-kernel sweep)")
    ap.add_argument("--dtype", default="fp32", choices=["fp32", "bf16"],
                    help="Gram compute precision to tune for")
    ap.add_argument("--budget", type=int, default=12,
                    help="max configurations evaluated per (kernel, shape)")
    ap.add_argument("--objective", default="auto",
                    choices=["auto", "wall", "roofline"])
    ap.add_argument("--out", default="",
                    help="cache file to merge results into (default: the "
                         "runtime cache path)")
    ap.add_argument("--dry-run", action="store_true",
                    help="tune and report, do not write the cache")
    args = ap.parse_args(argv)

    kernels = (sorted(autotune.DEFAULTS) if args.kernel == "all"
               else [args.kernel])
    jobs = []
    for k in kernels:
        shapes = args.shape if args.shape else DEFAULT_SHAPES[k]
        for s in shapes:
            jobs.append((k, parse_shape(k, s)))

    path = args.out or autotune.default_cache_path()
    cache = autotune.TuningCache.load(path)
    device = autotune.device_kind()
    print(f"device={device}  cache={path}  "
          f"({len(cache.entries)} existing entries)")
    for kernel, shape in jobs:
        res = tune_one(kernel, shape, dtype=args.dtype,
                       budget=args.budget, objective=args.objective)
        cache.put(autotune.cache_key(device, kernel, args.dtype, shape),
                  res)
    if not args.dry_run:
        cache.save(path)
        autotune.reset()   # runtime lookups see the fresh entries
        print(f"wrote {len(cache.entries)} entries -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
