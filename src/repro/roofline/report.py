"""Roofline report: dryrun JSONL -> the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun_*.jsonl

Per (arch x shape x mesh): three roofline terms, dominant bottleneck,
MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (inference) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import sys

from repro.configs.base import INPUT_SHAPES, get_config
from repro.roofline.collect import model_flops, roofline_terms


def load(paths) -> list[dict]:
    rows = []
    for pat in paths:
        for p in glob.glob(pat):
            with open(p) as f:
                for line in f:
                    rows.append(json.loads(line))
    return rows


def analyze(row: dict, diff: dict | None = None) -> dict | None:
    """diff: optional {(arch, shape, multi_pod): corrected-costs dict}
    from the depth-differential probe (scan bodies are otherwise counted
    once by cost_analysis — see repro.roofline.differential)."""
    if row.get("status") != "ok":
        return None
    cfg = get_config(row["arch"])
    shape = INPUT_SHAPES[row["shape"]]
    n_dev = row["n_devices"]
    key = (row["arch"], row["shape"], row.get("multi_pod", False))
    if diff and key in diff:
        c = diff[key]
        flops = c["flops"]
        hbm = c["bytes_accessed"]
        coll = c["collective_total"]
        row = dict(row, corrected=True)
    else:
        flops = row["cost"]["flops"]                  # per device
        hbm = row["cost"]["bytes_accessed"]           # per device
        coll = row["collectives"]["total_bytes"]      # per device
    terms = roofline_terms(flops=flops, hbm_bytes=hbm,
                           collective_bytes_total=coll)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(cfg.param_count(), cfg.active_param_count(),
                         tokens, kind="train")
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(cfg.param_count(), cfg.active_param_count(),
                         tokens, kind="serve")
    else:
        tokens = shape.global_batch                   # one token each
        mf = model_flops(cfg.param_count(), cfg.active_param_count(),
                         tokens, kind="serve")
    mf_per_dev = mf / n_dev
    ratio = mf_per_dev / flops if flops else 0.0
    return dict(row, terms=terms, model_flops_per_dev=mf_per_dev,
                useful_ratio=ratio)


def fmt_table(rows: list[dict], *, multi_pod: bool) -> str:
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "dominant | 6ND/HLO | HBM GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r is None or r.get("multi_pod") != multi_pod:
            continue
        t = r["terms"]
        mem_gib = (r["memory"]["argument_bytes"]
                   + r["memory"]["temp_bytes"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {t['t_compute_s'] * 1e3:.2f} ms "
            f"| {t['t_memory_s'] * 1e3:.2f} ms "
            f"| {t['t_collective_s'] * 1e3:.2f} ms "
            f"| **{t['dominant']}** "
            f"| {r['useful_ratio']:.2f} "
            f"| {mem_gib:.2f} |")
    return "\n".join(out)


def main(argv=None):
    args = argv or sys.argv[1:]
    paths = [a for a in args if not a.startswith("--diff")]
    diff_paths = [a.split("=", 1)[1] for a in args
                  if a.startswith("--diff=")]
    diff = {}
    for r in load(diff_paths):
        if r.get("status") == "ok":
            # differential probes run single-pod; the per-layer costs
            # apply to the single-pod mesh rows
            diff[(r["arch"], r["shape"], r.get("multi_pod", False))] = \
                r["corrected"]
    rows = [analyze(r, diff) for r in load(paths)]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], list(INPUT_SHAPES).index(
        r["shape"])))
    print("## Single-pod (16x16 = 256 chips)\n")
    print(fmt_table(rows, multi_pod=False))
    print("\n## Multi-pod (2x16x16 = 512 chips)\n")
    print(fmt_table(rows, multi_pod=True))
    # dominance summary
    from collections import Counter
    doms = Counter(r["terms"]["dominant"] for r in rows
                   if not r["multi_pod"])
    print(f"\nsingle-pod dominance: {dict(doms)}")
    worst = sorted((r for r in rows if not r["multi_pod"]),
                   key=lambda r: r["useful_ratio"])[:5]
    print("\nworst useful-compute ratios (hillclimb candidates):")
    for r in worst:
        print(f"  {r['arch']} x {r['shape']}: ratio="
              f"{r['useful_ratio']:.3f} dominant="
              f"{r['terms']['dominant']}")
    most_coll = sorted(
        (r for r in rows if not r["multi_pod"]),
        key=lambda r: -(r["terms"]["t_collective_s"]
                        / max(r["terms"]["t_total_est_s"], 1e-12)))[:5]
    print("\nmost collective-bound:")
    for r in most_coll:
        t = r["terms"]
        print(f"  {r['arch']} x {r['shape']}: "
              f"coll={t['t_collective_s'] * 1e3:.2f}ms "
              f"vs total={t['t_total_est_s'] * 1e3:.2f}ms")


if __name__ == "__main__":
    main()
