"""Roofline term extraction from compiled dry-run artifacts.

* ``collective_bytes`` parses post-SPMD HLO text and sums the operand
  bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute (cost_analysis does not report collectives).
* ``roofline_terms`` converts (cost, memory, collectives) into the three
  per-device time terms against TPU v5e constants.
"""
from __future__ import annotations

import re
from typing import Optional

# TPU v5e, per chip
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s (per direction per link)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# matches e.g.:  %x = (f32[128]) all-reduce(...), or fused tuple shapes
_COLL_LINE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum RESULT-shape bytes per collective kind (per device, since the
    post-SPMD module is the per-device program)."""
    per_kind: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    counts: dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for m in _COLL_LINE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue  # started op already counted
        per_kind[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"per_kind_bytes": per_kind, "counts": counts,
            "total_bytes": total}


def summarize_cost(cost: dict) -> dict:
    out = {"flops": float(cost.get("flops", 0.0)),
           "transcendentals": float(cost.get("transcendentals", 0.0)),
           "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    return out


def roofline_terms(*, flops: float, hbm_bytes: float,
                   collective_bytes_total: float,
                   ici_links: int = 4) -> dict:
    """Per-device seconds for each roofline term.

    collective traffic is divided by the per-chip aggregate ICI bandwidth
    (links x per-link BW) — optimistic ring assumption, consistent across
    configs so RELATIVE comparisons hold.
    """
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm_bytes / HBM_BW
    t_coll = collective_bytes_total / (ici_links * ICI_BW_PER_LINK)
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dom[1],
            "t_total_est_s": max(t_compute, t_memory, t_coll)}


def model_flops(_param_count: int, active_param_count: int, tokens: int,
                *, kind: str) -> float:
    # _param_count: total (vs active) params — informational for MoE
    # callers; the 6ND/2ND rule charges only active params
    """6·N·D rule (training); 2·N·D for inference forward passes."""
    n = active_param_count
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
