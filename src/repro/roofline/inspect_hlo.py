"""Collective attribution: top-N largest collectives in a compiled combo,
with their op_name metadata (maps each collective back to model source).

    PYTHONPATH=src python -m repro.roofline.inspect_hlo \
        --arch gemma3_12b --shape decode_32k [--variant onehot_embed]

Forced-device XLA env applied in ``main()`` (``hillclimb.setup_env``),
not at import time.
"""
import argparse
import re
import sys

from repro.roofline.collect import _COLL_LINE, _shape_bytes


def top_collectives(hlo: str, n=15):
    out = []
    for m in _COLL_LINE.finditer(hlo):
        if "-done(" in m.group(0):
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        line = hlo[m.start():hlo.find("\n", m.start())]
        meta = re.search(r'op_name="([^"]+)"', line)
        out.append((b, kind, shape_str[:60],
                    meta.group(1)[-120:] if meta else "?"))
    out.sort(reverse=True)
    return out[:n]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.models import runtime as RT
    from repro.roofline.hillclimb import VARIANTS
    RT.set_flags(**VARIANTS[args.variant])
    if args.unroll:
        RT.set_unroll(True)

    # lower at shallow depth for a readable unrolled module
    from repro.launch import dryrun as DR
    import jax
    from repro.configs.base import get_config, INPUT_SHAPES, supports_shape

    nl = args.n_layers or 2
    r = DR.lower_combo(args.arch, args.shape, multi_pod=False,
                       n_layers=nl, keep_hlo=True)
    print(f"{args.arch} x {args.shape} [{args.variant}] depth={nl} "
          f"unroll={args.unroll}")
    print(f"total collective bytes: {r['collectives']['total_bytes']:.3e}")
    for b, kind, shape, name in top_collectives(r["_hlo"]):
        print(f"  {b / 1e6:10.2f} MB  {kind:19s} {shape:40s} {name}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
