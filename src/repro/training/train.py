"""Training step: sharded cross-entropy loss + AdamW update.

``make_train_step`` builds the jit-able (params, opt_state, batch) ->
(params, opt_state, metrics) function used by both the end-to-end driver
and the dry-run (which lowers it with abstract inputs on the production
mesh).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.optim.adamw import AdamW


def cross_entropy(logits, labels, *, mask=None):
    """Mean token cross-entropy; f32 logsumexp; vocab may be sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss = cross_entropy(logits, batch["labels"],
                             mask=batch.get("loss_mask"))
        return loss + aux, {"loss": loss, "aux": aux}
    return loss_fn


def make_train_step(model: Model, opt: AdamW):
    from repro.models import runtime as RT
    loss_fn = make_loss_fn(model)
    micro = RT.MICROBATCHES

    def train_step(params, opt_state, batch):
        if micro <= 1:
            (total, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # gradient accumulation: scan over microbatches, f32 grad
            # accumulators. Peak activation memory drops ~micro-fold
            # (the batch dim of every layer temp shrinks), trading a
            # longer sequential schedule — the standard fit-into-HBM
            # lever for the train shapes.
            mb = jax.tree.map(
                lambda v: v.reshape((micro, v.shape[0] // micro)
                                    + v.shape[1:]), batch)

            def one(carry, b_i):
                g_acc, t_acc, m_acc = carry
                (total, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, b_i)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / micro,
                    g_acc, grads)
                return (g_acc, t_acc + total / micro,
                        {k: m_acc[k] + metrics[k] / micro
                         for k in m_acc}), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros(()), "aux": jnp.zeros(())}
            (grads, total, metrics), _ = jax.lax.scan(
                one, (zeros, jnp.zeros(()), m0), mb)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, total=total)
        return params, opt_state, metrics
    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return eval_step
