"""Multiclass OvO on the Pavia-like hyperspectral dataset (paper Fig. 4 /
Table IV): 9 classes -> 36 independent binary SMO problems distributed
over mesh workers via shard_map (the MPI layer).

    PYTHONPATH=src python examples/multiclass_pavia.py [n_workers]

Uses forced host devices to emulate n_workers "MPI ranks" on CPU.
"""
import os
import sys

N_WORKERS = int(sys.argv[1]) if len(sys.argv) > 1 else 4
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_WORKERS} "
    + os.environ.get("XLA_FLAGS", ""))
sys.path.insert(0, "src")

import time

import numpy as np
import jax

from repro.core import dist, kernels as K, multiclass as MC, ovo
from repro.core.svm import SVC
from repro.data import (load_pavia_like, make_imbalanced_blobs, normalize,
                        train_test_split)


def main():
    x, y = load_pavia_like(n_per_class=120)
    x = normalize(x)
    xtr, ytr, xte, yte = train_test_split(x, y, test_frac=0.2, seed=0)

    mesh = jax.make_mesh((N_WORKERS,), ("workers",))
    c_tasks = ovo.n_binary_tasks(9)
    print(f"9 classes -> {c_tasks} binary tasks over {N_WORKERS} workers "
          f"(N = C/P = {-(-c_tasks // N_WORKERS)} tasks/worker)")

    t0 = time.perf_counter()
    clf = SVC(solver="smo", mesh=mesh, worker_axes=("workers",)).fit(
        xtr, ytr)
    dt = time.perf_counter() - t0
    print(f"distributed OvO-SMO: fit {dt:.2f}s | "
          f"train acc {clf.score(xtr, ytr):.3f} | "
          f"test acc {clf.score(xte, yte):.3f} | "
          f"converged={clf.converged_}")

    # the paper's baseline: sequential GD ("Multi-Tensorflow")
    t0 = time.perf_counter()
    clf_gd = SVC(solver="gd", gd_steps=800).fit(xtr, ytr)
    dt_gd = time.perf_counter() - t0
    print(f"sequential GD (Multi-TF baseline): fit {dt_gd:.2f}s | "
          f"test acc {clf_gd.score(xte, yte):.3f}")
    print(f"speedup: {dt_gd / dt:.1f}x  <- paper Table IV axis "
          f"(NOTE: on this host all {N_WORKERS} emulated workers share "
          f"ONE cpu core and times include jit compile; "
          f"benchmarks/bench_multiclass.py measures the solvers "
          f"post-warmup)")


def imbalanced_demo():
    """The strategy layer on an IMBALANCED problem: the size-bucketed
    scheduler solves each shape bucket at its own width instead of
    padding every task to the widest class pair."""
    x, y = make_imbalanced_blobs((300, 200, 100, 50, 25), 24, sep=3.0)
    x = normalize(x)
    ts = MC.get_strategy("ovo").build_taskset(x, y)
    for name, cfg in (("padded  ", MC.ScheduleConfig(bucket_by="none")),
                      ("bucketed", MC.ScheduleConfig())):
        sched = MC.build_schedule(ts.sizes, cfg)
        stats = MC.schedule_stats(ts.sizes, sched)
        print(f"{name}: buckets={stats['bucket_widths']} "
              f"padded-FLOP fraction={stats['padded_flop_fraction']:.2f}")
    for strategy in ("ovo", "ovr"):
        clf = SVC(solver="smo", strategy=strategy).fit(x, y)
        print(f"strategy={strategy}: train acc {clf.score(x, y):.3f} "
              f"({clf._taskset.n_tasks} tasks)")


if __name__ == "__main__":
    main()
    imbalanced_demo()
