"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
the synthetic token stream and verify the loss drops (deliverable (b)).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a width-reduced mamba2 (attention-free -> fast on CPU) at ~100M
params. For the mesh-sharded variant of the same loop, see
``python -m repro.launch.train --mesh 2x2``.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="mamba2_780m")
    args = ap.parse_args()
    # ~100M params: 12 layers x d_model 768 mamba2 (+50k vocab embed)
    raise SystemExit(train_main([
        "--arch", args.arch, "--steps", str(args.steps),
        "--batch", "8", "--seq", "256",
        "--d-model", "768", "--n-layers", "12",
        "--lr", "1e-3", "--log-every", "20",
    ]))
