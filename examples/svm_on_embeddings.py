"""SVM head on frozen transformer embeddings — the pod-scale deployment
scenario from DESIGN.md §2: any of the 10 assigned backbones produces
pooled hidden-state features; the paper's distributed OvO-SMO trains a
multiclass probe on top.

    PYTHONPATH=src python examples/svm_on_embeddings.py [arch]
"""
import sys

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core.svm import SVC
from repro.data import normalize
from repro.models.model import Model

ARCH = sys.argv[1] if len(sys.argv) > 1 else "zamba2_1p2b"


def pooled_features(model, params, toks):
    """Mean-pooled logit features (stand-in for hidden-state pooling)."""
    logits, _ = jax.jit(model.forward)(params,
                                       {"tokens": jnp.asarray(toks)})
    return np.asarray(logits, np.float32).mean(axis=1)[:, :256]


def main():
    cfg = reduced(get_config(ARCH))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"backbone: {cfg.name} ({cfg.arch_type})")

    rng = np.random.default_rng(0)
    n_classes, n_per = 4, 24
    feats, labels = [], []
    for c in range(n_classes):
        lo = c * (cfg.vocab_size // n_classes)
        toks = rng.integers(lo, lo + cfg.vocab_size // n_classes,
                            (n_per, 32)).astype(np.int32)
        feats.append(pooled_features(model, params, toks))
        labels.append(np.full(n_per, c))
    x = normalize(np.concatenate(feats))
    y = np.concatenate(labels)
    perm = rng.permutation(len(y))          # stratify-ish: shuffle first
    x, y = x[perm], y[perm]

    n_test = n_classes * 6
    clf = SVC(solver="smo", C=10.0).fit(x[n_test:], y[n_test:])
    print(f"OvO tasks: {n_classes * (n_classes - 1) // 2}, "
          f"converged={clf.converged_}")
    print(f"probe train acc: {clf.score(x[n_test:], y[n_test:]):.3f}")
    print(f"probe test  acc: {clf.score(x[:n_test], y[:n_test]):.3f}")


if __name__ == "__main__":
    main()
