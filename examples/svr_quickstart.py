"""SVR quickstart: the paper's solver comparison, on regression.

Trains an epsilon-SVR on a smooth synthetic target two ways — the
parallel-SMO solver (the generalized QP core behind the paper's CUDA
path) and the projected-gradient-descent dual solver (the regression
analog of the paper's TensorFlow baseline) — and prints test R^2 +
wall time + the speedup ratio.

    PYTHONPATH=src python examples/svr_quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.svm import SVR
from repro.data import make_synth_regression, train_test_split


def main():
    x, y = make_synth_regression(600, 2, kind="sinc", noise=0.05, seed=0)
    xtr, ytr, xte, yte = train_test_split(x, y, test_frac=0.25, seed=0)

    results = {}
    for solver, label in (("smo", "parallel SMO (generalized QP core)"),
                          ("gd", "projected GD ('TF' baseline analog)")):
        reg = SVR(kernel="rbf", C=1.0, epsilon=0.1, solver=solver,
                  gd_steps=2000, gd_lr=0.01)
        reg.fit(xtr, ytr)          # warm-up: trace + compile
        t0 = time.perf_counter()
        reg.fit(xtr, ytr)          # measured: the training itself
        dt = time.perf_counter() - t0
        r2 = reg.score(xte, yte)
        mse = float(np.mean((reg.predict(xte) - yte) ** 2))
        results[solver] = dt
        print(f"{label:38s} R2={r2:.3f} mse={mse:.4f} "
              f"n_sv={reg.n_support_:4d} time={dt:.3f}s")

    print(f"\nspeedup (SMO over GD): {results['gd'] / results['smo']:.1f}x"
          f"  <- the regression analog of the paper's Table V axis")


if __name__ == "__main__":
    main()
