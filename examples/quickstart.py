"""Quickstart: the paper's core comparison in ~40 lines.

Trains a binary RBF-SVM on Iris two ways — the parallel-SMO solver (the
paper's CUDA implementation, adapted to TPU/JAX) and the
gradient-descent dual solver (the paper's TensorFlow baseline) — and
prints accuracy + wall time + the speedup ratio.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.svm import SVC
from repro.data import load_iris, normalize, train_test_split


def main():
    x, y = load_iris()
    x = normalize(x)
    sel = y != 2                       # binary: setosa vs versicolor
    xtr, ytr, xte, yte = train_test_split(x[sel], y[sel], test_frac=0.25,
                                          seed=0)

    results = {}
    for solver, label in (("smo", "parallel SMO ('MPI-CUDA' path)"),
                          ("gd", "gradient descent ('TF' baseline)")):
        clf = SVC(kernel="rbf", C=1.0, solver=solver, gd_steps=2000)
        clf.fit(xtr, ytr)          # warm-up: trace + compile
        t0 = time.perf_counter()
        clf.fit(xtr, ytr)          # measured: the training itself
        dt = time.perf_counter() - t0
        acc = clf.score(xte, yte)
        results[solver] = dt
        print(f"{label:38s} acc={acc:.3f} "
              f"iters={clf.n_iter_:5d} time={dt:.3f}s")

    print(f"\nspeedup (SMO over GD): {results['gd'] / results['smo']:.1f}x"
          f"  <- the paper's Table V axis")


if __name__ == "__main__":
    main()
