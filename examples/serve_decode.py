"""Batched serving example: prefill a batch of prompts, decode N tokens.

    PYTHONPATH=src python examples/serve_decode.py [arch] [n_tokens]

Exercises the production serving path (prefill -> KV caches -> greedy
decode_step loop) on a reduced model, reporting tokens/s. The same
`Model.prefill`/`Model.decode_step` pair is what the dry-run lowers for
the decode_32k / long_500k shapes on the pod meshes.
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.models.model import Model

ARCH = sys.argv[1] if len(sys.argv) > 1 else "zamba2_1p2b"
N_NEW = int(sys.argv[2]) if len(sys.argv) > 2 else 32


def main():
    cfg = reduced(get_config(ARCH))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    b, prompt_len, max_len = 4, 16, 16 + N_NEW

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, prompt_len)), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = 0.1 * jnp.ones(
            (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "audio":
        batch["frames"] = 0.1 * jnp.ones(
            (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)

    caches = model.cache_init(b, max_len)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch, caches)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(N_NEW - 1):
        logits, caches = decode(params, tok, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    toks = np.stack(out, 1)
    print(f"arch={cfg.name} batch={b} prompt={prompt_len} new={N_NEW}")
    print(f"prefill: {t_prefill:.3f}s ({b * prompt_len / t_prefill:.0f} "
          f"tok/s) | decode: {t_decode:.3f}s "
          f"({b * (N_NEW - 1) / max(t_decode, 1e-9):.0f} tok/s, "
          f"incl. first-step compile)")
    print("sample continuation ids:", toks[0, :10].tolist())
    assert toks.max() < cfg.vocab_size  # pad-vocab ids masked at decode


if __name__ == "__main__":
    main()
