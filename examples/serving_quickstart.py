"""Serving quickstart: fit -> pack -> save -> load -> batched Predictor.

    PYTHONPATH=src python examples/serving_quickstart.py

Walks the deployment story end to end: train a multiclass SVC, compact
it into a packed model artifact (versioned .npz — the only thing a
serving host needs), reload it, and answer request batches through the
jit-cached ``serve.Predictor``, reporting requests/s against the
training-side per-call path.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro import serve
from repro.core.svm import SVC
from repro.data import load_iris, normalize


def main():
    x, y = load_iris()
    x = normalize(x)
    clf = SVC(kernel="rbf", C=1.0, solver="smo").fit(x, y)
    print(f"trained: {len(clf.classes_)} classes, "
          f"{int(np.sum(clf.n_support_))} support vectors")

    # -- export the packed artifact (what ships to the serving fleet)
    packed = serve.pack(clf)
    path = os.path.join(tempfile.mkdtemp(), "iris-svc.npz")
    serve.save(path, packed)
    version = (serve.SCHEMA_VERSION if packed.feature_map
               else serve.SCHEMA_VERSION_CLASSIC)
    print(f"packed artifact: {path} ({os.path.getsize(path)} bytes, "
          f"schema v{version}, {packed.n_tasks} tasks in "
          f"{len(packed.buckets)} serving buckets)")

    # -- serving host: load + warm the decide programs
    pred = serve.Predictor(serve.load(path), engine="auto")
    pred.warmup(batch_sizes=(1, 32))

    batch = x[np.random.default_rng(0).integers(0, len(x), size=32)]
    t0 = time.perf_counter()
    n_calls = 50
    for _ in range(n_calls):
        labels = pred.predict(batch)
    dt = time.perf_counter() - t0
    print(f"warm predictor: {n_calls * len(batch) / dt:,.0f} requests/s "
          f"(batch=32, {pred.n_programs} compiled programs)")

    # the served labels match the training-side model exactly
    assert np.array_equal(pred.predict(x), clf.predict(x))
    acc = float(np.mean(pred.predict(x) == y))
    print(f"served accuracy: {acc:.3f} (bit-identical to training-side "
          f"predictions)")


if __name__ == "__main__":
    main()
