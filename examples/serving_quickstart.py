"""Serving quickstart: fit -> pack -> save -> load -> batched Predictor.

    PYTHONPATH=src python examples/serving_quickstart.py

Walks the deployment story end to end: train a multiclass SVC, compact
it into a packed model artifact (versioned .npz — the only thing a
serving host needs), reload it, and answer request batches through the
jit-cached ``serve.Predictor``, reporting requests/s against the
training-side per-call path. Then the under-load pieces: a quantized
fp16 pack (schema v3, decision-delta checked), and the async
``ServingService`` coalescing concurrent submitters into fused decides.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro import serve
from repro.core.svm import SVC
from repro.data import load_iris, normalize


def main():
    x, y = load_iris()
    x = normalize(x)
    clf = SVC(kernel="rbf", C=1.0, solver="smo").fit(x, y)
    print(f"trained: {len(clf.classes_)} classes, "
          f"{int(np.sum(clf.n_support_))} support vectors")

    # -- export the packed artifact (what ships to the serving fleet)
    packed = serve.pack(clf)
    path = os.path.join(tempfile.mkdtemp(), "iris-svc.npz")
    serve.save(path, packed)
    version = (serve.SCHEMA_VERSION if packed.feature_map
               else serve.SCHEMA_VERSION_CLASSIC)
    print(f"packed artifact: {path} ({os.path.getsize(path)} bytes, "
          f"schema v{version}, {packed.n_tasks} tasks in "
          f"{len(packed.buckets)} serving buckets)")

    # -- serving host: load + warm the decide programs
    pred = serve.Predictor(serve.load(path), engine="auto")
    pred.warmup(batch_sizes=(1, 32))

    batch = x[np.random.default_rng(0).integers(0, len(x), size=32)]
    t0 = time.perf_counter()
    n_calls = 50
    for _ in range(n_calls):
        labels = pred.predict(batch)
    dt = time.perf_counter() - t0
    print(f"warm predictor: {n_calls * len(batch) / dt:,.0f} requests/s "
          f"(batch=32, {pred.n_programs} compiled programs)")

    # the served labels match the training-side model exactly
    assert np.array_equal(pred.predict(x), clf.predict(x))
    acc = float(np.mean(pred.predict(x) == y))
    print(f"served accuracy: {acc:.3f} (bit-identical to training-side "
          f"predictions)")

    # -- quantized SV bank: half the artifact + resident HBM, f32 accum
    qpath = os.path.join(os.path.dirname(path), "iris-svc-fp16.npz")
    serve.save(qpath, serve.pack(clf, sv_dtype="fp16"))
    qpred = serve.Predictor(serve.load(qpath), engine="auto")
    delta = float(np.max(np.abs(qpred.decision_values(x)
                                - pred.decision_values(x))))
    assert np.array_equal(qpred.predict(x), pred.predict(x))
    print(f"fp16 pack: {os.path.getsize(qpath)} bytes (schema "
          f"v{serve.SCHEMA_VERSION_QUANT}), max decision delta "
          f"{delta:.2e}, label parity exact")

    # -- async service: concurrent submitters, one fused decide per
    #    batching window, futures scattered back per request
    with serve.ServingService(packed, window_ms=2.0) as svc:
        futs = [svc.submit(x[i:i + 1]) for i in range(64)]
        got = np.concatenate([f.result() for f in futs])
        assert np.array_equal(got, clf.predict(x[:64]))
        s = svc.stats
        print(f"service: {s['n_requests']} requests fused into "
              f"{s['n_batches']} batches "
              f"({s['rows_per_batch']:.1f} rows/batch)")


if __name__ == "__main__":
    main()
