"""Pallas kernel micro-benchmarks (interpret-mode correctness cost is
not meaningful perf; this reports the jnp-reference path wall time and
the kernels' structural roofline estimates for the TPU target)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import kernels as K
from repro.roofline.collect import HBM_BW, PEAK_FLOPS_BF16


def main():
    print("# Gram-matrix hot spot: jnp reference wall time + TPU roofline")
    rng = np.random.default_rng(0)
    for n, d in [(800, 102), (1600, 102), (4096, 128)]:
        a = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        fn = jax.jit(lambda x: K.rbf_gram(x, x, gamma=0.1))
        t = timeit(fn, a)
        flops = 2.0 * n * n * d
        bytes_ = (2 * n * d + n * n) * 4
        t_tpu = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)
        emit(f"gram_{n}x{d}_jnp_cpu", t,
             f"tpu_roofline_est={t_tpu * 1e6:.1f}us "
             f"ai={flops / bytes_:.1f}flop/B")


if __name__ == "__main__":
    main()
