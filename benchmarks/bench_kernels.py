"""Pallas kernel micro-benchmarks (interpret-mode correctness cost is
not meaningful perf; this reports the jnp-reference path wall time and
the kernels' structural roofline estimates for the TPU target).

``tile_sweep`` additionally runs the autotuner (``kernels.autotune``)
over the hot kernels and emits tuned-vs-default JSON lines — the tuned
config can never score worse than the default because the default is
always the hillclimb's first evaluation."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, emit_json, timeit
from repro.core import kernels as K
from repro.roofline.collect import HBM_BW, PEAK_FLOPS_BF16

# (kernel, shape, dtype) sweep points; quick mode keeps only the first
# per kernel and shrinks the hillclimb budget for CI smoke
SWEEP = [
    ("rbf_gram", (1024, 1024, 128), "fp32"),
    ("rbf_gram", (4096, 4096, 128), "fp32"),
    ("rbf_gram", (4096, 4096, 128), "bf16"),
    ("multitask_decision", (8, 256, 512, 128), "fp32"),
    ("multitask_decision", (8, 256, 512, 128), "bf16"),
]


def tile_sweep(quick: bool = False) -> None:
    """Tuned-vs-default tile configs as JSON lines (one per sweep
    point). Uses the deterministic roofline objective so the output is
    stable on CPU; on TPU the ``auto`` objective measures wall time."""
    from repro.kernels import autotune

    points = SWEEP
    if quick:
        seen: set[str] = set()
        points = [p for p in SWEEP
                  if p[0] not in seen and not seen.add(p[0])]
    budget = 3 if quick else 12
    objective = ("auto" if jax.default_backend() == "tpu"
                 else "roofline")
    for kernel, shape, dtype in points:
        res = autotune.tune(kernel, shape, dtype=dtype, budget=budget,
                            objective=objective)
        emit_json({
            "bench": "tile_sweep",
            "kernel": kernel,
            "shape": list(shape),
            "dtype": dtype,
            "objective": res.objective,
            "device": autotune.device_kind(),
            "default_config": res.default.config,
            "tuned_config": res.best.config,
            "default_roofline_us": res.default.roofline_s * 1e6,
            "tuned_roofline_us": res.best.roofline_s * 1e6,
            "default_wall_us": (res.default.wall_s or 0) * 1e6 or None,
            "tuned_wall_us": (res.best.wall_s or 0) * 1e6 or None,
            "n_evaluated": len(res.trace),
            "ge_default": res.best.score <= res.default.score,
        })


def main():
    print("# Gram-matrix hot spot: jnp reference wall time + TPU roofline")
    rng = np.random.default_rng(0)
    for n, d in [(800, 102), (1600, 102), (4096, 128)]:
        a = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        fn = jax.jit(lambda x: K.rbf_gram(x, x, gamma=0.1))
        t = timeit(fn, a)
        flops = 2.0 * n * n * d
        bytes_ = (2 * n * d + n * n) * 4
        t_tpu = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)
        emit(f"gram_{n}x{d}_jnp_cpu", t,
             f"tpu_roofline_est={t_tpu * 1e6:.1f}us "
             f"ai={flops / bytes_:.1f}flop/B")


if __name__ == "__main__":
    main()
