"""Single-problem strong scaling: sharded binary SMO vs shard count.

One fixed n (default 8192) RBF problem, solved by ``sharded_binary_smo``
at shard counts {1, 2, 4, 8} (clamped to the visible device count), one
JSON line per point via ``benchmarks.common.emit_json``:

    {"bench": "sharded", "n": 8192, "shards": 4, "wall_s": ...,
     "n_iter": ..., "converged": ..., "n_sv": ...,
     "peak_state_bytes_per_shard": ..., "xfull_bytes_per_shard": ...,
     "gram_bytes_dense": ...}

``peak_state_bytes_per_shard`` is the per-device resident kernel state
(two working rows + the LRU cache + the f/alpha/mask shards, all
O(n/shards)) — the strong-scaling memory axis; ``xfull_bytes_per_shard``
is the replicated all-gathered sample matrix (O(n d), paid once per
device, the price of collective-free kernel rows). ``gram_bytes_dense``
(n^2 * 4) is what the paper's dense single-device layout would need.

Run standalone (forces a multi-device host CPU BEFORE jax initializes):

    PYTHONPATH=src python -m benchmarks.bench_sharded [--quick]

or via the runner on an already-multi-device process (CI sets XLA_FLAGS):

    PYTHONPATH=src python -m benchmarks.run --only sharded [--quick]
"""
from __future__ import annotations

import argparse
import os
import time

N = 8192
N_QUICK = 2048
SHARDS = (1, 2, 4, 8)
CACHE_SLOTS = 16
CHUNK = 1024
D = 8


def bench_one(n: int, n_shards: int) -> dict:
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import kernel_engine as KE
    from repro.core import kernels as K, smo
    from repro.data import make_blobs, normalize
    from repro.launch.mesh import make_shard_mesh

    x, y = make_blobs(n // 2, 2, D, sep=4.0, seed=7)
    yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
    x = normalize(x)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    cfg = smo.SMOConfig(max_iter=60_000)
    ecfg = KE.EngineConfig(cache_slots=CACHE_SLOTS, chunk=CHUNK)
    mesh = make_shard_mesh(n_shards)

    def fit():
        return smo.sharded_binary_smo(x, yy, mesh=mesh, cfg=cfg,
                                      kernel=kp, engine=ecfg)

    r = fit()                      # warmup includes compile
    jax.block_until_ready(r.alpha)
    t0 = time.perf_counter()
    r = fit()
    jax.block_until_ready(r.alpha)
    wall = time.perf_counter() - t0
    n_local = -(-n // n_shards)
    return {
        "bench": "sharded",
        "n": n,
        "shards": n_shards,
        "wall_s": round(wall, 3),
        "n_iter": int(r.n_iter),
        "converged": bool(r.converged),
        "gap": float(r.gap),
        "n_sv": int((np.asarray(r.alpha) > 1e-8).sum()),
        # f/alpha/active shards + two working rows + LRU slots, per device
        "peak_state_bytes_per_shard": 4 * n_local * (3 + 2 + CACHE_SLOTS),
        "xfull_bytes_per_shard": 4 * n * D,
        "gram_bytes_dense": 4 * n * n,
    }


def main(quick: bool = False) -> None:
    import jax

    from benchmarks.common import emit_json

    n = N_QUICK if quick else N
    n_dev = jax.device_count()
    shards = [s for s in SHARDS if s <= n_dev]
    if quick:
        shards = shards[:3]
    for s in shards:
        emit_json(bench_one(n, s))


if __name__ == "__main__":
    # must land before the first jax import in THIS process
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={max(SHARDS)}"
        ).strip()
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller n, fewer shard counts")
    args = ap.parse_args()
    main(quick=args.quick)
