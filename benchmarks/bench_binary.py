"""Paper Tables III & V: binary SVM training time, parallel-SMO
("CUDA-GPU") vs gradient-descent ("Tensorflow-GPU"), across sample sizes.

Reproduces the paper's protocol: N training samples PER CLASS, RBF
kernel; reports wall time for both solvers and the speedup ratio. The
paper's claim being validated: the explicit solver wins by a widening
margin as the sample count grows.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import gd, kernels as K, smo
from repro.data import (load_breast_cancer_like, load_iris,
                        load_pavia_like, normalize)
from repro.data.pipeline import subsample_per_class

GD_STEPS = 2000   # the TF-recipe fixed session loop


def _binary_subset(x, y, n_per_class, classes=(0, 1), seed=0):
    sel = np.isin(y, classes)
    xs, ys = subsample_per_class(x[sel], y[sel], n_per_class, seed=seed)
    yy = np.where(ys == classes[0], 1.0, -1.0).astype(np.float32)
    return xs, yy


def bench_pair(x, yy, label):
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    xj, yj = jnp.asarray(x), jnp.asarray(yy)

    smo_fn = jax.jit(lambda a, b: smo.binary_smo(
        a, b, cfg=smo.SMOConfig(), kernel=kp).alpha)
    gd_fn = jax.jit(lambda a, b: gd.binary_gd(
        a, b, cfg=gd.GDConfig(lr=0.01, steps=GD_STEPS), kernel=kp).alpha)

    t_smo = timeit(smo_fn, xj, yj)
    t_gd = timeit(gd_fn, xj, yj)
    emit(f"{label}_smo", t_smo, f"speedup={t_gd / t_smo:.1f}x")
    emit(f"{label}_gd", t_gd, f"gd_steps={GD_STEPS}")
    return t_smo, t_gd


def main():
    print("# Table III: Pavia-like binary, N samples/class "
          "(smo='CUDA', gd='Tensorflow')")
    x, y = load_pavia_like(n_per_class=800)
    x = normalize(x)
    for n in (200, 400, 600, 800):
        xs, yy = _binary_subset(x, y, n)
        bench_pair(xs, yy, f"pavia_binary_{n}")

    print("# beyond-paper: WSS2 second-order selection vs the paper's "
          "first-order (iteration counts)")
    xs, yy = _binary_subset(x, y, 800)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(xs))
    for mode in ("first", "second"):
        fn = jax.jit(lambda a, b: smo.binary_smo(
            a, b, cfg=smo.SMOConfig(selection=mode), kernel=kp))
        r = fn(jnp.asarray(xs), jnp.asarray(yy))
        t = timeit(lambda: fn(jnp.asarray(xs), jnp.asarray(yy)).alpha)
        emit(f"pavia_binary_800_wss_{mode}", t,
             f"n_iter={int(r.n_iter)}")

    print("# Table V: Iris (40/4/2) and Breast-Cancer-like (190/32/2)")
    xi, yi = load_iris()
    xi = normalize(xi)
    xs, yy = _binary_subset(xi, yi, 20)      # 40 points total
    bench_pair(xs, yy, "iris_binary_40")

    xc, yc = load_breast_cancer_like()
    xc = normalize(xc)
    xs, yy = _binary_subset(xc, yc, 95)      # 190 points total
    bench_pair(xs, yy, "cancer_binary_190")


if __name__ == "__main__":
    main()
