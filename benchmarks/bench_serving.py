"""Serving throughput: the batched Predictor vs the per-call engine path.

The pre-PR serving story re-uploaded support vectors, rebuilt a
``KernelEngine`` and looped serving buckets in Python on EVERY
``predict`` call. ``serve.Predictor`` keeps the packed SV bank resident
on device and answers from a warm jit cache of fused decide programs.
This benchmark measures both on the same warm 5-class RBF model at
request batch sizes {1, 32, 256} and emits JSON lines:

    {"bench": "serving", "batch": B, "engine": ...,
     "old_rps": ..., "new_rps": ..., "speedup": ...}

``requests/s`` counts individual rows (a batch of 256 that takes 1 ms
is 256k requests/s). Run via ``python -m benchmarks.run --only
serving`` (CI runs the --quick variant as a smoke check).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core.svm import SVC
from repro.data.synth import make_blobs

BATCHES = (1, 32, 256)


def _legacy_predict(clf: SVC, xt: np.ndarray) -> np.ndarray:
    """The pre-predictor serving path: per-call engine rebuild + Python
    bucket loop + decision aggregation."""
    df = clf._decision_function_engine(xt)
    idx = clf.strategy.decide(jnp.asarray(df), clf._taskset, clf.decision)
    return clf.classes_[np.asarray(idx)]


def main(quick: bool = False, engine: str = "chunked") -> None:
    n_per_class = 40 if quick else 120
    x, y = make_blobs(n_per_class, 5, 16, sep=2.5, seed=0)
    clf = SVC(solver="smo", gamma=0.5, engine=engine).fit(x, y)
    pred = clf.predictor().warmup(batch_sizes=BATCHES)

    rng = np.random.default_rng(1)
    iters = 3 if quick else 5
    for batch in BATCHES:
        xt = x[rng.integers(0, len(x), size=batch)]
        t_old = common.timeit(lambda: _legacy_predict(clf, xt),
                              warmup=1, iters=iters)
        t_new = common.timeit(lambda: pred.predict(xt),
                              warmup=1, iters=iters)
        record = {
            "bench": "serving",
            "engine": engine,
            "batch": int(batch),
            "n_train": int(len(x)),
            "n_tasks": int(pred.model.n_tasks),
            "n_support": int(pred.model.n_support),
            "old_s_per_call": t_old,
            "new_s_per_call": t_new,
            "old_rps": batch / t_old,
            "new_rps": batch / t_new,
            "speedup": t_old / t_new,
        }
        # predictor-owned program ledger (no more private jit API)
        record["n_programs"] = int(pred.n_programs)
        common.emit_json(record)


if __name__ == "__main__":
    main()
