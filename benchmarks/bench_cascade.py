"""Cascade SVM: wall clock / accuracy / certificate vs shard count.

One fixed RBF binary problem solved by the hierarchical cascade
(``SVC(shard="cascade")``) at shard counts {1, 2, 4, 8}, against the
unsharded exact SMO baseline — one JSON line per point via
``benchmarks.common.emit_json``:

    {"bench": "cascade", "n": 4096, "shards": 4, "wall_s": ...,
     "rounds": ..., "kkt": ..., "converged": ..., "n_sv": ...,
     "acc": ..., "n_iter": ...}

(the baseline line carries ``"shards": 0``). ``kkt`` is the float64
full-dataset certificate the cascade terminates on — the point of the
sweep is that it stays <= tol at every shard count while the leaf
solves shrink to n/S. ``--quick`` is the CI parity smoke: small n, and
every cascade point must CERTIFY (converged) and land within
``QUICK_GATE`` of the unsharded accuracy.

Run standalone:

    PYTHONPATH=src python -m benchmarks.bench_cascade [--quick]

or via the runner:

    PYTHONPATH=src python -m benchmarks.run --only cascade [--quick]
"""
from __future__ import annotations

import argparse
import time

N = 4096
N_QUICK = 512
N_TEST = 512
SHARDS = (1, 2, 4, 8)
SHARDS_QUICK = (1, 2, 4)
ROUNDS = 8
QUICK_GATE = 0.02      # CI smoke: |acc_cascade - acc_exact| gate
D = 8


def _problem(n: int, seed: int = 7):
    from repro.data import make_blobs, normalize
    x, y = make_blobs((n + N_TEST) // 2, 2, D, sep=4.0, seed=seed)
    x = normalize(x)   # make_blobs shuffles, so a tail split is iid
    return (x[:n], y[:n]), (x[n:n + N_TEST], y[n:n + N_TEST])


def _timed_fit(clf, x, y) -> float:
    t0 = time.perf_counter()
    clf.fit(x, y)
    return time.perf_counter() - t0


def main(quick: bool = False) -> None:
    from benchmarks.common import emit_json
    from repro.core.svm import SVC

    n = N_QUICK if quick else N
    shard_counts = SHARDS_QUICK if quick else SHARDS
    (xtr, ytr), (xte, yte) = _problem(n)

    exact = SVC(kernel="rbf")
    wall = _timed_fit(exact, xtr, ytr)
    acc_exact = exact.score(xte, yte)
    emit_json({
        "bench": "cascade", "n": n, "shards": 0, "wall_s": round(wall, 3),
        "rounds": 0, "kkt": None, "converged": bool(exact.converged_),
        "n_iter": int(exact.n_iter_), "n_sv": int(exact.n_support_),
        "acc": round(acc_exact, 4),
    })

    accs = {}
    for s in shard_counts:
        clf = SVC(kernel="rbf", shard="cascade", cascade_shards=s,
                  cascade_rounds=ROUNDS)
        wall = _timed_fit(clf, xtr, ytr)
        acc = clf.score(xte, yte)
        accs[s] = (acc, bool(clf.converged_))
        emit_json({
            "bench": "cascade", "n": n, "shards": s,
            "wall_s": round(wall, 3),
            "rounds": int(clf.cascade_rounds_),
            "kkt": float(clf.cascade_kkt_),
            "converged": bool(clf.converged_),
            "n_iter": int(clf.n_iter_),
            "n_sv": int(clf.n_support_),
            "acc": round(acc, 4),
        })

    if quick:
        # CI parity gate: every shard count must certify the global KKT
        # conditions AND match the unsharded accuracy
        for s, (acc, converged) in accs.items():
            assert converged, f"cascade parity gate: S={s} did not certify"
            assert acc >= acc_exact - QUICK_GATE, (
                f"cascade parity gate: S={s} accuracy {acc:.4f} vs exact "
                f"{acc_exact:.4f} (gate {QUICK_GATE})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
