"""epsilon-SVR benchmark: SMO vs projected-GD on the insensitive dual.

The regression analog of the paper's central comparison (Tables III/V):
the explicit working-set solver against the fixed-step autodiff baseline
on the SAME dual QP — here the doubled-variable epsilon-SVR instance of
the generalized ``smo.solve_qp`` core. Emits one JSON line per
(n, solver) cell: wall seconds, training MSE, iterations, and the
SMO-over-GD speedup, via ``common.emit_json``.

    PYTHONPATH=src python -m benchmarks.run --only svr [--quick]
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit_json, timeit
from repro.core import gd, kernels as K, smo
from repro.data import make_synth_regression

GD_STEPS = 1000
EPSILON = 0.1
SIZES = (256, 512, 1024)


def _mse(x, y, beta, b, kp):
    pred = smo.decision_function(jnp.asarray(x),
                                 jnp.ones(x.shape[0], jnp.float32),
                                 beta, b, jnp.asarray(x), kernel=kp)
    return float(np.mean((np.asarray(pred) - y) ** 2))


def bench_one(n: int) -> None:
    x, y = make_synth_regression(n, 8, noise=0.05, seed=0)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    smo_fn = jax.jit(lambda a, b: smo.svr_smo(
        a, b, epsilon=EPSILON, cfg=smo.SMOConfig(), kernel=kp))
    gd_fn = jax.jit(lambda a, b: gd.svr_gd(
        a, b, epsilon=EPSILON, cfg=gd.GDConfig(lr=0.01, steps=GD_STEPS),
        kernel=kp))

    t_smo = timeit(smo_fn, xj, yj)
    t_gd = timeit(gd_fn, xj, yj)
    r_smo = smo_fn(xj, yj)
    r_gd = gd_fn(xj, yj)

    emit_json({"bench": "svr", "n": n, "solver": "smo",
               "seconds": t_smo, "epsilon": EPSILON,
               "n_iter": int(r_smo.n_iter),
               "mse": _mse(x, y, r_smo.beta, r_smo.b, kp),
               "speedup_vs_gd": t_gd / t_smo})
    emit_json({"bench": "svr", "n": n, "solver": "gd",
               "seconds": t_gd, "epsilon": EPSILON,
               "n_iter": GD_STEPS,
               "mse": _mse(x, y, r_gd.beta, r_gd.b, kp)})


def main(quick: bool = False) -> None:
    print("# beyond-paper: epsilon-SVR, SMO vs projected-GD "
          "(JSON lines)")
    for n in (SIZES[:1] if quick else SIZES):
        bench_one(n)


if __name__ == "__main__":
    main()
