"""Shared benchmark utilities: timing, CSV/JSON emission."""
from __future__ import annotations

import json
import time
from typing import Callable

import jax


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds, post-warmup, blocking on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def emit_json(record: dict) -> None:
    """One JSON object per line (machine-consumable trajectory points —
    future PRs diff these across commits)."""
    print(json.dumps(record, sort_keys=True), flush=True)
