"""Shared benchmark utilities: timing, CSV/JSON emission.

Every record that flows through ``emit``/``emit_json`` is also appended
to the active sink (``set_sink``), which is how ``benchmarks.run``
collects each suite's results into a stable repo-root
``BENCH_<suite>.json`` document — one file per suite, sorted keys, so
successive commits diff cleanly.
"""
from __future__ import annotations

import json
import time
from typing import Callable, Optional

import jax

# active record sink (a plain list) — see module docstring / run.py
_SINK: Optional[list] = None


def set_sink(records: Optional[list]) -> None:
    """Route every emitted record into ``records`` (None disables)."""
    global _SINK
    _SINK = records


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds, post-warmup, blocking on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    if _SINK is not None:
        _SINK.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                      "derived": derived})


def emit_json(record: dict) -> None:
    """One JSON object per line (machine-consumable trajectory points —
    future PRs diff these across commits)."""
    print(json.dumps(record, sort_keys=True), flush=True)
    if _SINK is not None:
        _SINK.append(record)
