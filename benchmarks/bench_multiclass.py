"""Paper Table IV: multiclass (9-way, one-vs-one) training time.

  MPI-CUDA          -> vmapped/sharded parallel SMO over all 36 tasks
  Multi-Tensorflow  -> sequential GD, one "session" per task

Also reports the distributed (shard_map, forced multi-device) variant in
a subprocess — the actual MPI analogue — and its scaling vs worker count,
plus ``bucketed()``: padded vs size-bucketed scheduler wall time and
padded-FLOP fraction on an imbalanced dataset (JSON lines via
``common.emit_json``).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, emit_json, timeit
from repro.core import dist, kernels as K, multiclass as MC, ovo
from repro.data import load_pavia_like, make_imbalanced_blobs, normalize
from repro.data.pipeline import subsample_per_class

GD_STEPS = 2000


def main():
    print("# Table IV: Pavia-like 9-class OvO, N samples/class")
    x_all, y_all = load_pavia_like(n_per_class=800)
    x_all = normalize(x_all)

    for n in (200, 400, 600, 800):
        xs, ys = subsample_per_class(x_all, y_all, n, seed=0)
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(xs))
        tasks = ovo.build_tasks(xs, ys)

        t_par = timeit(
            lambda: dist.vmapped_ovo_fit(tasks, solver="smo",
                                         kernel=kp).alpha,
            warmup=1, iters=1)
        t_seq = timeit(
            lambda: dist.sequential_ovo_fit(
                tasks, solver="gd",
                gd_cfg=__import__("repro.core.gd",
                                  fromlist=["GDConfig"]).GDConfig(
                    lr=0.01, steps=GD_STEPS),
                kernel=kp).alpha,
            warmup=0, iters=1)
        emit(f"pavia_multi_{n}_parallel_smo", t_par,
             f"speedup={t_seq / t_par:.1f}x")
        emit(f"pavia_multi_{n}_sequential_gd", t_seq,
             f"tasks={ovo.n_binary_tasks(9)}")


_SCALING = textwrap.dedent("""
    import os, time, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    sys.path.insert(0, "src"); sys.path.insert(0, ".")
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import ovo, dist, kernels as K
    from repro.data import load_pavia_like, normalize
    x, y = load_pavia_like(n_per_class=100)
    x = normalize(x)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    mesh = jax.make_mesh((%d,), ("workers",))
    tasks = ovo.build_tasks(x, y, pad_tasks_to=%d)
    f = lambda: jax.block_until_ready(dist.distributed_ovo_fit(
        tasks, mesh, ("workers",), solver="smo", kernel=kp).alpha)
    f()
    t0 = time.perf_counter(); f(); print(time.perf_counter() - t0)
""")


def scaling(workers=(1, 2, 4)):
    """Worker-scaling of the shard_map MPI layer (subprocesses: device
    count locks at jax init). Note: forced host 'devices' share the same
    CPU, so wall time does NOT drop — the check is that the distribution
    overhead stays ~0 (the paper's 'communication only at the ends')."""
    print("# MPI-layer scaling (36 tasks over P workers, shard_map)")
    base = None
    for w in workers:
        r = subprocess.run(
            [sys.executable, "-c", _SCALING % (w, w, w)],
            capture_output=True, text=True, timeout=900)
        t = float(r.stdout.strip().splitlines()[-1])
        base = base or t
        emit(f"dist_ovo_workers_{w}", t, f"rel={t / base:.2f}")


def bucketed(quick: bool = False):
    """Padded vs size-bucketed scheduler on an IMBALANCED multiclass
    problem — the tentpole number of the strategy layer. Emits one JSON
    line per configuration: wall seconds + padded-FLOP fraction."""
    print("# bucketed vs padded scheduler, imbalanced 6-class OvO")
    class_sizes = (150, 120, 60, 30, 20, 12) if quick else \
                  (600, 400, 200, 100, 50, 25)
    x, y = make_imbalanced_blobs(class_sizes, 32, sep=3.0, seed=11)
    x = normalize(x)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    taskset = MC.get_strategy("ovo").build_taskset(x, y)

    for name, cfg in (("padded", MC.ScheduleConfig(bucket_by="none")),
                      ("bucketed", MC.ScheduleConfig(bucket_by="pow2"))):
        sched = MC.build_schedule(taskset.sizes, cfg)
        stats = MC.schedule_stats(taskset.sizes, sched)
        secs = timeit(
            lambda: dist.fit_taskset(taskset, sched, solver="smo",
                                     kernel=kp).alpha,
            warmup=1)  # 3-iteration median — single-shot timing is noisy
                       # enough to invert the padded/bucketed comparison
        emit_json({
            "bench": "multiclass_scheduler",
            "schedule": name,
            "class_sizes": list(class_sizes),
            "n_tasks": stats["n_tasks"],
            "n_buckets": stats["n_buckets"],
            "bucket_widths": stats["bucket_widths"],
            "padded_flop_fraction": round(stats["padded_flop_fraction"],
                                          4),
            "wall_seconds": round(secs, 4),
        })


if __name__ == "__main__":
    main()
    scaling()
    bucketed()
