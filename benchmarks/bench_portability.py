"""Paper Table VI analogue: cross-platform portability.

The paper's point: the SAME TensorFlow program runs unchanged on CPU and
GPU (vs CUDA being GPU-only). The JAX analogue measured here: the SAME
jitted program runs compiled (jit = the 'session executor') vs in
op-by-op eager dispatch (disable_jit), unchanged — and (on this host)
the same source would run on CPU/GPU/TPU backends unchanged, which is
the portability property the table demonstrates.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import gd, kernels as K
from repro.data import load_breast_cancer_like, load_iris, normalize
from repro.data.pipeline import subsample_per_class

GD_STEPS = 500


def bench(x, yy, label):
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    xj, yj = jnp.asarray(x), jnp.asarray(yy)
    cfg = gd.GDConfig(lr=0.01, steps=GD_STEPS)
    fn = jax.jit(lambda a, b: gd.binary_gd(a, b, cfg=cfg, kernel=kp).alpha)
    t_jit = timeit(fn, xj, yj)
    with jax.disable_jit():
        t_eager = timeit(
            lambda a, b: gd.binary_gd(a, b, cfg=gd.GDConfig(
                lr=0.01, steps=20), kernel=kp).alpha, xj, yj,
            warmup=0, iters=1) * (GD_STEPS / 20)
    emit(f"{label}_jit", t_jit, f"backend={jax.default_backend()}")
    emit(f"{label}_eager_est", t_eager,
         f"jit_speedup={t_eager / t_jit:.1f}x")


def main():
    print("# Table VI analogue: same program, compiled vs eager "
          "(portability: same source runs on cpu/gpu/tpu backends)")
    x, y = load_iris()
    x = normalize(x)
    sel = y != 2
    xs, ys = subsample_per_class(x[sel], y[sel], 20, seed=0)
    bench(xs, np.where(ys == 0, 1.0, -1.0).astype(np.float32),
          "iris_gd_40")

    xc, yc = load_breast_cancer_like()
    xc = normalize(xc)
    xs, ys = subsample_per_class(xc, yc, 95, seed=0)
    bench(xs, np.where(ys == 0, 1.0, -1.0).astype(np.float32),
          "cancer_gd_190")


if __name__ == "__main__":
    main()
