"""Open-loop serving benchmark: Poisson arrivals vs the service layer.

The closed-loop sweep (``bench_serving``) measures how fast one caller
can hammer the predictor; real traffic is OPEN-LOOP — requests arrive
on their own Poisson clock whether or not the server has finished the
previous one, and the metrics that matter are tail latency and the
throughput the server can SUSTAIN before its queue diverges.

This benchmark replays the same pre-drawn arrival schedule (Poisson
inter-arrivals at several offered rates, batch-1 head-to-head plus a
mixed-size workload) against two dispatch modes on the same warm
packed model:

* ``per_request`` — a single worker serves the queue one request at a
  time (the pre-service story: nothing coalesces);
* ``dynamic``     — ``serve.ServingService`` with its batching window
  (collect <= window_ms or until the bucket fills, one fused decide).

Per (mode, rate) it emits p50/p99 request latency and sustained
requests/s (rows completed / span). At rates beyond the per-request
capacity the baseline queue grows without bound — its p99 explodes and
its sustained rps caps out — while the batcher widens its fused batches
instead. The committed ``BENCH_serving_load.json`` shows the >= 2x
sustained-throughput acceptance gate at batch-1 arrivals; ``--quick``
is the CI smoke, which ASSERTS dynamic >= QUICK_SPEEDUP_GATE x
per-request sustained rps at the top offered rate and that the
fp16/bf16 quantized banks stay within QUANT_GATE of fp32 decisions.

Run via ``python -m benchmarks.run --only serving_load``.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

from benchmarks import common
from repro import serve
from repro.core.svm import SVC
from repro.data.synth import make_blobs

WINDOW_MS = 2.0
QUICK_SPEEDUP_GATE = 1.3   # CI smoke floor; the committed full run >= 2x
QUANT_GATE = 3e-2          # max |fp16/bf16 - fp32| decision delta
# offered rates as multiples of the measured per-request capacity: one
# comfortably under, one at the knee, one past saturation
RATE_FACTORS = (0.5, 1.5, 4.0)
# REPRO_COMPILE_GUARD=1 (CI sets it on the smoke) wraps every measured
# replay in a zero-budget CompileGuard: the full pow2 ladder is warmed
# before the clock starts, so ANY fresh XLA compile mid-replay is a
# shape-keyed cache leak poisoning the tail latencies it reports
COMPILE_GUARD = os.environ.get("REPRO_COMPILE_GUARD") == "1"


class _PerRequestServer:
    """The no-batching baseline: one worker thread, one predictor call
    per request, FIFO — same open-loop interface as the service."""

    def __init__(self, pred: serve.Predictor):
        self._pred = pred
        self._q: queue.Queue = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def submit(self, x: np.ndarray) -> Future:
        fut: Future = Future()
        self._q.put((x, fut))
        return fut

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            x, fut = item
            try:
                fut.set_result(self._pred.predict(x))
            except Exception as e:            # noqa: BLE001
                fut.set_exception(e)

    def close(self) -> None:
        self._q.put(None)
        self._worker.join()


def _draw_schedule(rng, rate: float, duration: float, sizes, probs,
                   max_requests: int):
    """(arrival_s, batch_rows) pairs: Poisson arrivals, iid sizes."""
    gaps = rng.exponential(1.0 / rate, size=max_requests)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration]
    ns = rng.choice(sizes, size=len(arrivals), p=probs)
    return list(zip(arrivals.tolist(), ns.tolist()))


def _replay(submit, schedule, pool: np.ndarray) -> dict:
    """Open-loop replay: submit at the scheduled instants (never wait
    for completions), then measure per-request latency = completion -
    scheduled arrival."""
    recs = []
    t0 = time.perf_counter()
    for arrival, n in schedule:
        now = time.perf_counter() - t0
        if arrival > now:
            time.sleep(arrival - now)
        rec = {"sched": arrival, "rows": n}

        def _done(fut, rec=rec):
            rec["done"] = time.perf_counter() - t0

        start = np.random.randint(0, len(pool) - n + 1)
        fut = submit(pool[start:start + n])
        fut.add_done_callback(_done)
        rec["future"] = fut
        recs.append(rec)
    for rec in recs:
        rec["future"].result(timeout=600)
    lat = np.array([r["done"] - r["sched"] for r in recs])
    span = max(r["done"] for r in recs) - recs[0]["sched"]
    rows = sum(r["rows"] for r in recs)
    return {
        "n_requests": len(recs),
        "n_rows": int(rows),
        "span_s": round(span, 4),
        "sustained_rps": round(rows / span, 1),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
    }


def _guarded_replay(submit, schedule, pool, note: str) -> dict:
    if not COMPILE_GUARD:
        return _replay(submit, schedule, pool)
    from repro.analysis.compile_guard import CompileGuard
    with CompileGuard(budget=0, note=note):
        return _replay(submit, schedule, pool)


def _run_mode(mode: str, packed, pool, schedule) -> dict:
    # warm the ENTIRE pow2 batch-bucket ladder first: a bucket first
    # seen mid-replay would pay its jit compile inside the measured
    # window, stalling the queue and poisoning the tail latencies
    if mode == "dynamic":
        svc = serve.ServingService(packed, engine="chunked",
                                   window_ms=WINDOW_MS)
        pred = svc.registry.get("default")
        pred.warmup(tuple(1 << k for k in
                          range(pred.max_batch.bit_length())))
        try:
            out = _guarded_replay(svc.submit, schedule, pool,
                                  "serving_load dynamic replay")
            out["rows_per_batch"] = round(svc.stats["rows_per_batch"], 2)
        finally:
            svc.close()
        return out
    pred = serve.Predictor(packed, engine="chunked")
    pred.warmup(tuple(1 << k for k in range(pred.max_batch.bit_length())))
    srv = _PerRequestServer(pred)
    try:
        return _guarded_replay(srv.submit, schedule, pool,
                               "serving_load per_request replay")
    finally:
        srv.close()


def _quantization_gate(clf, pool, quick: bool) -> None:
    full = serve.Predictor(serve.pack(clf), engine="chunked")
    df_full = full.decision_values(pool)
    labels_full = full.predict(pool)
    for sv_dtype in ("fp16", "bf16"):
        quant = serve.Predictor(serve.pack(clf, sv_dtype=sv_dtype),
                                engine="chunked")
        delta = float(np.max(np.abs(quant.decision_values(pool)
                                    - df_full)))
        parity = bool(np.array_equal(quant.predict(pool), labels_full))
        common.emit_json({
            "bench": "serving_load", "section": "quantization",
            "sv_dtype": sv_dtype, "max_decision_delta": round(delta, 5),
            "label_parity": parity, "gate": QUANT_GATE,
            "within_gate": delta <= QUANT_GATE,
        })
        assert delta <= QUANT_GATE, (
            f"{sv_dtype} SV bank moved decisions by {delta:.4f} "
            f"(> gate {QUANT_GATE})")
        assert parity, f"{sv_dtype} SV bank flipped predicted labels"


def main(quick: bool = False) -> None:
    n_per_class = 40 if quick else 120
    x, y = make_blobs(n_per_class, 5, 16, sep=2.5, seed=0)
    clf = SVC(solver="smo", gamma=0.5, engine="chunked").fit(x, y)
    packed = serve.pack(clf)
    pool = np.asarray(x, np.float32)
    rng = np.random.default_rng(1)

    # calibrate the per-request batch-1 capacity on a warm predictor —
    # offered rates are set relative to it so the saturation story is
    # machine-independent
    pred = serve.Predictor(packed, engine="chunked").warmup((1,))
    t1 = common.timeit(lambda: pred.predict(pool[:1]), warmup=2,
                       iters=5)
    capacity = 1.0 / t1
    duration = 1.2 if quick else 3.0
    max_requests = 2000 if quick else 6000
    common.emit_json({
        "bench": "serving_load", "section": "calibration",
        "per_request_s": round(t1, 6),
        "per_request_capacity_rps": round(capacity, 1),
        "window_ms": WINDOW_MS, "duration_s": duration,
    })

    # head-to-head at batch-1 arrivals (the acceptance gate axis)
    sustained = {"dynamic": {}, "per_request": {}}
    for factor in RATE_FACTORS:
        rate = capacity * factor
        schedule = _draw_schedule(rng, rate, duration, [1], [1.0],
                                  max_requests)
        for mode in ("per_request", "dynamic"):
            out = _run_mode(mode, packed, pool, schedule)
            out.update({"bench": "serving_load", "section": "batch1",
                        "mode": mode, "rate_factor": factor,
                        "offered_rps": round(rate, 1)})
            sustained[mode][factor] = out["sustained_rps"]
            common.emit_json(out)

    top = RATE_FACTORS[-1]
    speedup = sustained["dynamic"][top] / sustained["per_request"][top]
    common.emit_json({
        "bench": "serving_load", "section": "summary",
        "rate_factor": top,
        "dynamic_sustained_rps": sustained["dynamic"][top],
        "per_request_sustained_rps": sustained["per_request"][top],
        "speedup": round(speedup, 2),
    })
    assert speedup >= QUICK_SPEEDUP_GATE, (
        f"dynamic batching sustained only {speedup:.2f}x the "
        f"per-request dispatch at {top}x capacity "
        f"(gate {QUICK_SPEEDUP_GATE}x)")

    # mixed batch sizes through the dynamic path (open-loop realism:
    # mostly single rows, some bulk scoring)
    rate = capacity * 2.0
    schedule = _draw_schedule(rng, rate, duration, [1, 8, 32],
                              [0.7, 0.2, 0.1], max_requests)
    out = _run_mode("dynamic", packed, pool, schedule)
    out.update({"bench": "serving_load", "section": "mixed",
                "mode": "dynamic", "offered_rps": round(rate, 1),
                "batch_mix": {"1": 0.7, "8": 0.2, "32": 0.1}})
    common.emit_json(out)

    _quantization_gate(clf, pool, quick)


if __name__ == "__main__":
    main()
