"""Benchmark runner — one section per paper table.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Emits ``name,us_per_call,derived`` CSV lines per benchmark:
  Table III & V -> bench_binary      (binary SMO vs GD training time)
  Table IV      -> bench_multiclass  (9-class OvO parallel vs sequential,
                                      + bucketed-vs-padded scheduler JSON)
  Table VI      -> bench_portability (same program jit vs eager)
  kernels       -> bench_kernels     (hot-spot roofline estimates)
  beyond-paper  -> bench_large_n     (chunked-engine large-n trajectory,
                                      JSON lines; --only large_n)
  beyond-paper  -> --only scheduler  (bucketed-vs-padded multiclass
                                      scheduler JSON alone; CI smoke)
  beyond-paper  -> bench_sharded     (single-problem strong scaling vs
                                      shard count, JSON lines; --only
                                      sharded — needs a multi-device
                                      process, e.g. XLA_FLAGS=
                                      --xla_force_host_platform_device_count=8)
  beyond-paper  -> bench_svr         (epsilon-SVR SMO vs projected-GD
                                      wall time + MSE, JSON lines;
                                      --only svr)
  beyond-paper  -> bench_serving     (batched Predictor vs per-call
                                      engine serving, requests/s at
                                      batch {1, 32, 256}, JSON lines;
                                      --only serving)
  beyond-paper  -> tile_sweep        (autotuner tuned-vs-default tile
                                      configs for the Pallas kernels,
                                      JSON lines; part of the kernels
                                      section, or --only tile_sweep for
                                      the sweep alone; CI smoke uses
                                      --quick --only tile_sweep)
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="drop the largest sample sizes")
    ap.add_argument("--only", default="",
                    help="comma list: binary,multiclass,portability,"
                         "kernels; opt-in extras: large_n,scheduler,"
                         "sharded,svr,serving,tile_sweep")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")

    from benchmarks import (bench_binary, bench_kernels, bench_large_n,
                            bench_multiclass, bench_portability)
    if args.quick:
        bench_binary.GD_STEPS = 300
        bench_multiclass.GD_STEPS = 300

    if only is None or "binary" in only:
        bench_binary.main()
    if only is None or "multiclass" in only:
        bench_multiclass.main()
        bench_multiclass.bucketed(quick=args.quick)
        if not args.quick:
            bench_multiclass.scaling()
    if only is not None and "scheduler" in only:
        # the bucketed-vs-padded JSON comparison alone (CI smoke)
        bench_multiclass.bucketed(quick=args.quick)
    if only is None or "portability" in only:
        bench_portability.main()
    if only is None or "kernels" in only:
        bench_kernels.main()
        bench_kernels.tile_sweep(quick=args.quick)
    if only is not None and "tile_sweep" in only:
        # the autotuner tuned-vs-default JSON alone (CI smoke)
        bench_kernels.tile_sweep(quick=args.quick)
    if only is not None and "large_n" in only:
        # opt-in: minutes-long at full size (JSON lines, not CSV)
        bench_large_n.main(quick=args.quick)
    if only is not None and "sharded" in only:
        # opt-in: single-problem strong scaling over forced host devices
        from benchmarks import bench_sharded
        bench_sharded.main(quick=args.quick)
    if only is not None and "svr" in only:
        # opt-in: the regression analog of the SMO-vs-GD comparison
        from benchmarks import bench_svr
        bench_svr.main(quick=args.quick)
    if only is not None and "serving" in only:
        # opt-in: batched Predictor vs the per-call engine serving path
        from benchmarks import bench_serving
        bench_serving.main(quick=args.quick)


if __name__ == "__main__":
    main()
