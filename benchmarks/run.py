"""Benchmark runner — one section per paper table.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Emits ``name,us_per_call,derived`` CSV lines per benchmark, and writes
every executed suite's records to a repo-root ``BENCH_<suite>.json``
(stable sorted-keys schema collected through ``common.set_sink`` — the
machine-consumable trajectory successive commits diff):
  Table III & V -> bench_binary      (binary SMO vs GD training time)
  Table IV      -> bench_multiclass  (9-class OvO parallel vs sequential,
                                      + bucketed-vs-padded scheduler JSON)
  Table VI      -> bench_portability (same program jit vs eager)
  kernels       -> bench_kernels     (hot-spot roofline estimates)
  beyond-paper  -> bench_large_n     (chunked-engine large-n trajectory,
                                      JSON lines; --only large_n — also
                                      runs the approx-vs-exact sweep)
  beyond-paper  -> --only approx     (Nystrom/RFF accuracy-vs-rank and
                                      wall-clock vs the exact SMO, plus
                                      a million-sample approx-only
                                      point; --quick is the CI parity
                                      smoke at small n)
  beyond-paper  -> --only scheduler  (bucketed-vs-padded multiclass
                                      scheduler JSON alone; CI smoke)
  beyond-paper  -> bench_sharded     (single-problem strong scaling vs
                                      shard count, JSON lines; --only
                                      sharded — needs a multi-device
                                      process, e.g. XLA_FLAGS=
                                      --xla_force_host_platform_device_count=8)
  beyond-paper  -> bench_svr         (epsilon-SVR SMO vs projected-GD
                                      wall time + MSE, JSON lines;
                                      --only svr)
  beyond-paper  -> bench_serving     (batched Predictor vs per-call
                                      engine serving, requests/s at
                                      batch {1, 32, 256}, JSON lines;
                                      --only serving)
  beyond-paper  -> bench_serving_load (open-loop Poisson arrivals vs the
                                      dynamic-batching service: p50/p99
                                      latency + sustained requests/s per
                                      offered rate, dynamic vs
                                      per-request dispatch, plus the
                                      fp16/bf16 quantization gate;
                                      --only serving_load — --quick is
                                      the CI smoke asserting the
                                      speedup + accuracy gates)
  beyond-paper  -> bench_cascade     (hierarchical cascade training:
                                      wall clock / accuracy / KKT
                                      certificate vs shard count, JSON
                                      lines; --only cascade — --quick
                                      is the CI parity smoke)
  beyond-paper  -> tile_sweep        (autotuner tuned-vs-default tile
                                      configs for the Pallas kernels,
                                      JSON lines; part of the kernels
                                      section, or --only tile_sweep for
                                      the sweep alone; CI smoke uses
                                      --quick --only tile_sweep)
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks import common

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_suite(name: str, fn) -> None:
    """Run one suite with the record sink attached; write the collected
    records to ``<repo>/BENCH_<name>.json`` (skipped when a suite emits
    nothing, e.g. on an early error path)."""
    records: list = []
    common.set_sink(records)
    try:
        fn()
    finally:
        common.set_sink(None)
    if not records:
        return
    path = os.path.join(_REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"bench": name, "records": records}, f,
                  indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(records)} records -> {path}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="drop the largest sample sizes")
    ap.add_argument("--only", default="",
                    help="comma list: binary,multiclass,portability,"
                         "kernels; opt-in extras: large_n,approx,"
                         "scheduler,sharded,svr,serving,serving_load,"
                         "tile_sweep,cascade")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")

    from benchmarks import (bench_binary, bench_kernels, bench_large_n,
                            bench_multiclass, bench_portability)
    if args.quick:
        bench_binary.GD_STEPS = 300
        bench_multiclass.GD_STEPS = 300

    if only is None or "binary" in only:
        _run_suite("binary", bench_binary.main)
    if only is None or "multiclass" in only:
        def _multiclass():
            bench_multiclass.main()
            bench_multiclass.bucketed(quick=args.quick)
            if not args.quick:
                bench_multiclass.scaling()
        _run_suite("multiclass", _multiclass)
    if only is not None and "scheduler" in only:
        # the bucketed-vs-padded JSON comparison alone (CI smoke)
        _run_suite("scheduler",
                   lambda: bench_multiclass.bucketed(quick=args.quick))
    if only is None or "portability" in only:
        _run_suite("portability", bench_portability.main)
    if only is None or "kernels" in only:
        def _kernels():
            bench_kernels.main()
            bench_kernels.tile_sweep(quick=args.quick)
        _run_suite("kernels", _kernels)
    if only is not None and "tile_sweep" in only:
        # the autotuner tuned-vs-default JSON alone (CI smoke)
        _run_suite("tile_sweep",
                   lambda: bench_kernels.tile_sweep(quick=args.quick))
    if only is not None and "large_n" in only:
        # opt-in: minutes-long at full size (JSON lines, not CSV)
        def _large_n():
            bench_large_n.main(quick=args.quick)
            bench_large_n.approx_sweep(quick=args.quick)
        _run_suite("large_n", _large_n)
    if only is not None and "approx" in only:
        # opt-in: the approx-vs-exact sweep alone (CI smoke: --quick
        # asserts the small-n accuracy parity gate)
        _run_suite("approx",
                   lambda: bench_large_n.approx_sweep(quick=args.quick))
    if only is not None and "sharded" in only:
        # opt-in: single-problem strong scaling over forced host devices
        from benchmarks import bench_sharded
        _run_suite("sharded", lambda: bench_sharded.main(quick=args.quick))
    if only is not None and "svr" in only:
        # opt-in: the regression analog of the SMO-vs-GD comparison
        from benchmarks import bench_svr
        _run_suite("svr", lambda: bench_svr.main(quick=args.quick))
    if only is not None and "cascade" in only:
        # opt-in: cascade shard-solve-reduce scaling (CI smoke: --quick
        # asserts the certificate + accuracy parity gate)
        from benchmarks import bench_cascade
        _run_suite("cascade", lambda: bench_cascade.main(quick=args.quick))
    if only is not None and "serving" in only:
        # opt-in: batched Predictor vs the per-call engine serving path
        from benchmarks import bench_serving
        _run_suite("serving", lambda: bench_serving.main(quick=args.quick))
    if only is not None and "serving_load" in only:
        # opt-in: open-loop Poisson load on the dynamic-batching service
        # (asserts the batching speedup + quantization accuracy gates)
        from benchmarks import bench_serving_load
        _run_suite("serving_load",
                   lambda: bench_serving_load.main(quick=args.quick))


if __name__ == "__main__":
    main()
