"""Large-n training trajectory: the chunked KernelEngine past the dense
memory wall.

Sweeps n over {2k, 8k, 16k, 32k} binary RBF problems with the chunked +
adaptive-shrinking engine (LRU row cache, WSS2 selection) and emits one
JSON line per point via ``benchmarks.common.emit_json``:

    {"bench": "large_n", "n": 16384, "backend": "chunked",
     "wall_s": ..., "n_iter": ..., "converged": ..., "n_sv": ...,
     "mem_mode": "chunked", "gram_bytes_dense": ..., "peak_gram_bytes": ...}

``gram_bytes_dense`` is what the full-Gram path would need (n^2 * 4);
``peak_gram_bytes`` is the chunked engine's actual resident kernel state
(chunk*n matvec stripe + cache_slots*n LRU rows). Future PRs diff this
trajectory for regressions as the scaling work proceeds.

``approx_sweep`` is the approximate-tier companion (``--only approx``
through ``benchmarks.run``): held-out accuracy and wall clock of the
Nyström / RFF low-rank engines vs the exact chunked SMO across rank at
a feasible n, plus a million-sample approx-only point where the exact
path cannot run at all (the dense Gram would be 4 TB) — peak kernel
memory for the low-rank tier is the (n, rank) feature matrix. In
``--quick`` mode the sweep doubles as the CI parity smoke: it ASSERTS
the largest-rank accuracy lands within ``QUICK_GATE`` of exact.

    PYTHONPATH=src python -m benchmarks.bench_large_n [--quick]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit_json
from repro.core import kernel_engine as KE
from repro.core import kernels as K, smo
from repro.data import make_blobs, normalize

SIZES = (2048, 8192, 16384, 32768)
CACHE_SLOTS = 16
CHUNK = 2048


def _problem(n: int, d: int = 8, seed: int = 7):
    x, y = make_blobs(n // 2, 2, d, sep=4.0, seed=seed)
    yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
    return normalize(x), yy


def bench_one(n: int) -> dict:
    x, yy = _problem(n)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    ecfg = KE.EngineConfig(backend="chunked", cache_slots=CACHE_SLOTS,
                           chunk=CHUNK)
    cfg = smo.SMOConfig(max_iter=60_000, shrink_every=4,
                        selection="second")
    fit = jax.jit(lambda a, b: smo.binary_smo(a, b, cfg=cfg, kernel=kp,
                                              engine=ecfg))
    xj, yj = jnp.asarray(x), jnp.asarray(yy)
    r = fit(xj, yj)          # warmup includes compile
    jax.block_until_ready(r.alpha)
    t0 = time.perf_counter()
    r = fit(xj, yj)
    jax.block_until_ready(r.alpha)
    wall = time.perf_counter() - t0
    chunk = min(CHUNK, n)
    return {
        "bench": "large_n",
        "n": n,
        "backend": ecfg.backend,
        "selection": cfg.selection,
        "shrink_every": cfg.shrink_every,
        "wall_s": round(wall, 3),
        "n_iter": int(r.n_iter),
        "converged": bool(r.converged),
        "gap": float(r.gap),
        "n_sv": int((np.asarray(r.alpha) > 1e-8).sum()),
        "mem_mode": "chunked",
        "gram_bytes_dense": 4 * n * n,
        "peak_gram_bytes": 4 * n * (chunk + CACHE_SLOTS),
    }


def main(quick: bool = False) -> None:
    sizes = SIZES[:2] if quick else SIZES
    for n in sizes:
        emit_json(bench_one(n))


# ----------------------------------------------------- approximate tier
APPROX_N = 16384          # exact-vs-approx comparison size
APPROX_N_QUICK = 4096
APPROX_TEST = 2048        # held-out rows for accuracy
RANKS = (64, 128, 256, 512)
RANKS_QUICK = (64, 128)
HUGE_N = 1_000_000        # approx-only point; dense Gram would be 4 TB
HUGE_RANK = 128
HUGE_EPOCHS = 3           # bounded-wall demo point, not run to tol
QUICK_GATE = 0.02         # CI smoke: |acc_approx - acc_exact| gate


def _approx_problem(n: int, d: int = 8, seed: int = 7):
    x, y = make_blobs((n + APPROX_TEST) // 2, 2, d, sep=4.0, seed=seed)
    x = normalize(x)   # make_blobs shuffles, so a tail split is iid
    return (x[:n], y[:n]), (x[n:n + APPROX_TEST], y[n:n + APPROX_TEST])


def _timed_fit(clf, x, y):
    t0 = time.perf_counter()
    clf.fit(x, y)
    return time.perf_counter() - t0


def _accuracy(clf, xte, yte) -> float:
    df = clf._decision_function_engine(xte)
    pred = clf.classes_[(df > 0).astype(np.int64)]
    return float(np.mean(pred == yte))


def approx_sweep(quick: bool = False) -> None:
    from repro.core import linear
    from repro.core.svm import SVC

    n = APPROX_N_QUICK if quick else APPROX_N
    ranks = RANKS_QUICK if quick else RANKS
    (xtr, ytr), (xte, yte) = _approx_problem(n)

    exact = SVC(engine=KE.EngineConfig(backend="chunked",
                                       cache_slots=CACHE_SLOTS,
                                       chunk=min(CHUNK, n)),
                shrink_every=4)
    wall = _timed_fit(exact, xtr, ytr)
    acc_exact = _accuracy(exact, xte, yte)
    emit_json({"bench": "approx", "n": n, "engine": "exact-smo",
               "rank": None, "wall_s": round(wall, 3),
               "n_iter": exact.n_iter_, "accuracy": round(acc_exact, 4),
               "acc_delta_vs_exact": 0.0,
               "peak_gram_bytes": 4 * n * (min(CHUNK, n) + CACHE_SLOTS)})

    last_acc = {}
    for engine in ("nystrom", "rff"):
        for rank in ranks:
            clf = SVC(engine=engine, rank=rank)
            wall = _timed_fit(clf, xtr, ytr)
            acc = _accuracy(clf, xte, yte)
            last_acc[engine] = acc
            emit_json({"bench": "approx", "n": n, "engine": engine,
                       "rank": rank, "wall_s": round(wall, 3),
                       "n_iter": clf.n_iter_,
                       "converged": clf.converged_,
                       "accuracy": round(acc, 4),
                       "acc_delta_vs_exact": round(acc - acc_exact, 4),
                       "peak_gram_bytes": 4 * n * rank})

    if quick:
        # CI parity smoke: at the largest quick rank both approximations
        # must land within QUICK_GATE of the exact-SMO accuracy
        for engine, acc in last_acc.items():
            assert acc >= acc_exact - QUICK_GATE, (
                f"approx parity gate: {engine} accuracy {acc:.4f} vs "
                f"exact {acc_exact:.4f} (gate {QUICK_GATE})")
        return

    # the million-sample point: approx-only (no exact baseline exists —
    # the dense Gram alone would be 4 * n^2 = 4 TB); epochs are bounded
    # so this is a throughput/feasibility point, not a solve to tol
    (xtr, ytr), (xte, yte) = _approx_problem(HUGE_N)
    for engine in ("nystrom", "rff"):
        clf = SVC(engine=engine, rank=HUGE_RANK)
        clf.dcd_cfg = linear.DCDConfig(C=clf.smo_cfg.C, tol=clf.smo_cfg.tol,
                                       max_epochs=HUGE_EPOCHS)
        wall = _timed_fit(clf, xtr, ytr)
        acc = _accuracy(clf, xte, yte)
        emit_json({"bench": "approx", "n": HUGE_N, "engine": engine,
                   "rank": HUGE_RANK, "wall_s": round(wall, 3),
                   "n_iter": clf.n_iter_, "max_epochs": HUGE_EPOCHS,
                   "accuracy": round(acc, 4),
                   "gram_bytes_dense": 4 * HUGE_N * HUGE_N,
                   "peak_gram_bytes": 4 * HUGE_N * HUGE_RANK})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="only the two smallest sizes")
    args = ap.parse_args()
    main(quick=args.quick)
