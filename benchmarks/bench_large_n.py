"""Large-n training trajectory: the chunked KernelEngine past the dense
memory wall.

Sweeps n over {2k, 8k, 16k, 32k} binary RBF problems with the chunked +
adaptive-shrinking engine (LRU row cache, WSS2 selection) and emits one
JSON line per point via ``benchmarks.common.emit_json``:

    {"bench": "large_n", "n": 16384, "backend": "chunked",
     "wall_s": ..., "n_iter": ..., "converged": ..., "n_sv": ...,
     "mem_mode": "chunked", "gram_bytes_dense": ..., "peak_gram_bytes": ...}

``gram_bytes_dense`` is what the full-Gram path would need (n^2 * 4);
``peak_gram_bytes`` is the chunked engine's actual resident kernel state
(chunk*n matvec stripe + cache_slots*n LRU rows). Future PRs diff this
trajectory for regressions as the scaling work proceeds.

    PYTHONPATH=src python -m benchmarks.bench_large_n [--quick]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit_json
from repro.core import kernel_engine as KE
from repro.core import kernels as K, smo
from repro.data import make_blobs, normalize

SIZES = (2048, 8192, 16384, 32768)
CACHE_SLOTS = 16
CHUNK = 2048


def _problem(n: int, d: int = 8, seed: int = 7):
    x, y = make_blobs(n // 2, 2, d, sep=4.0, seed=seed)
    yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
    return normalize(x), yy


def bench_one(n: int) -> dict:
    x, yy = _problem(n)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    ecfg = KE.EngineConfig(backend="chunked", cache_slots=CACHE_SLOTS,
                           chunk=CHUNK)
    cfg = smo.SMOConfig(max_iter=60_000, shrink_every=4,
                        selection="second")
    fit = jax.jit(lambda a, b: smo.binary_smo(a, b, cfg=cfg, kernel=kp,
                                              engine=ecfg))
    xj, yj = jnp.asarray(x), jnp.asarray(yy)
    r = fit(xj, yj)          # warmup includes compile
    jax.block_until_ready(r.alpha)
    t0 = time.perf_counter()
    r = fit(xj, yj)
    jax.block_until_ready(r.alpha)
    wall = time.perf_counter() - t0
    chunk = min(CHUNK, n)
    return {
        "bench": "large_n",
        "n": n,
        "backend": ecfg.backend,
        "selection": cfg.selection,
        "shrink_every": cfg.shrink_every,
        "wall_s": round(wall, 3),
        "n_iter": int(r.n_iter),
        "converged": bool(r.converged),
        "gap": float(r.gap),
        "n_sv": int((np.asarray(r.alpha) > 1e-8).sum()),
        "mem_mode": "chunked",
        "gram_bytes_dense": 4 * n * n,
        "peak_gram_bytes": 4 * n * (chunk + CACHE_SLOTS),
    }


def main(quick: bool = False) -> None:
    sizes = SIZES[:2] if quick else SIZES
    for n in sizes:
        emit_json(bench_one(n))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="only the two smallest sizes")
    args = ap.parse_args()
    main(quick=args.quick)
