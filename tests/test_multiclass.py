"""The multiclass strategy layer: OvO/OvR task builders, the
size-bucketed LPT scheduler, vectorized voting, and engine-backed
multiclass serving."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dist, kernel_engine as KE, kernels as K
from repro.core import multiclass as MC
from repro.core import ovo
from repro.core.svm import SVC
from repro.data import (load_iris, make_blobs, make_imbalanced_blobs,
                        normalize)

IMBALANCED_SIZES = (64, 48, 24, 12, 7)  # 5-class fixture of the ISSUE


def _imbalanced(seed=0):
    x, y = make_imbalanced_blobs(IMBALANCED_SIZES, 10, sep=4.0, seed=seed)
    return normalize(x), y


# ------------------------------------------------------------- strategies
class TestStrategies:
    def test_ovo_taskset_shape(self):
        x, y = _imbalanced()
        ts = MC.get_strategy("ovo").build_taskset(x, y)
        m = len(IMBALANCED_SIZES)
        assert ts.n_tasks == m * (m - 1) // 2
        # task sizes are sums of the two class sizes
        sz = sorted(IMBALANCED_SIZES, reverse=True)
        assert int(ts.sizes.max()) == sz[0] + sz[1]
        assert int(ts.sizes.min()) == sz[-1] + sz[-2]
        for t in ts.tasks:
            assert set(np.unique(t.y)) == {-1.0, 1.0}

    def test_ovr_taskset_shape(self):
        x, y = _imbalanced()
        ts = MC.get_strategy("ovr").build_taskset(x, y)
        assert ts.n_tasks == len(IMBALANCED_SIZES)
        for c, t in enumerate(ts.tasks):
            assert t.size == len(y)                     # every sample
            assert (t.y > 0).sum() == IMBALANCED_SIZES[c]
            assert t.pos == c and t.neg == -1

    def test_unknown_strategy_raises(self):
        with pytest.raises(ValueError, match="unknown multiclass"):
            MC.get_strategy("ova")

    def test_ovo_vs_ovr_agree_on_separable(self):
        # well-separated blobs: both decompositions must predict the
        # same classes (and get them right)
        x, y = make_blobs(40, 4, 8, sep=6.0, seed=5)
        x = normalize(x)
        a = SVC(solver="smo", strategy="ovo").fit(x, y)
        b = SVC(solver="smo", strategy="ovr").fit(x, y)
        assert a.score(x, y) == 1.0
        assert b.score(x, y) == 1.0
        np.testing.assert_array_equal(a.predict(x), b.predict(x))

    def test_margin_decision_matches_vote_when_unambiguous(self):
        x, y = make_blobs(30, 3, 6, sep=6.0, seed=2)
        x = normalize(x)
        v = SVC(solver="smo", decision="vote").fit(x, y)
        m = SVC(solver="smo", decision="margin").fit(x, y)
        np.testing.assert_array_equal(v.predict(x), m.predict(x))

    def test_bad_decision_mode_raises(self):
        # eagerly, at construction — not after a potentially long fit
        with pytest.raises(ValueError, match="unknown OvO decision"):
            SVC(solver="smo", decision="softmax")


# -------------------------------------------------------------- scheduler
class TestScheduler:
    def test_pow2_bucketing_and_lpt_cover_all_tasks(self):
        sizes = [300, 40, 37, 150, 8, 8, 8]
        sch = MC.build_schedule(sizes, MC.ScheduleConfig(n_workers=2))
        seen = []
        for b in sch.buckets:
            assert b.task_ids.shape[0] == 2
            for t in b.task_ids.reshape(-1):
                if t >= 0:
                    assert sizes[t] <= b.width  # width covers the task
                    seen.append(int(t))
        assert sorted(seen) == list(range(len(sizes)))

    def test_tiny_tasks_capped_at_global_max(self):
        # min_width must not push widths past the global max size: that
        # would schedule MORE padding than the legacy pad-to-max layout
        sch = MC.build_schedule([16, 16, 16], MC.ScheduleConfig())
        assert [b.width for b in sch.buckets] == [16]
        sb = MC.schedule_stats([16, 16, 16], sch)
        assert sb["padded_flop_fraction"] == 0.0

    def test_padded_schedule_is_single_bucket(self):
        sch = MC.build_schedule([10, 20, 30],
                                MC.ScheduleConfig(bucket_by="none"))
        assert len(sch.buckets) == 1
        assert sch.buckets[0].width == 30

    def test_bucketed_schedules_less_cost_than_padded(self):
        x, y = _imbalanced()
        ts = MC.get_strategy("ovo").build_taskset(x, y)
        bucketed = MC.build_schedule(ts.sizes, MC.ScheduleConfig())
        padded = MC.build_schedule(ts.sizes,
                                   MC.ScheduleConfig(bucket_by="none"))
        sb = MC.schedule_stats(ts.sizes, bucketed)
        sp = MC.schedule_stats(ts.sizes, padded)
        assert sb["scheduled_cost"] < sp["scheduled_cost"]
        assert sb["padded_flop_fraction"] < sp["padded_flop_fraction"]

    def test_lpt_balances_workers(self):
        # 4 heavy + 4 light tasks over 2 workers: LPT must not stack all
        # heavy tasks on one worker (blind striping would)
        sizes = [256, 256, 256, 256, 16, 16, 16, 16]
        sch = MC.build_schedule(sizes, MC.ScheduleConfig(n_workers=2,
                                                         min_width=16))
        heavy = sch.buckets[0].task_ids
        assert (heavy >= 0).sum(axis=1).tolist() == [2, 2]


def test_schedule_property_hypothesis():
    hyp = pytest.importorskip(
        "hypothesis",
        reason="optional dev dependency (see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @given(st.lists(st.integers(1, 500), min_size=1, max_size=40),
           st.integers(1, 4), st.sampled_from([8, 32, 64]))
    @settings(max_examples=50, deadline=None)
    def check(sizes, workers, min_width):
        sch = MC.build_schedule(
            sizes, MC.ScheduleConfig(n_workers=workers,
                                     min_width=min_width))
        seen = []
        widths = set()
        for b in sch.buckets:
            assert b.width not in widths  # one bucket per shape
            widths.add(b.width)
            assert b.task_ids.shape[0] == workers
            for t in b.task_ids.reshape(-1):
                if t >= 0:
                    assert sizes[t] <= b.width
                    seen.append(int(t))
        # every task scheduled exactly once
        assert sorted(seen) == list(range(len(sizes)))

    check()


# ------------------------------------------------- bucketed == padded fit
class TestBucketedEquivalence:
    def test_fit_taskset_bucketed_matches_padded(self):
        x, y = _imbalanced()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        ts = MC.get_strategy("ovo").build_taskset(x, y)
        fb = dist.fit_taskset(ts, kernel=kp,
                              schedule_cfg=MC.ScheduleConfig())
        fp = dist.fit_taskset(
            ts, kernel=kp,
            schedule_cfg=MC.ScheduleConfig(bucket_by="none"))
        # masked solves are width-invariant: identical alphas and biases
        np.testing.assert_allclose(fb.alpha, fp.alpha, rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(fb.b, fp.b, rtol=1e-5, atol=1e-6)

    def test_svc_bucketed_matches_padded_predictions(self):
        x, y = _imbalanced()
        b = SVC(solver="smo", schedule="bucketed").fit(x, y)
        p = SVC(solver="smo", schedule="padded").fit(x, y)
        # same support sets ...
        np.testing.assert_array_equal(b.n_support_, p.n_support_)
        np.testing.assert_allclose(b._fit.alpha, p._fit.alpha,
                                   rtol=1e-5, atol=1e-6)
        # ... and exactly the same predictions
        xq = np.asarray(
            make_imbalanced_blobs(IMBALANCED_SIZES, 10, sep=4.0,
                                  seed=9)[0], np.float32)
        np.testing.assert_array_equal(b.predict(xq), p.predict(xq))

    def test_ovo_shim_matches_fit_taskset(self):
        x, y = load_iris()
        x = normalize(x)
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        tasks = ovo.build_tasks(x, y)
        shim = dist.vmapped_ovo_fit(tasks, solver="smo", kernel=kp)
        ts = dist.taskset_from_ovo(tasks)
        fit = dist.fit_taskset(
            ts, kernel=kp,
            schedule_cfg=MC.ScheduleConfig(bucket_by="none",
                                           pad_width=tasks.y.shape[1]))
        np.testing.assert_allclose(np.asarray(shim.alpha)[:, :fit.alpha.shape[1]],
                                   fit.alpha, rtol=1e-6, atol=1e-7)


# ------------------------------------------------------------- vectorized vote
class TestVote:
    def _reference_votes(self, decisions, pairs, classes, n_real):
        """The pre-vectorization loop-of-scatter-adds implementation
        (returns the full vote matrix)."""
        m = len(classes)
        cls_index = {c: i for i, c in enumerate(classes)}
        votes = np.zeros((decisions.shape[1], m), np.float64)
        for t in range(n_real):
            a, b = pairs[t]
            pos = decisions[t] > 0
            votes[:, cls_index[a]] += pos.astype(np.float64)
            votes[:, cls_index[b]] += (~pos).astype(np.float64)
            votes[:, cls_index[a]] += 1e-6 * np.tanh(decisions[t])
            votes[:, cls_index[b]] -= 1e-6 * np.tanh(decisions[t])
        return votes

    def test_vectorized_vote_matches_loop_reference(self):
        rng = np.random.default_rng(0)
        classes = np.array([3, 7, 11, 20])
        pairs = np.array([(a, b) for i, a in enumerate(classes)
                          for b in classes[i + 1:]])
        dec = rng.normal(size=(len(pairs) + 2, 64)).astype(np.float32)
        got = np.asarray(ovo.vote(jnp.asarray(dec), pairs, classes,
                                  len(pairs)))
        votes = self._reference_votes(dec, pairs, classes, len(pairs))
        want = np.argmax(votes, axis=1)
        # summation ORDER differs (loop of scatter-adds vs one matmul),
        # so argmax may legitimately flip where the 1e-6 tie-break sums
        # agree to float noise; require equality on all decided samples
        top2 = np.sort(votes, axis=1)[:, -2:]
        decided = (top2[:, 1] - top2[:, 0]) > 1e-9
        assert decided.sum() >= int(0.9 * len(decided))
        np.testing.assert_array_equal(got[decided], want[decided])

    def test_vectorized_vote_exact_on_unambiguous(self):
        classes = np.array([0, 1, 2])
        pairs = np.array([[0, 1], [0, 2], [1, 2]])
        dec = jnp.asarray(np.array([[+1.0, -1.0], [+1.0, -5.0],
                                    [+1.0, -1.0]]))
        idx = np.asarray(ovo.vote(dec, pairs, classes, 3))
        assert idx.tolist() == [0, 2]

    def test_margin_decision_prefers_larger_margin(self):
        # class 0 wins 0v1 weakly, loses 0v2; class 2 wins both its tasks
        pairs = np.array([[0, 1], [0, 2], [1, 2]])
        df = jnp.asarray(np.array([[0.1], [-2.0], [-2.0]]))
        idx = MC.margin_decision(df, pairs, 3)
        assert int(idx[0]) == 2


# ---------------------------------------------------- engine-backed serving
class TestServingEngine:
    def test_multiclass_decision_function_respects_engine(self, monkeypatch):
        """The multiclass serving path must go through KernelEngine (not
        K.make_gram_fn directly), so engine='pallas'/'chunked' is honored
        at predict time."""
        x, y = _imbalanced()
        clf = SVC(solver="smo", engine="chunked").fit(x, y)
        seen = []
        orig = KE.make_engine

        def spy(xs, kernel, cfg=KE.EngineConfig(), **kw):
            eng = orig(xs, kernel, cfg, **kw)
            seen.append(eng.backend)
            return eng

        monkeypatch.setattr(KE, "make_engine", spy)
        clf.decision_function(x[:8])
        assert seen and all(b == "chunked" for b in seen)

    def test_multiclass_pallas_serving_matches_chunked(self):
        import dataclasses

        x, y = _imbalanced()
        clf = SVC(solver="smo", engine="chunked").fit(x, y)
        df_chunked = clf.decision_function(x[:16])
        # same fitted model, serving Gram re-routed to the pallas engine
        clf.engine_cfg = dataclasses.replace(clf.engine_cfg,
                                             backend="pallas")
        df_pallas = clf.decision_function(x[:16])
        np.testing.assert_allclose(df_chunked, df_pallas,
                                   rtol=1e-4, atol=1e-5)

    def test_ovr_svc_on_iris(self):
        x, y = load_iris()
        x = normalize(x)
        clf = SVC(solver="smo", strategy="ovr").fit(x, y)
        assert clf.score(x, y) >= 0.93
        df = clf.decision_function(x[:5])
        assert df.shape == (3, 5)  # one task per class


# ----------------------------------------------------------- distributed
def test_fit_taskset_rejects_mismatched_schedule():
    x, y = _imbalanced()
    ts = MC.get_strategy("ovo").build_taskset(x, y)
    sch = MC.build_schedule(ts.sizes, MC.ScheduleConfig(n_workers=2))
    with pytest.raises(ValueError, match="workers"):
        dist.fit_taskset(ts, sch)  # no mesh -> 1 worker
