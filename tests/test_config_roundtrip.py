"""Every public config field observably changes behavior — or errors.

The R005 rule catches a kwarg that never REACHES a config; this suite
closes the other half of the max_iter bug class: a field that reaches
the config but is then ignored by the solver. One parametrized case per
public field of ``SMOConfig`` / ``DCDConfig`` / ``EngineConfig``: flip
the field between two values and assert a solver-visible observable
(alphas, iteration counts, engine class, program structure, values)
differs — or that the invalid setting raises.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import kernel_engine as KE
from repro.core import kernels as K
from repro.core import linear, smo
from repro.data import make_blobs, normalize


def _blobs(n=24, d=3, seed=0):
    rng = np.random.default_rng(seed)
    y = np.where(np.arange(n) % 2 == 0, 1.0, -1.0).astype(np.float32)
    x = (rng.normal(size=(n, d)) + 2.0 * y[:, None]).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


X, Y = _blobs()
KP = K.KernelParams(name="rbf", gamma=0.5)


def _smo(**overrides):
    return smo.binary_smo(X, Y, cfg=smo.SMOConfig(**overrides), kernel=KP)


def _smo_engine(cfg: smo.SMOConfig):
    return smo._resolve_engine(X, KP, cfg, engine=None, gram=None,
                               row_fn=None)


# ------------------------------------------------------------ SMOConfig
def _overlap_smo(**overrides):
    """Overlapping blobs solved to a MID-RUN iteration cap: the final
    convergence pass un-shrinks (n_active == n at convergence by
    design), so shrinking is only observable when the cap fires while
    the corridor freeze is in effect."""
    x, y = make_blobs(150, 2, 10, sep=0.8, seed=3)
    yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
    x = normalize(x)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    return smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp,
                          engine="chunked",
                          cfg=smo.SMOConfig(max_iter=320, **overrides))


def _max_iter_case():
    capped, free = _smo(max_iter=2), _smo(max_iter=100_000)
    return (int(capped.n_iter) < int(free.n_iter)
            and not bool(capped.converged) and bool(free.converged))


SMO_FIELD_CASES = {
    "C": lambda: not np.allclose(_smo(C=1.0).alpha, _smo(C=0.05).alpha),
    "tol": lambda: int(_smo(tol=1e-6).n_iter) != int(_smo(tol=0.5).n_iter),
    "max_iter": _max_iter_case,
    # the cap quantizes to check_every device iterations per check
    "check_every": lambda: (int(_smo(max_iter=2, check_every=1).n_iter)
                            != int(_smo(max_iter=2, check_every=32).n_iter)),
    "precompute_gram": lambda: (
        type(_smo_engine(smo.SMOConfig(precompute_gram=True)))
        is not type(_smo_engine(smo.SMOConfig(precompute_gram=False)))),
    "use_pallas": lambda: isinstance(
        _smo_engine(smo.SMOConfig(use_pallas=True, precompute_gram=False)),
        KE.PallasKernelEngine),
    "selection": lambda: (int(_smo(selection="first").n_iter)
                          != int(_smo(selection="second").n_iter)),
    "shrink_every": lambda: (int(_overlap_smo(shrink_every=1).n_active)
                             < int(_overlap_smo(shrink_every=0).n_active)),
    "shrink_slack": lambda: (
        int(_overlap_smo(shrink_every=1, shrink_slack=0.0).n_active)
        != int(_overlap_smo(shrink_every=1, shrink_slack=1000.0).n_active)),
}


@pytest.mark.parametrize("field", sorted(f.name for f in
                                         dataclasses.fields(smo.SMOConfig)))
def test_smo_config_field_observable(field):
    assert field in SMO_FIELD_CASES, (
        f"SMOConfig grew field {field!r}: add an observability case")
    assert SMO_FIELD_CASES[field](), (
        f"SMOConfig.{field} did not observably change solver behavior")


# ------------------------------------------------------------ DCDConfig
PHI = jnp.asarray(np.random.default_rng(1).normal(
    size=(32, 4)).astype(np.float32))
YL = jnp.asarray(np.where(np.arange(32) % 2 == 0, 1.0, -1.0)
                 .astype(np.float32))


def _dcd(**overrides):
    return linear.linear_svc(PHI, YL, cfg=linear.DCDConfig(**overrides))


DCD_FIELD_CASES = {
    "C": lambda: not np.allclose(_dcd(C=1.0).alpha, _dcd(C=0.01).alpha),
    "tol": lambda: int(_dcd(tol=1e-8).n_iter) != int(_dcd(tol=0.9).n_iter),
    "max_epochs": lambda: (int(_dcd(max_epochs=1).n_iter)
                           < int(_dcd(max_epochs=1000).n_iter)),
    "bias": lambda: (float(_dcd(bias=0.0).b) == 0.0
                     and float(_dcd(bias=1.0).b) != 0.0),
}


@pytest.mark.parametrize("field", sorted(f.name for f in
                                         dataclasses.fields(linear.DCDConfig)))
def test_dcd_config_field_observable(field):
    assert field in DCD_FIELD_CASES, (
        f"DCDConfig grew field {field!r}: add an observability case")
    assert DCD_FIELD_CASES[field](), (
        f"DCDConfig.{field} did not observably change solver behavior")


# ---------------------------------------------------------- EngineConfig
def _engine(**overrides):
    return KE.make_engine(X, KP, KE.EngineConfig(**overrides))


def _backend_case():
    assert isinstance(_engine(backend="dense"), KE.DenseKernelEngine)
    assert isinstance(_engine(backend="chunked"), KE.ChunkedKernelEngine)
    with pytest.raises(ValueError):
        _engine(backend="no-such-backend")
    return True


def _cache_slots_case():
    eng0 = _engine(backend="chunked", cache_slots=0)
    eng8 = _engine(backend="chunked", cache_slots=8)
    return (eng0.init_cache() is None
            and eng8.init_cache().rows.shape == (8, X.shape[0]))


def _chunk_case():
    # the streaming block size changes the compiled program structure
    # of the training matvec (decide streams over TEST rows, which fit
    # one block at either setting here)
    j4 = str(jax.make_jaxpr(
        lambda a: _engine(backend="chunked", chunk=4).matvec(a))(Y))
    j16 = str(jax.make_jaxpr(
        lambda a: _engine(backend="chunked", chunk=16).matvec(a))(Y))
    return j4 != j16


def _dense_limit_case():
    n = X.shape[0]
    small = _engine(backend="auto", dense_limit=n)
    big = _engine(backend="auto", dense_limit=n - 1)
    return (isinstance(small, KE.DenseKernelEngine)
            and isinstance(big, KE.ChunkedKernelEngine)
            and not isinstance(big, KE.PallasKernelEngine))


def _shard_axis_case():
    with pytest.raises(ValueError, match="shard_axis"):
        _engine(backend="sharded")
    return True


def _gram_dtype_case():
    g32 = np.asarray(_engine(backend="chunked", gram_dtype="fp32").full())
    g16 = np.asarray(_engine(backend="chunked", gram_dtype="bf16").full())
    return (not np.array_equal(g32, g16)) and np.allclose(g32, g16,
                                                          atol=5e-2)


ENGINE_FIELD_CASES = {
    "backend": _backend_case,
    "cache_slots": _cache_slots_case,
    "chunk": _chunk_case,
    "dense_limit": _dense_limit_case,
    "shard_axis": _shard_axis_case,
    "gram_dtype": _gram_dtype_case,
    "rank": lambda: (_engine(backend="rff", rank=8).rank == 8
                     and _engine(backend="rff", rank=16).rank == 16),
    "landmarks": lambda: not np.allclose(
        np.asarray(_engine(backend="nystrom", rank=8,
                           landmarks="uniform").phi),
        np.asarray(_engine(backend="nystrom", rank=8,
                           landmarks="kmeans++").phi)),
    "seed": lambda: not np.allclose(
        np.asarray(_engine(backend="rff", rank=8, seed=0).phi),
        np.asarray(_engine(backend="rff", rank=8, seed=1).phi)),
}


@pytest.mark.parametrize("field", sorted(f.name for f in
                                         dataclasses.fields(KE.EngineConfig)))
def test_engine_config_field_observable(field):
    assert field in ENGINE_FIELD_CASES, (
        f"EngineConfig grew field {field!r}: add an observability case")
    assert ENGINE_FIELD_CASES[field](), (
        f"EngineConfig.{field} did not observably change engine behavior")
