"""End-to-end behaviour tests: the paper's pipeline and the LM driver."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.svm import SVC
from repro.data import (load_breast_cancer_like, load_iris,
                        load_pavia_like, normalize, train_test_split)
from repro.data.pipeline import subsample_per_class


class TestPaperPipeline:
    """The paper's three dataset scenarios, end to end (accuracy checks —
    the TIME comparison lives in benchmarks/)."""

    def test_iris_binary_both_solvers(self):
        # paper Table V: Iris 40 points / 4 features / 2 classes
        x, y = load_iris()
        x = normalize(x)
        xs, ys = subsample_per_class(x[y != 2], y[y != 2], 20, seed=0)
        for solver in ("smo", "gd"):
            clf = SVC(solver=solver, gd_steps=2000).fit(xs, ys)
            assert clf.score(xs, ys) >= 0.95, solver

    def test_breast_cancer_binary(self):
        # paper Table V: 190 points / 32 features / 2 classes
        x, y = load_breast_cancer_like()
        x = normalize(x)
        xs, ys = subsample_per_class(x, y, 95, seed=0)
        clf = SVC(solver="smo").fit(xs, ys)
        assert clf.score(xs, ys) >= 0.9

    def test_pavia_multiclass_9(self):
        # paper Table IV: 9-class one-vs-one
        x, y = load_pavia_like(n_per_class=30)
        x = normalize(x)
        xtr, ytr, xte, yte = train_test_split(x, y, test_frac=0.25, seed=1)
        clf = SVC(solver="smo").fit(xtr, ytr)
        assert clf.score(xte, yte) >= 0.95
        assert clf.converged_

    def test_generalization_train_test(self):
        x, y = load_iris()
        x = normalize(x)
        xtr, ytr, xte, yte = train_test_split(x, y, test_frac=0.2, seed=0)
        clf = SVC(solver="smo").fit(xtr, ytr)
        assert clf.score(xte, yte) >= 0.9


class TestLMTraining:
    def test_reduced_lm_loss_decreases(self):
        """A reduced mamba2 trains on the synthetic stream and the loss
        moves down within 30 steps (end-to-end driver sanity)."""
        from repro.configs.base import get_config, reduced
        from repro.data.lm import token_batches
        from repro.models.model import Model
        from repro.optim.adamw import AdamW
        from repro.training.train import make_train_step

        cfg = reduced(get_config("mamba2_780m"))
        model = Model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=3e-3)
        state = opt.init(params)
        step = jax.jit(make_train_step(model, opt))
        losses = []
        for nb in token_batches(vocab_size=cfg.vocab_size, batch=4,
                                seq_len=64, n_batches=30, seed=0):
            batch = {k: jnp.asarray(v) for k, v in nb.items()}
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2

    def test_checkpoint_roundtrip_with_model(self, tmp_path):
        from repro.checkpoint import ckpt as CK
        from repro.configs.base import get_config, reduced
        from repro.models.model import Model

        cfg = reduced(get_config("phi4_mini_3p8b"))
        model = Model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        path = str(tmp_path / "m.npz")
        CK.save(path, params, step=1)
        restored = CK.restore(path, params)
        batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
        a, _ = model.forward(params, batch)
        b, _ = model.forward(restored, batch)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSVMOnEmbeddings:
    def test_svm_head_on_backbone_features(self):
        """The integration scenario from DESIGN.md: OvO-SVM trained on
        pooled transformer hidden states separates synthetic 'domains'."""
        from repro.configs.base import get_config, reduced
        from repro.models.model import Model
        from repro.models import layers as L

        cfg = reduced(get_config("phi4_mini_3p8b"))
        model = Model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        # three synthetic token "domains" (disjoint vocab ranges)
        feats, labels = [], []
        fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
        for c in range(3):
            lo = c * (cfg.vocab_size // 3)
            toks = rng.integers(lo, lo + cfg.vocab_size // 3,
                                (12, 16)).astype(np.int32)
            # mean-pooled final hidden state proxy: logits pooled
            lg = np.asarray(fwd(params, jnp.asarray(toks)),
                            np.float32).mean(axis=1)
            feats.append(lg[:, :256])
            labels.append(np.full(12, c))
        x = normalize(np.concatenate(feats))
        y = np.concatenate(labels)
        clf = SVC(solver="smo", C=10.0).fit(x, y)
        assert clf.score(x, y) >= 0.9
