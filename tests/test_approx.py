"""Approximate-kernel tier: Nyström / RFF feature maps, the low-rank
engine, the linear DCD path through SVC/SVR, and low-rank serving.

The load-bearing identities:

* Nyström with landmarks == all points reproduces the EXACT Gram
  (``K K^+ K = K``), so the approximation limit is testable exactly —
  including running the unchanged exact SMO over the low-rank engine
  and recovering the dense-engine solution.
* RFF Gram error is O(1/sqrt(rank)) Monte-Carlo: it must shrink as the
  feature count grows (hypothesis property over seeds).
* The fused Pallas feature-map kernel is bit-compatible with the jnp
  reference (fp32) across non-block-divisible shapes.
* Low-rank fits never materialize an (n, n) object — the slow-marked
  bounded-memory case pins that at n = 131072.
"""
import io

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import approx, kernel_engine as KE, kernels as K, linear
from repro.core import smo
from repro.core.svm import SVC, SVR
from repro.data import make_blobs, make_synth_regression, normalize
from repro import serve
from repro.kernels import ops


def _rbf(x, seed=0, gamma=-1.0):
    kp = K.KernelParams(name="rbf", gamma=gamma)
    return K.resolve_gamma(kp, jnp.asarray(x))


def _blob_problem(n=240, d=6, seed=0):
    x, y = make_blobs(n // 2, 2, d, sep=3.0, seed=seed)
    return normalize(x), y


# ------------------------------------------------------ approximation limit
def test_nystrom_full_rank_reproduces_exact_gram():
    """landmarks == all points => Phi Phi^T == K up to the spectral clip."""
    x, _ = _blob_problem(160)
    kp = _rbf(x)
    cfg = KE.EngineConfig(backend="nystrom", rank=160)
    fmap = approx.make_feature_map(jnp.asarray(x), kp, cfg)
    exact = K.make_gram_fn(kp)(jnp.asarray(x), jnp.asarray(x))
    phi = fmap.transform(jnp.asarray(x))
    err = float(jnp.max(jnp.abs(phi @ phi.T - exact)))
    assert err < 1e-4, err


def test_exact_smo_over_lowrank_engine_matches_dense_at_full_rank():
    """The unchanged exact SMO, run against the full-rank Nyström engine,
    must recover the dense-engine alphas (same QP up to the clip)."""
    x, y = _blob_problem(120)
    yy = jnp.asarray(np.where(y == 1, 1.0, -1.0).astype(np.float32))
    kp = _rbf(x)
    cfg = smo.SMOConfig(C=1.0, tol=1e-3)
    r_dense = smo.binary_smo(jnp.asarray(x), yy, cfg=cfg, kernel=kp,
                             engine=KE.EngineConfig(backend="dense"))
    r_low = smo.binary_smo(jnp.asarray(x), yy, cfg=cfg, kernel=kp,
                           engine=KE.EngineConfig(backend="nystrom",
                                                  rank=120))
    assert bool(r_low.converged)
    np.testing.assert_allclose(np.asarray(r_low.alpha),
                               np.asarray(r_dense.alpha), atol=5e-3)
    np.testing.assert_allclose(float(r_low.b), float(r_dense.b),
                               atol=5e-3)


def test_rff_gram_error_shrinks_with_rank():
    x, _ = _blob_problem(180)
    kp = _rbf(x)
    exact = np.asarray(K.make_gram_fn(kp)(jnp.asarray(x), jnp.asarray(x)))
    errs = []
    for rank in (32, 256, 2048):
        cfg = KE.EngineConfig(backend="rff", rank=rank, seed=3)
        phi = approx.make_feature_map(jnp.asarray(x), kp,
                                      cfg).transform(jnp.asarray(x))
        errs.append(float(np.mean(np.abs(np.asarray(phi @ phi.T)
                                         - exact))))
    assert errs[0] > errs[1] > errs[2], errs
    assert errs[2] < 0.02, errs


def test_rff_rejects_non_rbf():
    x, _ = _blob_problem(40)
    kp = K.KernelParams(name="linear")
    cfg = KE.EngineConfig(backend="rff", rank=16)
    with pytest.raises(ValueError, match="rff.*[Rr][Bb][Ff]"):
        approx.make_feature_map(jnp.asarray(x), kp, cfg)


# ------------------------------------------------------------- landmarks
@pytest.mark.parametrize("method", approx.LANDMARK_METHODS)
def test_select_landmarks_valid(method):
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(200, 4)).astype(np.float32))
    idx = approx.select_landmarks(x, 32, method, jax.random.PRNGKey(0))
    idx = np.asarray(idx)
    assert idx.shape == (32,)
    assert ((idx >= 0) & (idx < 200)).all()
    if method == "uniform":   # permutation-based: no duplicates
        assert len(np.unique(idx)) == 32


def test_kmeanspp_spreads_over_clusters():
    """D^2 seeding must hit every well-separated cluster at least once
    (uniform can miss one; that's the point of the method)."""
    x, y = make_blobs(50, 4, 3, sep=12.0, seed=1)
    idx = np.asarray(approx.select_landmarks(
        jnp.asarray(x), 8, "kmeans++", jax.random.PRNGKey(2)))
    assert len(set(y[idx])) == 4


def test_unknown_landmark_method_raises():
    x = jnp.zeros((10, 2), jnp.float32)
    with pytest.raises(ValueError, match="landmark"):
        approx.select_landmarks(x, 4, "grid", jax.random.PRNGKey(0))


# ------------------------------------------------------ fused Pallas kernel
@pytest.mark.parametrize("shape", [(128, 128, 128), (200, 77, 13),
                                   (5, 300, 257)])
def test_rff_features_pallas_matches_jnp(shape):
    n, k, d = shape
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    om = jnp.asarray(rng.normal(size=(d, k)).astype(np.float32))
    ph = jnp.asarray(rng.uniform(0, 2 * np.pi, size=k).astype(np.float32))
    scale = float(np.sqrt(2.0 / k))
    ref = scale * jnp.cos(x @ om + ph)
    got = ops.rff_features(x, om, ph, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5)
    got16 = ops.rff_features(x, om, ph, scale=scale, compute_dtype="bf16")
    np.testing.assert_allclose(np.asarray(got16), np.asarray(ref),
                               atol=5e-2)


def test_rffmap_fused_flag_parity():
    x, _ = _blob_problem(96)
    kp = _rbf(x)
    cfg = KE.EngineConfig(backend="rff", rank=64, seed=0)
    fmap = approx.make_feature_map(jnp.asarray(x), kp, cfg)
    plain = np.asarray(fmap.transform(jnp.asarray(x)))
    fmap.fused = True   # force the Pallas path (interpreter on CPU)
    fused = np.asarray(fmap.transform(jnp.asarray(x)))
    np.testing.assert_allclose(fused, plain, atol=1e-5)


# ------------------------------------------------------------ engine facade
def test_make_engine_lowrank_backend():
    x, _ = _blob_problem(80)
    kp = _rbf(x)
    eng = KE.make_engine(jnp.asarray(x), kp,
                         KE.EngineConfig(backend="nystrom", rank=24))
    assert isinstance(eng, approx.LowRankKernelEngine)
    assert eng.rank == 24
    row = np.asarray(eng.row(3)[0])
    blk = np.asarray(eng.block(jnp.arange(5), jnp.arange(80)))
    np.testing.assert_allclose(blk[3], row[:80], atol=1e-5)
    v = jnp.ones((80,), jnp.float32)
    np.testing.assert_allclose(np.asarray(eng.matvec(v)),
                               np.asarray(eng.full() @ v), rtol=1e-4,
                               atol=1e-4)


def test_lowrank_full_respects_dense_limit():
    x = jnp.zeros((64, 3), jnp.float32)
    kp = K.KernelParams(name="rbf", gamma=0.5)
    eng = KE.make_engine(x, kp, KE.EngineConfig(backend="rff", rank=8,
                                                dense_limit=32))
    with pytest.raises(RuntimeError, match="dense_limit"):
        eng.full()


def test_unknown_backend_error_lists_lowrank():
    with pytest.raises(ValueError, match="nystrom"):
        KE.make_engine(jnp.zeros((4, 2), jnp.float32),
                       K.KernelParams(name="rbf", gamma=0.5),
                       KE.EngineConfig(backend="bogus"))


# ------------------------------------------------------------- model paths
@pytest.mark.parametrize("engine", ["nystrom", "rff"])
def test_svc_lowrank_matches_exact_accuracy(engine):
    x, y = _blob_problem(400, seed=5)
    xtr, ytr, xte, yte = x[:300], y[:300], x[300:], y[300:]
    exact = SVC(engine="dense").fit(xtr, ytr)
    clf = SVC(engine=engine, rank=128).fit(xtr, ytr)
    assert clf.converged_
    acc_e = exact.score(xte, yte)
    acc_a = float(np.mean(
        clf.classes_[(clf._decision_function_engine(xte) > 0)
                     .astype(np.int64)] == yte))
    assert acc_a >= acc_e - 0.02, (acc_a, acc_e)


@pytest.mark.parametrize("engine", ["nystrom", "rff"])
def test_svr_lowrank_close_to_exact(engine):
    x, y = make_synth_regression(300, 4, kind="sinc", noise=0.05, seed=3)
    reg_e = SVR(engine="dense", epsilon=0.1).fit(x[:220], y[:220])
    reg_a = SVR(engine=engine, rank=128, epsilon=0.1).fit(x[:220], y[:220])
    mse_e = float(np.mean((reg_e._predict_engine(x[220:]) - y[220:]) ** 2))
    mse_a = float(np.mean((reg_a._predict_engine(x[220:]) - y[220:]) ** 2))
    assert mse_a <= mse_e + 0.05, (mse_a, mse_e)


def test_svc_multiclass_lowrank():
    x, y = make_blobs(80, 4, 5, sep=4.0, seed=2)
    x = normalize(x)
    clf = SVC(engine="nystrom", rank=96).fit(x[:240], y[:240])
    assert clf.task_w_.shape == (6, clf._feature_map.rank)  # ovo: C(4,2)
    assert clf.n_support_.shape == (6,)
    acc = clf.score(x[240:], y[240:])
    assert acc >= 0.9, acc


def test_lowrank_fit_deterministic():
    x, y = _blob_problem(200, seed=7)
    a = SVC(engine="rff", rank=64, seed=11).fit(x, y)
    b = SVC(engine="rff", rank=64, seed=11).fit(x, y)
    assert np.array_equal(a.alpha_, b.alpha_)
    assert np.array_equal(a.w_, b.w_)
    c = SVC(engine="rff", rank=64, seed=12).fit(x, y)
    assert not np.array_equal(a.w_, c.w_)   # seed actually matters


def test_exact_engines_unchanged_by_lowrank_kwargs():
    """rank/landmarks/seed must be inert for classic backends — the
    pre-approx fit stays bit-identical."""
    x, y = _blob_problem(150, seed=4)
    base = SVC(engine="dense").fit(x, y)
    knob = SVC(engine="dense", rank=17, landmarks="kmeans++",
               seed=99).fit(x, y)
    assert np.array_equal(base.alpha_, knob.alpha_)
    assert base.b_ == knob.b_


# ---------------------------------------------------------------- serving
@pytest.mark.parametrize("engine", ["nystrom", "rff"])
def test_lowrank_serving_roundtrip(engine):
    x, y = _blob_problem(300, seed=6)
    clf = SVC(engine=engine, rank=64).fit(x[:220], y[:220])
    ref = clf._decision_function_engine(x[220:])
    np.testing.assert_allclose(clf.decision_function(x[220:]), ref,
                               atol=1e-5)
    packed = serve.pack(clf)
    assert packed.feature_map is not None
    assert packed.buckets == ()
    assert packed.linear_w.shape == (1, 64)
    buf = io.BytesIO()
    serve.save(buf, packed)
    buf.seek(0)
    loaded = serve.load(buf)
    assert loaded.feature_map.kind == engine
    pred = serve.Predictor(loaded)
    np.testing.assert_allclose(pred.decision_function(x[220:]), ref,
                               atol=1e-5)
    assert (pred.predict(x[220:]) == clf.predict(x[220:])).all()


def test_lowrank_svr_serving_roundtrip(tmp_path):
    x, y = make_synth_regression(260, 4, kind="sinc", noise=0.05, seed=8)
    reg = SVR(engine="nystrom", rank=48, epsilon=0.1).fit(x[:200], y[:200])
    ref = reg._predict_engine(x[200:])
    path = tmp_path / "lowrank.npz"
    serve.save(path, serve.pack(reg))
    pred = serve.Predictor(serve.load(path))
    np.testing.assert_allclose(pred.predict(x[200:]), ref, atol=1e-5)


def test_lowrank_multiclass_serving_matches_engine():
    x, y = make_blobs(70, 3, 5, sep=4.0, seed=3)
    x = normalize(x)
    clf = SVC(engine="rff", rank=96).fit(x[:150], y[:150])
    ref = clf._decision_function_engine(x[150:])
    buf = io.BytesIO()
    serve.save(buf, serve.pack(clf))
    buf.seek(0)
    pred = serve.Predictor(serve.load(buf))
    np.testing.assert_allclose(pred.decision_function(x[150:]), ref,
                               atol=1e-5)
    assert (pred.predict(x[150:]) == clf.predict(x[150:])).all()


def test_classic_pack_still_writes_version_1():
    import json
    x, y = _blob_problem(100)
    clf = SVC(engine="dense").fit(x, y)
    buf = io.BytesIO()
    serve.save(buf, serve.pack(clf))
    buf.seek(0)
    with np.load(buf) as z:
        meta = json.loads(str(z["meta"]))
    assert meta["version"] == 1
    assert "feature_map" not in meta


def test_lowrank_pack_validation():
    fm = serve.LowRankMap(kind="rff", a=np.zeros((3, 4), np.float32),
                          b=np.zeros((4,), np.float32))
    with pytest.raises(ValueError, match="linear_w"):
        serve.PackedModel(kind="svc",
                          kernel=K.KernelParams(name="rbf", gamma=0.5),
                          n_features=3, n_tasks=1, buckets=(),
                          feature_map=fm)


# ------------------------------------------------------------ linear solver
def test_dcd_matches_smo_on_explicit_features():
    """On the SAME low-rank kernel, the DCD optimum and the exact-SMO
    optimum agree (two solvers, one QP)."""
    x, y = _blob_problem(140, seed=9)
    yy = np.where(y == 1, 1.0, -1.0).astype(np.float32)
    kp = _rbf(x)
    cfg = KE.EngineConfig(backend="nystrom", rank=64)
    fmap = approx.make_feature_map(jnp.asarray(x), kp, cfg)
    phi = fmap.transform(jnp.asarray(x))
    r = linear.linear_svc(phi, jnp.asarray(yy),
                          cfg=linear.DCDConfig(C=1.0, tol=1e-4))
    assert bool(r.converged)
    # SMO solves the SAME QP but with an equality constraint / free bias;
    # decisions (not raw alphas) are the comparable quantity
    r_smo = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy),
                           cfg=smo.SMOConfig(C=1.0, tol=1e-4), kernel=kp,
                           engine=cfg)
    df_dcd = np.asarray(phi @ r.w + r.b)
    df_smo = np.asarray(
        phi @ (phi.T @ (jnp.asarray(yy) * r_smo.alpha)) + r_smo.b)
    agree = np.mean((df_dcd > 0) == (df_smo > 0))
    assert agree >= 0.98, agree


def test_dcd_mask_freezes_coordinates():
    rng = np.random.default_rng(0)
    phi = jnp.asarray(rng.normal(size=(60, 8)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=60)).astype(np.float32))
    mask = np.ones(60, bool)
    mask[40:] = False
    r = linear.linear_svc(phi, y, cfg=linear.DCDConfig(),
                          mask=jnp.asarray(mask))
    assert np.all(np.asarray(r.alpha)[40:] == 0.0)


def test_dcd_warm_start_from_optimum_converges_immediately():
    """Feeding the solved betas back as alpha0 must re-certify in one
    epoch (the cascade's warm-started feedback rounds rely on this)."""
    rng = np.random.default_rng(3)
    phi = jnp.asarray(rng.normal(size=(80, 12)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=80)).astype(np.float32))
    cold = linear.linear_svc(phi, y, cfg=linear.DCDConfig(tol=1e-4))
    assert bool(cold.converged)
    warm = linear.linear_svc(phi, y, cfg=linear.DCDConfig(tol=1e-4),
                             alpha0=cold.alpha)
    assert bool(warm.converged) and int(warm.n_iter) == 1
    # the certifying epoch still nudges free coordinates by their
    # (tol-scale) Newton steps — equality only holds to that scale
    np.testing.assert_allclose(np.asarray(warm.alpha),
                               np.asarray(cold.alpha), atol=1e-4)


def test_max_iter_bounds_lowrank_epochs():
    """Regression: SVC/SVR used to build DCDConfig without threading
    ``max_iter`` into ``max_epochs``, so the knob was silently ignored
    on the low-rank path."""
    x, y = _blob_problem(160, seed=5)
    clf = SVC(engine="nystrom", rank=32, max_iter=2)
    assert clf.dcd_cfg.max_epochs == 2
    clf.fit(x, y)
    assert clf.n_iter_ == 2 and not clf.converged_
    free = SVC(engine="nystrom", rank=32).fit(x, y)
    assert free.converged_ and free.n_iter_ > 2

    xr, yr = make_synth_regression(150, 5, seed=5)
    reg = SVR(engine="rff", rank=32, max_iter=1)
    assert reg.dcd_cfg.max_epochs == 1
    reg.fit(normalize(xr), yr)
    assert reg.n_iter_ == 1 and not reg.converged_


def test_lowrank_multiclass_single_transform_bit_identical():
    """Regression: the multiclass low-rank path used to re-run
    ``fmap.transform`` per task on overlapping row subsets; it now
    transforms the full X once and gathers rows via ``task.indices`` —
    the task weights must be bit-identical to the per-task transforms."""
    x, y = make_blobs(50, 4, 5, sep=3.0, seed=11)
    x = normalize(x)
    clf = SVC(engine="nystrom", rank=32, gamma=0.5).fit(x, y)
    fmap = clf._feature_map
    fit = linear.fit_linear_svc(clf.dcd_cfg)
    for t, task in enumerate(clf._taskset.tasks):
        assert task.indices is not None
        np.testing.assert_array_equal(x[task.indices], task.x)
        r = fit(fmap.transform(jnp.asarray(task.x)), jnp.asarray(task.y))
        np.testing.assert_array_equal(clf.task_w_[t], np.asarray(r.w))
        assert clf.task_b_[t] == float(r.b)


# ------------------------------------------------------- hypothesis property
def test_rff_error_property():
    pytest.importorskip(
        "hypothesis",
        reason="optional dev dependency (see requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    x, _ = _blob_problem(100)
    kp = _rbf(x)
    exact = np.asarray(K.make_gram_fn(kp)(jnp.asarray(x),
                                          jnp.asarray(x)))

    @settings(deadline=None, max_examples=8)
    @given(seed=st.integers(min_value=0, max_value=2 ** 16))
    def check(seed):
        errs = []
        for rank in (16, 1024):
            cfg = KE.EngineConfig(backend="rff", rank=rank, seed=seed)
            phi = approx.make_feature_map(
                jnp.asarray(x), kp, cfg).transform(jnp.asarray(x))
            errs.append(float(np.mean(np.abs(np.asarray(phi @ phi.T)
                                             - exact))))
        # 64x more features => ~8x lower MC error; demand at least 2x
        assert errs[1] < errs[0] / 2, (seed, errs)

    check()


# ------------------------------------------------------------ bounded memory
@pytest.mark.slow
def test_lowrank_large_n_bounded_memory():
    """n = 131072 trains under both approx engines with O(n * rank)
    state — the dense Gram would be 64 GiB. Epochs are capped (this is
    a feasibility pin, not a convergence test); accuracy on blobs must
    still beat a coin flip by a wide margin."""
    n = 131072
    x, y = make_blobs(n // 2, 2, 8, sep=4.0, seed=7)
    x = normalize(x)
    for engine in ("nystrom", "rff"):
        clf = SVC(engine=engine, rank=64)
        clf.dcd_cfg = linear.DCDConfig(C=1.0, tol=1e-3, max_epochs=3)
        clf.fit(x, y)
        assert clf._feature_map.rank == 64
        assert clf.alpha_.shape == (n,)
        acc = float(np.mean(
            clf.classes_[(clf._decision_function_engine(x[:4096]) > 0)
                         .astype(np.int64)] == y[:4096]))
        assert acc >= 0.75, (engine, acc)
