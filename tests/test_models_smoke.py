"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family runs one forward + one train step on CPU with
correct output shapes and no NaNs; decode paths covered too."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_NAMES, get_config, reduced
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.training.train import make_train_step


def _batch(cfg, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = 0.1 * jnp.ones(
            (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "audio":
        batch["frames"] = 0.1 * jnp.ones(
            (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    # spec tree structure mirrors the param tree
    jax.tree.map(lambda p, s: None, params, specs,
                 is_leaf=lambda x: isinstance(x, tuple)
                 and not isinstance(x, dict))
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert np.isfinite(float(aux))

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    p2, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b2)))
                for a, b2 in zip(jax.tree.leaves(params),
                                 jax.tree.leaves(p2)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    b, s, max_len = 2, 16, 32
    batch = _batch(cfg, b, s)
    batch.pop("labels")
    caches = model.cache_init(b, max_len)
    logits, caches = jax.jit(model.prefill)(params, batch, caches)
    assert logits.shape == (b, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    dec = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, caches = dec(params, tok, caches)
        assert not bool(jnp.any(jnp.isnan(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert int(tok.max()) < cfg.vocab_size  # pad-vocab ids masked


@pytest.mark.parametrize("arch", ["phi4_mini_3p8b", "mamba2_780m",
                                  "minicpm3_4b", "zamba2_1p2b",
                                  "gemma3_12b"])
def test_decode_matches_teacher_forced_forward(arch):
    """prefill(t[:k]) + decode(t[k:]) must reproduce forward(t) logits at
    every decoded position (KV-cache correctness)."""
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    b, s, k = 1, 12, 6
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
    full_logits, _ = jax.jit(model.forward)(
        params, {"tokens": jnp.asarray(toks)})
    caches = model.cache_init(b, s + 4)
    lg, caches = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks[:, :k])}, caches)
    got = [np.asarray(lg)]
    dec = jax.jit(model.decode_step)
    for t in range(k, s):
        lg, caches = dec(params, jnp.asarray(toks[:, t]), caches)
        got.append(np.asarray(lg))
    want = np.asarray(full_logits[0, k - 1:s]).astype(np.float32)
    got = np.concatenate(got, 0).astype(np.float32)[:len(want)]
    # bf16 compute: compare softmax-normalized logits loosely + argmax
    w = want - want.max(-1, keepdims=True)
    g = got - got.max(-1, keepdims=True)
    np.testing.assert_allclose(g, w, atol=0.15)
    assert (np.argmax(got, -1) == np.argmax(want, -1)).mean() >= 0.8


def test_param_counts_match_targets():
    """Full configs should land near the advertised sizes."""
    targets = {
        "phi4_mini_3p8b": (3.8e9, 0.35),
        "gemma3_12b": (12e9, 0.35),
        "deepseek_67b": (67e9, 0.15),
        "mamba2_780m": (780e6, 0.35),
        "minicpm3_4b": (4e9, 0.45),
        "deepseek_moe_16b": (16.4e9, 0.30),
        "qwen2_moe_a2p7b": (14.3e9, 0.40),  # total (A2.7b = active)
        "zamba2_1p2b": (1.2e9, 0.40),
        "whisper_medium": (760e6, 0.45),
        "phi3_vision_4p2b": (3.8e9, 0.35),  # LM backbone (vision stubbed)
    }
    for arch, (target, tol) in targets.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_moe_active_params_below_total():
    cfg = get_config("deepseek_moe_16b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
