"""§Perf optimization knobs must preserve model semantics (the hillclimb
rule: never trade correctness for a term)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, reduced
from repro.models import runtime as RT
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.training.train import make_train_step


@pytest.fixture(autouse=True)
def _reset_flags():
    yield
    RT.set_flags(scores_bf16=False, remat_policy="full",
                 chunked_threshold=8192, embed_onehot=False,
                 moe_grouped=False, microbatches=1, window_cache_sp=False,
                 gather_weights=False, moe_xe_shard=False)
    RT.set_unroll(False)


def _logits(arch="phi4_mini_3p8b", seed=0, b=2, s=32):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    toks = jnp.asarray(np.random.default_rng(seed).integers(
        0, cfg.vocab_size, (b, s)), jnp.int32)
    lg, _ = jax.jit(model.forward)(params, {"tokens": toks})
    return np.asarray(lg, np.float32)


def test_scores_bf16_close():
    base = _logits()
    RT.set_flags(scores_bf16=True)
    opt = _logits()
    assert np.abs(base - opt).max() < 0.5


def test_chunked_attention_close():
    base = _logits()
    RT.set_flags(chunked_threshold=16)
    opt = _logits()
    assert np.abs(base - opt).max() < 0.5


def test_embed_onehot_exact_dtype_tolerance():
    base = _logits(arch="gemma3_12b")
    RT.set_flags(embed_onehot=True)
    opt = _logits(arch="gemma3_12b")
    assert np.abs(base - opt).max() < 0.05


def test_moe_grouped_close():
    base = _logits(arch="qwen2_moe_a2p7b")
    RT.set_flags(moe_grouped=True)
    opt = _logits(arch="qwen2_moe_a2p7b")
    # capacity boundaries differ per group -> a few tokens may drop
    assert np.abs(base - opt).mean() < 0.05


def test_microbatched_train_step_matches_full_batch():
    cfg = reduced(get_config("phi4_mini_3p8b"))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    batch = {
        "tokens": jnp.asarray(np.random.default_rng(1).integers(
            0, cfg.vocab_size, (4, 16)), jnp.int32),
        "labels": jnp.asarray(np.random.default_rng(2).integers(
            0, cfg.vocab_size, (4, 16)), jnp.int32),
    }
    p1, _, m1 = jax.jit(make_train_step(model, opt))(
        params, opt.init(params), batch)
    RT.set_flags(microbatches=4)
    p2, _, m2 = jax.jit(make_train_step(model, opt))(
        params, opt.init(params), batch)
    # microbatch-mean loss == full-batch loss (same tokens)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2
    # updated params close (grad averaging == full-batch grad)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3


def test_window_cache_sp_decode_consistency():
    RT.set_flags(window_cache_sp=True)
    cfg = reduced(get_config("gemma3_12b"))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    toks = np.random.default_rng(3).integers(0, cfg.vocab_size,
                                             (1, 12)).astype(np.int32)
    full, _ = jax.jit(model.forward)(params, {"tokens": jnp.asarray(toks)})
    caches = model.cache_init(1, 16)
    lg, caches = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks[:, :6])}, caches)
    outs = [np.asarray(lg)]
    dec = jax.jit(model.decode_step)
    for t in range(6, 12):
        lg, caches = dec(params, jnp.asarray(toks[:, t]), caches)
        outs.append(np.asarray(lg))
    got = np.concatenate(outs, 0).astype(np.float32)[:6]
    want = np.asarray(full[0, 5:11]).astype(np.float32)
    agree = (np.argmax(got, -1) == np.argmax(want, -1)).mean()
    assert agree >= 0.8


def test_unroll_scan_equivalence():
    cfg = reduced(get_config("mamba2_780m"))
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(4))
    toks = jnp.asarray(np.random.default_rng(4).integers(
        0, cfg.vocab_size, (1, 32)), jnp.int32)
    a, _ = model.forward(params, {"tokens": toks})
    RT.set_unroll(True)
    b, _ = model.forward(params, {"tokens": toks})
    RT.set_unroll(False)
    # scan vs unrolled changes bf16 fusion/reassociation order
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=0.05)
