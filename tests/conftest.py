"""Shared test environment: multi-device host CPU, set up BEFORE jax.

``--xla_force_host_platform_device_count`` only takes effect if it is in
the environment before jax initializes its backends; setting it from an
individual test module is order-dependent (a silent no-op whenever any
earlier test touched jax first). This conftest is imported before every
test module, so the flag lands exactly once, process-wide:

* the suite runs on ``REPRO_TEST_DEVICES`` (default 8) forced host CPU
  devices — multi-device code paths (shard_map task distribution, the
  sharded single-problem SMO, dry-run meshes) are exercised in-process
  on every run, no subprocess respawn needed;
* tests that NEED a minimum device count declare it with
  ``@pytest.mark.requires_devices(n)`` and are skipped (not failed)
  when the host provides fewer;
* the ``mesh_devices`` fixture hands back the visible device list.
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

_N_DEVICES = int(os.environ.get("REPRO_TEST_DEVICES", "8"))
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count={_N_DEVICES}"
    ).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402  (env must be set before anything imports jax)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "requires_devices(n): skip unless at least n jax devices are "
        "visible (forced host CPU devices count)")


def pytest_runtest_setup(item):
    marker = item.get_closest_marker("requires_devices")
    if marker is None:
        return
    need = int(marker.args[0])
    import jax  # deferred: first jax import locks the device count
    have = jax.device_count()
    if have < need:
        pytest.skip(f"needs {need} devices, only {have} visible")


@pytest.fixture
def mesh_devices():
    """The visible device list (jax initialized under the forced count)."""
    import jax
    return jax.devices()


@pytest.fixture
def compile_guard():
    """The runtime recompile budget (repro.analysis.compile_guard).

    Usage::

        def test_replay(compile_guard):
            with compile_guard(budget=2, note="decode replay"):
                svc.predict(...)   # > 2 XLA compiles -> test fails

    Returned as a factory so each test declares its own budget; the
    guard raises ``CompileBudgetExceeded`` (an AssertionError) when the
    guarded region compiles more programs than declared — the runtime
    backstop for the shape-keyed leaks rule R001 cannot see statically.
    """
    from repro.analysis.compile_guard import CompileGuard
    return CompileGuard
