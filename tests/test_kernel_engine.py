"""KernelEngine: backend equivalence, LRU row cache, adaptive shrinking,
SV-compacted serving, and the large-n chunked training regression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import kernel_engine as KE
from repro.core import kernels as K, smo
from repro.core.svm import SVC
from repro.data import load_iris, make_blobs, normalize


def _small_problem(n_per=48, d=6, seed=3):
    x, y = make_blobs(n_per, 2, d, sep=1.5, seed=seed)
    yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
    return normalize(x), yy


def _engines(x, kp, slots=8):
    cfg = KE.EngineConfig(cache_slots=slots, chunk=64, dense_limit=4096)
    return {
        "dense": KE.DenseKernelEngine(x, kp, cfg),
        "chunked": KE.ChunkedKernelEngine(x, kp, cfg),
        "pallas": KE.PallasKernelEngine(x, kp, cfg),
    }


class TestBackendEquivalence:
    """dense / chunked / pallas must expose the SAME Gram through every
    interface method."""

    def test_all_methods_agree(self):
        x, _ = _small_problem()
        xj = jnp.asarray(x)
        kp = K.resolve_gamma(K.KernelParams(), xj)
        engines = _engines(xj, kp)
        ref = np.asarray(engines["dense"].full())
        rows = jnp.asarray([3, 17, 40])
        cols = jnp.asarray([0, 9, 55, 80])
        zt = xj[:13] * 1.1  # off-training-grid test block
        coef = jnp.asarray(np.random.default_rng(0).normal(
            size=(x.shape[0],)).astype(np.float32))
        for name, eng in engines.items():
            tol = dict(rtol=3e-5, atol=3e-5)
            np.testing.assert_allclose(np.asarray(eng.full()), ref,
                                       err_msg=name, **tol)
            np.testing.assert_allclose(np.asarray(eng.diag()),
                                       np.diag(ref), err_msg=name, **tol)
            r, _ = eng.row(jnp.int32(7), None)
            np.testing.assert_allclose(np.asarray(r), ref[7],
                                       err_msg=name, **tol)
            np.testing.assert_allclose(
                np.asarray(eng.block(rows, cols)),
                ref[np.asarray(rows)][:, np.asarray(cols)],
                err_msg=name, **tol)
            np.testing.assert_allclose(np.asarray(eng.matvec(coef)),
                                       ref @ np.asarray(coef),
                                       err_msg=name, rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(
                np.asarray(eng.cross(zt)),
                np.asarray(engines["dense"].cross(zt)),
                err_msg=name, **tol)
            np.testing.assert_allclose(
                np.asarray(eng.decide(zt, coef, 0.25)),
                np.asarray(engines["dense"].cross(zt)) @ np.asarray(coef)
                + 0.25, err_msg=name, rtol=2e-4, atol=2e-4)

    def test_auto_backend_resolution(self):
        x, _ = _small_problem()
        xj = jnp.asarray(x)
        kp = K.KernelParams(gamma=0.5)
        small = KE.make_engine(xj, kp, KE.EngineConfig(dense_limit=1000))
        assert isinstance(small, KE.DenseKernelEngine)
        big = KE.make_engine(xj, kp, KE.EngineConfig(dense_limit=10))
        assert isinstance(big, KE.ChunkedKernelEngine)
        with pytest.raises(ValueError):
            KE.make_engine(xj, kp, "no_such_backend")

    def test_chunked_full_guard(self):
        """The chunked backend must REFUSE to materialize (n, n) beyond
        dense_limit — that is its whole reason to exist."""
        x, _ = _small_problem()
        eng = KE.ChunkedKernelEngine(jnp.asarray(x),
                                     K.KernelParams(gamma=0.5),
                                     KE.EngineConfig(dense_limit=10))
        with pytest.raises(RuntimeError, match="refusing to materialize"):
            eng.full()


class TestRowCache:
    def test_hit_miss_and_lru_eviction(self):
        x, _ = _small_problem()
        kp = K.KernelParams(gamma=0.5)
        eng = KE.ChunkedKernelEngine(jnp.asarray(x), kp,
                                     KE.EngineConfig(cache_slots=4))
        ref = np.asarray(KE.DenseKernelEngine(jnp.asarray(x), kp).full())
        cache = eng.init_cache()
        r, cache = eng.row(jnp.int32(3), cache)      # miss
        np.testing.assert_allclose(np.asarray(r), ref[3], rtol=1e-5,
                                   atol=1e-6)
        r, cache = eng.row(jnp.int32(3), cache)      # hit
        np.testing.assert_allclose(np.asarray(r), ref[3], rtol=1e-5,
                                   atol=1e-6)
        assert int(cache.hits) == 1 and int(cache.misses) == 1
        # fill the remaining 3 slots, then one more: row 3 (LRU) evicted
        for i in (10, 11, 12, 13):
            r, cache = eng.row(jnp.int32(i), cache)
            np.testing.assert_allclose(np.asarray(r), ref[i], rtol=1e-5,
                                       atol=1e-6)
        assert int(cache.misses) == 5
        assert 3 not in np.asarray(cache.keys)
        assert set(np.asarray(cache.keys)) == {10, 11, 12, 13}
        # evicted row still served correctly (recomputed, counts a miss)
        r, cache = eng.row(jnp.int32(3), cache)
        np.testing.assert_allclose(np.asarray(r), ref[3], rtol=1e-5,
                                   atol=1e-6)
        assert int(cache.misses) == 6

    def test_cache_disabled(self):
        x, _ = _small_problem()
        eng = KE.ChunkedKernelEngine(jnp.asarray(x),
                                     K.KernelParams(gamma=0.5),
                                     KE.EngineConfig(cache_slots=0))
        assert eng.init_cache() is None


class TestShrinking:
    def test_shrinking_matches_plain_on_iris(self):
        x, y = load_iris()
        x = normalize(x)
        sel = y != 2
        x = x[sel]
        yy = np.where(y[sel] == 0, 1.0, -1.0).astype(np.float32)
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        r0 = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp)
        r1 = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp,
                            engine="chunked",
                            cfg=smo.SMOConfig(shrink_every=2))
        assert bool(r1.converged)
        np.testing.assert_allclose(np.asarray(r0.alpha),
                                   np.asarray(r1.alpha), rtol=1e-3,
                                   atol=1e-4)
        assert abs(float(r0.b) - float(r1.b)) < 1e-2

    def test_unshrunk_kkt_recheck_gates_convergence(self):
        """An aggressive shrink schedule must still only report
        convergence after the FULL (un-shrunk) KKT check passes."""
        x, y = make_blobs(150, 2, 10, sep=0.8, seed=3)
        yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
        x = normalize(x)
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        r = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp,
                           engine="chunked",
                           cfg=smo.SMOConfig(shrink_every=1,
                                             shrink_slack=0.0))
        assert bool(r.converged)
        # reported gap comes from the final un-shrunk selection
        assert float(r.gap) <= 2.1e-3


class TestDenseChunkedAgreement:
    """ISSUE 1 acceptance: chunked+shrinking agrees with the dense engine
    on n <= 2k — same support set, |b| diff < 1e-2, equal accuracy."""

    def test_n2048_same_solution(self):
        x, y = make_blobs(1024, 2, 8, sep=2.5, seed=11)
        yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
        x = normalize(x)
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        xj, yj = jnp.asarray(x), jnp.asarray(yy)
        rd = jax.jit(lambda a, b: smo.binary_smo(
            a, b, cfg=smo.SMOConfig(), kernel=kp, engine="dense"))(xj, yj)
        rc = jax.jit(lambda a, b: smo.binary_smo(
            a, b, cfg=smo.SMOConfig(shrink_every=4), kernel=kp,
            engine=KE.EngineConfig(backend="chunked", cache_slots=16)))(
                xj, yj)
        assert bool(rd.converged) and bool(rc.converged)
        sv_d = np.asarray(rd.alpha) > 1e-8
        sv_c = np.asarray(rc.alpha) > 1e-8
        assert (sv_d == sv_c).all(), "support sets differ"
        assert abs(float(rd.b) - float(rc.b)) < 1e-2
        eng = KE.make_engine(xj, kp, "chunked")
        acc_d = np.mean(np.sign(np.asarray(eng.decide(
            xj, jnp.asarray(np.asarray(rd.alpha) * yy), rd.b))) == yy)
        acc_c = np.mean(np.sign(np.asarray(eng.decide(
            xj, jnp.asarray(np.asarray(rc.alpha) * yy), rc.b))) == yy)
        assert acc_d == acc_c


class TestLargeN:
    """ISSUE 1 acceptance: n = 16,384 RBF training with the chunked +
    shrinking engine, never materializing the (n, n) Gram (the engine
    would raise if asked; 16384^2 floats = 1 GiB the dense path needs)."""

    def test_n16384_trains_without_full_gram(self):
        n_per = 8192
        x, y = make_blobs(n_per, 2, 8, sep=4.0, seed=7)
        yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
        x = normalize(x)
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        ecfg = KE.EngineConfig(backend="chunked", cache_slots=16,
                               chunk=2048)
        cfg = smo.SMOConfig(max_iter=30_000, shrink_every=4,
                            selection="second")
        r = jax.jit(lambda a, b: smo.binary_smo(
            a, b, cfg=cfg, kernel=kp, engine=ecfg))(
                jnp.asarray(x), jnp.asarray(yy))
        assert bool(r.converged), f"gap={float(r.gap)}"
        alpha = np.asarray(r.alpha)
        assert alpha.min() >= 0.0 and alpha.max() <= 1.0 + 1e-6
        assert abs(float(np.sum(alpha * yy))) < 1e-2
        # the engine refuses the (n, n) materialization outright
        eng = KE.make_engine(jnp.asarray(x), kp, ecfg)
        with pytest.raises(RuntimeError, match="refusing to materialize"):
            eng.full()
        # chunked serving on a subsample: the trained margin classifies
        sub = np.random.default_rng(0).choice(len(yy), 1024, replace=False)
        df = np.asarray(eng.decide(jnp.asarray(x[sub]),
                                   jnp.asarray(alpha * yy), r.b))
        assert np.mean(np.sign(df) == yy[sub]) >= 0.99


class TestDeprecationShims:
    """Old gram= / row_fn= / use_pallas plumbing resolves to engines and
    keeps producing the same solutions."""

    def test_gram_kwarg(self):
        x, yy = _small_problem()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        g = K.make_gram_fn(kp)(jnp.asarray(x), jnp.asarray(x))
        r0 = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp)
        r1 = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp,
                            gram=g)
        np.testing.assert_allclose(np.asarray(r0.alpha),
                                   np.asarray(r1.alpha), rtol=1e-5,
                                   atol=1e-6)

    def test_row_fn_kwarg(self):
        x, yy = _small_problem()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        gram_fn = K.make_gram_fn(kp)
        row_fn = lambda xs, z: gram_fn(xs, z[None, :])[:, 0]
        r0 = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp,
                            cfg=smo.SMOConfig(precompute_gram=False))
        r1 = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp,
                            row_fn=row_fn)
        np.testing.assert_allclose(np.asarray(r0.alpha),
                                   np.asarray(r1.alpha), rtol=1e-5,
                                   atol=1e-6)


class TestCompactedServing:
    def test_binary_svc_serves_from_support_vectors_only(self):
        x, y = load_iris()
        x = normalize(x)
        sel = y != 2
        clf = SVC(solver="smo").fit(x[sel], y[sel])
        assert clf.n_support_ == len(clf.support_)
        assert clf.support_vectors_.shape == (clf.n_support_, x.shape[1])
        assert 0 < clf.n_support_ < sel.sum()  # actually compacted
        # compacted decision == full-training-set decision (sklearn
        # orientation: classes_[1] == class 1 encodes as +1)
        yy = np.where(y[sel] == 1, 1.0, -1.0).astype(np.float32)
        full = smo.decision_function(
            jnp.asarray(x[sel]), jnp.asarray(yy),
            jnp.asarray(clf.alpha_), clf.b_, jnp.asarray(x[sel]),
            kernel=clf.kernel_params)
        np.testing.assert_allclose(clf.decision_function(x[sel]),
                                   np.asarray(full), rtol=1e-4, atol=1e-4)
        assert clf.score(x[sel], y[sel]) == 1.0

    def test_multiclass_svc_compacts_per_task(self):
        x, y = load_iris()
        x = normalize(x)
        clf = SVC(solver="smo").fit(x, y)
        n_task = int(clf._taskset.sizes.max())
        for g in clf._serving_buckets:
            # strictly fewer rows served than trained, per bucket
            assert g.sv_x.shape[1] < n_task
            # bucket width covers its members' SV counts
            assert g.sv_x.shape[1] >= clf.n_support_[g.task_ids].max()
        served = np.concatenate([g.task_ids
                                 for g in clf._serving_buckets])
        assert sorted(served.tolist()) == list(range(clf._taskset.n_tasks))
        assert clf.score(x, y) >= 0.96

    def test_svc_chunked_engine_end_to_end(self):
        x, y = load_iris()
        x = normalize(x)
        ref = SVC(solver="smo").fit(x, y)
        chk = SVC(solver="smo", engine="chunked", shrink_every=4).fit(x, y)
        assert chk.score(x, y) == ref.score(x, y)
