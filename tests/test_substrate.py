"""Substrate tests: optimizer, checkpoint, data pipeline, loss, roofline
parsing utilities."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ckpt as CK
from repro.data import (load_breast_cancer_like, load_iris,
                        load_pavia_like, normalize, train_test_split)
from repro.data.lm import token_batches
from repro.data.pipeline import subsample_per_class
from repro.optim.adamw import AdamW, SGD, cosine_schedule, global_norm
from repro.roofline.collect import (collective_bytes, roofline_terms)
from repro.training.train import cross_entropy


class TestOptim:
    def test_adamw_minimizes_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = jax.tree.map(lambda p: 2 * p, params)
            params, state = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_grad_clip(self):
        opt = AdamW(lr=0.0, grad_clip=1.0)
        g = {"w": jnp.asarray([1e6, 1e6])}
        assert float(global_norm(g)) > 1.0
        p, _ = opt.update(g, opt.init(g), {"w": jnp.zeros(2)})
        assert np.all(np.isfinite(np.asarray(p["w"])))

    def test_cosine_schedule(self):
        lr = cosine_schedule(peak_lr=1.0, warmup=10, total=100)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
        assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-2)

    def test_sgd(self):
        opt = SGD(lr=0.5)
        p = {"w": jnp.asarray(4.0)}
        s = opt.init(p)
        p, s = opt.update({"w": jnp.asarray(2.0)}, s, p)
        assert float(p["w"]) == 3.0


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        path = str(tmp_path / "ck.npz")
        CK.save(path, tree, step=7)
        out = CK.restore(path, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert CK.latest_step(path) == 7

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        CK.save(path, {"a": jnp.ones((2,))})
        with pytest.raises(ValueError):
            CK.restore(path, {"a": jnp.ones((3,))})


class TestData:
    def test_iris_shape(self):
        x, y = load_iris()
        assert x.shape == (150, 4) and len(np.unique(y)) == 3
        assert all((y == c).sum() == 50 for c in range(3))

    def test_pavia_like(self):
        x, y = load_pavia_like(n_per_class=20)
        assert x.shape == (180, 102) and len(np.unique(y)) == 9

    def test_cancer_like(self):
        x, y = load_breast_cancer_like()
        assert x.shape == (569, 32) and len(np.unique(y)) == 2

    def test_normalize(self):
        x, _ = load_iris()
        z = normalize(x)
        np.testing.assert_allclose(z.mean(0), 0.0, atol=1e-5)
        np.testing.assert_allclose(z.std(0), 1.0, atol=1e-4)

    def test_split_disjoint(self):
        x, y = load_iris()
        xtr, ytr, xte, yte = train_test_split(x, y, test_frac=0.2)
        assert len(ytr) + len(yte) == 150 and len(yte) == 30

    def test_subsample_per_class(self):
        x, y = load_pavia_like(n_per_class=50)
        xs, ys = subsample_per_class(x, y, 10)
        assert all((ys == c).sum() == 10 for c in np.unique(y))

    def test_token_batches_learnable_structure(self):
        bs = list(token_batches(vocab_size=64, batch=2, seq_len=32,
                                n_batches=3, seed=0))
        assert len(bs) == 3
        assert bs[0]["tokens"].shape == (2, 32)
        # shift-by-one consistency
        np.testing.assert_array_equal(bs[0]["tokens"][:, 1:],
                                      bs[0]["labels"][:, :-1])


class TestLoss:
    def test_cross_entropy_uniform(self):
        v = 16
        logits = jnp.zeros((2, 3, v))
        labels = jnp.zeros((2, 3), jnp.int32)
        assert float(cross_entropy(logits, labels)) == pytest.approx(
            np.log(v), abs=1e-5)

    def test_cross_entropy_mask(self):
        logits = jnp.zeros((1, 4, 8))
        labels = jnp.zeros((1, 4), jnp.int32)
        mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
        assert float(cross_entropy(logits, labels, mask=mask)) == \
            pytest.approx(np.log(8), abs=1e-5)


class TestRooflineParsing:
    HLO = """
  %ag = bf16[8,128] all-gather(%x), replica_groups=[2,16]<=[32]
  %ar = f32[1024] all-reduce(%y), channel_id=1
  %rs = f32[64,32] reduce-scatter(%z), channel_id=2
  %cp = bf16[16] collective-permute(%w)
  %a2a = (f32[8], f32[8]) all-to-all(%u, %v)
"""

    def test_collective_bytes(self):
        out = collective_bytes(self.HLO)
        pk = out["per_kind_bytes"]
        assert pk["all-gather"] == 8 * 128 * 2
        assert pk["all-reduce"] == 1024 * 4
        assert pk["reduce-scatter"] == 64 * 32 * 4
        assert pk["collective-permute"] == 16 * 2
        assert pk["all-to-all"] == 2 * 8 * 4
        assert out["total_bytes"] == sum(pk.values())

    def test_roofline_dominance(self):
        t = roofline_terms(flops=197e12, hbm_bytes=1.0,
                           collective_bytes_total=1.0)
        assert t["dominant"] == "compute"
        assert t["t_compute_s"] == pytest.approx(1.0)
        t = roofline_terms(flops=1.0, hbm_bytes=819e9,
                           collective_bytes_total=1.0)
        assert t["dominant"] == "memory"
        t = roofline_terms(flops=1.0, hbm_bytes=1.0,
                           collective_bytes_total=200e9)
        assert t["dominant"] == "collective"
