"""Cascade SVM: equivalence, certificates, and routing.

The load-bearing claims:

* A single-shard cascade IS the unsharded solver: identical jit body,
  cold start, no merges — alphas / b / (SVR) raw duals reproduce the
  plain ``SVC``/``SVR`` fit bit for bit, on the exact AND low-rank
  paths.
* A sharded cascade (S in {2, 4}) must pass the same independently
  recomputed float64 KKT certificate, at the same tol, as the unsharded
  solver — for SVC and SVR, exact and low-rank per-shard solves. The
  certificate is recomputed here from scratch (never trusted from the
  model) with the ``test_kkt_certificate`` conventions.
* Cascades are deterministic: refits are bit-identical (round-robin
  partitions, no RNG anywhere in the reduction).
* The equality-repair projection keeps merged warm starts feasible:
  sum_i y_i a_i == 0 without leaving the box.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import approx, cascade, kernel_engine as KE, kernels as K
from repro.core import linear, smo
from repro.core.svm import SVC, SVR
from repro.data import make_blobs, make_synth_regression, normalize
from repro import serve

TOL = 1e-3


def _binary_problem(n=240, d=6, seed=0):
    x, y = make_blobs(n // 2, 2, d, sep=2.5, seed=seed)
    return normalize(x), y


def _regression_problem(n=200, seed=0):
    x, y = make_synth_regression(n, 5, noise=0.05, seed=seed)
    return normalize(x), y


# --------------------------------------------- independent f64 certificates
def _svc_violation(clf, x, y):
    kp = clf.kernel_params
    yy = np.where(np.asarray(y) == clf.classes_[1], 1.0, -1.0)
    g = np.asarray(K.make_gram_fn(kp)(jnp.asarray(x), jnp.asarray(x)),
                   np.float64)
    f = g @ (clf.alpha_.astype(np.float64) * yy) - yy
    return float(smo.kkt_violation(clf.alpha_, yy, f, 0.0,
                                   clf.smo_cfg.C))


def _svr_violation(reg, x, y):
    n = len(y)
    g = np.asarray(K.make_gram_fn(reg.kernel_params)(
        jnp.asarray(x), jnp.asarray(x)), np.float64)
    gb = g @ reg.beta_.astype(np.float64)
    y64 = np.asarray(y, np.float64)
    f = np.concatenate([gb + reg.epsilon - y64, gb - reg.epsilon - y64])
    s = np.concatenate([np.ones(n), -np.ones(n)])
    return float(smo.kkt_violation(reg.alpha_raw_, s, f, 0.0,
                                   reg.smo_cfg.C))


def _phibar(model, x):
    phi = np.asarray(model._feature_map.transform(jnp.asarray(x)),
                     np.float64)
    bias = np.full((phi.shape[0], 1), model.dcd_cfg.bias, np.float64)
    return np.concatenate([phi, bias], axis=1)


def _svc_violation_lowrank(clf, x, y):
    yy = np.where(np.asarray(y) == clf.classes_[1], 1.0, -1.0)
    pb = _phibar(clf, x)
    f = pb @ (pb.T @ (clf.alpha_.astype(np.float64) * yy)) - yy
    return float(smo.kkt_violation(clf.alpha_, yy, f, 0.0,
                                   clf.smo_cfg.C, r=0.0))


def _svr_violation_lowrank(reg, x, y):
    n = len(y)
    pb = _phibar(reg, x)
    gb = pb @ (pb.T @ reg.beta_.astype(np.float64))
    y64 = np.asarray(y, np.float64)
    f = np.concatenate([gb + reg.epsilon - y64, gb - reg.epsilon - y64])
    s = np.concatenate([np.ones(n), -np.ones(n)])
    return float(smo.kkt_violation(reg.alpha_raw_, s, f, 0.0,
                                   reg.smo_cfg.C, r=0.0))


# ------------------------------------------------- single-shard bit-identity
def test_single_shard_svc_bit_identical_to_unsharded():
    x, y = _binary_problem()
    plain = SVC(kernel="rbf", gamma=0.5).fit(x, y)
    casc = SVC(kernel="rbf", gamma=0.5, shard="cascade",
               cascade_shards=1).fit(x, y)
    np.testing.assert_array_equal(casc.alpha_, plain.alpha_)
    assert casc.b_ == plain.b_
    np.testing.assert_array_equal(casc.support_, plain.support_)
    np.testing.assert_array_equal(casc.dual_coef_, plain.dual_coef_)
    assert casc.cascade_rounds_ == 1 and casc.converged_


def test_single_shard_svr_bit_identical_to_unsharded():
    x, y = _regression_problem()
    plain = SVR(kernel="rbf", gamma=0.5).fit(x, y)
    casc = SVR(kernel="rbf", gamma=0.5, shard="cascade",
               cascade_shards=1).fit(x, y)
    np.testing.assert_array_equal(casc.beta_, plain.beta_)
    np.testing.assert_array_equal(casc.alpha_raw_, plain.alpha_raw_)
    assert casc.b_ == plain.b_ and casc.converged_


def test_single_shard_lowrank_bit_identical_to_unsharded():
    x, y = _binary_problem()
    kw = dict(engine="nystrom", rank=48, gamma=0.5, seed=3)
    plain = SVC(**kw).fit(x, y)
    casc = SVC(shard="cascade", cascade_shards=1, **kw).fit(x, y)
    np.testing.assert_array_equal(casc.alpha_, plain.alpha_)
    np.testing.assert_array_equal(casc.w_, plain.w_)
    assert casc.b_ == plain.b_


# ------------------------------------------------- certified sharded solves
@pytest.mark.parametrize("shards", [2, 4])
def test_cascade_svc_exact_certifies_at_solver_tol(shards):
    x, y = _binary_problem()
    ref = SVC(kernel="rbf", gamma=0.5, tol=TOL).fit(x, y)
    clf = SVC(kernel="rbf", gamma=0.5, tol=TOL, shard="cascade",
              cascade_shards=shards).fit(x, y)
    assert clf.converged_, clf.cascade_history_
    # the same certificate the unsharded solver passes, same tol
    assert _svc_violation(ref, x, y) <= TOL
    assert _svc_violation(clf, x, y) <= TOL
    # the certified duals describe (numerically) the same model
    assert clf.score(x, y) == pytest.approx(ref.score(x, y), abs=0.02)


@pytest.mark.parametrize("shards", [2, 4])
def test_cascade_svr_exact_certifies_at_solver_tol(shards):
    x, y = _regression_problem()
    ref = SVR(kernel="rbf", gamma=0.5, tol=TOL).fit(x, y)
    reg = SVR(kernel="rbf", gamma=0.5, tol=TOL, shard="cascade",
              cascade_shards=shards).fit(x, y)
    assert reg.converged_, reg.cascade_history_
    assert _svr_violation(ref, x, y) <= TOL
    assert _svr_violation(reg, x, y) <= TOL
    assert reg.score(x, y) == pytest.approx(ref.score(x, y), abs=0.05)


@pytest.mark.parametrize("shards", [2, 4])
@pytest.mark.parametrize("engine", ["nystrom", "rff"])
def test_cascade_svc_lowrank_certifies_at_solver_tol(engine, shards):
    x, y = _binary_problem()
    clf = SVC(engine=engine, rank=48, gamma=0.5, tol=TOL, shard="cascade",
              cascade_shards=shards).fit(x, y)
    assert clf.converged_, clf.cascade_history_
    assert _svc_violation_lowrank(clf, x, y) <= TOL


@pytest.mark.parametrize("shards", [2, 4])
def test_cascade_svr_lowrank_certifies_at_solver_tol(shards):
    x, y = _regression_problem()
    reg = SVR(engine="rff", rank=64, gamma=0.5, tol=TOL, shard="cascade",
              cascade_shards=shards).fit(x, y)
    assert reg.converged_, reg.cascade_history_
    assert _svr_violation_lowrank(reg, x, y) <= TOL


def test_cascade_multiclass_certifies_every_task():
    x, y = make_blobs(60, 3, 5, sep=2.5, seed=2)
    x = normalize(x)
    ref = SVC(kernel="rbf", gamma=0.5).fit(x, y)
    clf = SVC(kernel="rbf", gamma=0.5, shard="cascade",
              cascade_shards=2).fit(x, y)
    assert clf.converged_           # every task's certificate passed
    assert (clf.cascade_kkt_ <= TOL).all()
    assert clf.cascade_rounds_.shape == (3,)   # one cascade per OvO pair
    assert clf.score(x, y) == pytest.approx(ref.score(x, y), abs=0.02)


# -------------------------------------------------------------- determinism
def test_cascade_refit_is_deterministic():
    x, y = _binary_problem(seed=7)
    a = SVC(kernel="rbf", gamma=0.5, shard="cascade",
            cascade_shards=4).fit(x, y)
    b = SVC(kernel="rbf", gamma=0.5, shard="cascade",
            cascade_shards=4).fit(x, y)
    np.testing.assert_array_equal(a.alpha_, b.alpha_)
    assert a.b_ == b.b_
    assert a.cascade_rounds_ == b.cascade_rounds_
    assert a.cascade_kkt_ == b.cascade_kkt_


def test_cascade_refit_within_compile_budget(compile_guard):
    """An identical refit must replay entirely through the jit cache:
    shard solves, merges and the certificate pass are shape-stable, so
    a second fit over the same data compiles ZERO fresh XLA programs.
    The runtime counterpart of analysis rule R001 for the training
    path — a shape-keyed leak anywhere in the cascade (shard buckets,
    KKT reduce, repair projection) trips this immediately."""
    x, y = _binary_problem(n=120, seed=5)
    kw = dict(kernel="rbf", gamma=0.5, shard="cascade", cascade_shards=2)
    SVC(**kw).fit(x, y)                      # warm every program
    with compile_guard(budget=0, note="identical cascade refit") as g:
        SVC(**kw).fit(x, y)
    assert g.count == 0


# ----------------------------------------------------------------- serving
def test_cascade_serving_state_packs_and_serves():
    """Cascade fits produce the standard compacted serving state, so the
    pack/Predictor pipeline works unchanged and agrees with the
    reference engine path."""
    x, y = _binary_problem()
    clf = SVC(kernel="rbf", gamma=0.5, shard="cascade",
              cascade_shards=4).fit(x, y)
    xt = x[:40]
    np.testing.assert_allclose(clf.decision_function(xt),
                               clf._decision_function_engine(xt),
                               rtol=1e-5, atol=1e-5)
    assert serve.pack(clf).kind == "svc"

    xm, ym = make_blobs(50, 3, 5, sep=2.5, seed=4)
    xm = normalize(xm)
    cm = SVC(kernel="rbf", gamma=0.5, shard="cascade",
             cascade_shards=2).fit(xm, ym)
    assert serve.pack(cm).kind == "svc"
    assert cm.predict(xm).shape == ym.shape


# ----------------------------------------------------------- mesh cascades
@pytest.mark.requires_devices(4)
def test_cascade_over_mesh_certifies_and_matches_local():
    """With a mesh, each cascade level's shard solves distribute
    task-parallel through fit_taskset. The worker layout changes bucket
    padding (and therefore solver trajectories), so alphas are not
    bitwise comparable — but the distributed cascade must pass the SAME
    independently recomputed certificate and describe the same model."""
    from repro.launch.mesh import make_local_mesh
    x, y = _binary_problem()
    local = SVC(kernel="rbf", gamma=0.5, shard="cascade",
                cascade_shards=4).fit(x, y)
    dist_ = SVC(kernel="rbf", gamma=0.5, shard="cascade",
                cascade_shards=4, mesh=make_local_mesh(4)).fit(x, y)
    assert dist_.converged_
    assert _svc_violation(dist_, x, y) <= TOL
    np.testing.assert_allclose(dist_.decision_function(x),
                               local.decision_function(x), atol=5e-2)
    assert dist_.score(x, y) == pytest.approx(local.score(x, y),
                                              abs=0.02)


# ------------------------------------------------------- primitive behavior
def test_repair_equality_projects_onto_constraint():
    rng = np.random.default_rng(0)
    y = np.where(rng.random(50) > 0.5, 1.0, -1.0)
    a = rng.uniform(0.0, 1.0, 50)
    fixed = cascade._repair_equality(a, y)
    assert abs(float(np.sum(y * fixed.astype(np.float64)))) < 1e-5
    assert (fixed >= 0).all() and (fixed <= a + 1e-7).all()
    # a feasible start is untouched
    bal = np.concatenate([[0.5, 0.5], np.zeros(8)])
    yb = np.concatenate([[1.0, -1.0], np.ones(8)])
    np.testing.assert_array_equal(
        cascade._repair_equality(bal, yb), bal.astype(np.float32))
    # SVR convention: y = 1 makes the constraint sum(beta) = 0
    beta = rng.normal(size=30)
    fixed = cascade._repair_equality(beta, np.ones(30))
    assert abs(float(fixed.astype(np.float64).sum())) < 1e-5


def test_partition_indices_round_robin_disjoint_cover():
    parts = cascade.partition_indices(11, 4)
    assert len(parts) == 4
    allidx = np.concatenate(parts)
    assert len(allidx) == 11 and len(np.unique(allidx)) == 11
    np.testing.assert_array_equal(parts[1], [1, 5, 9])
    # shards clamp to n
    assert len(cascade.partition_indices(3, 8)) == 3


def test_cascade_validation():
    x, y = _binary_problem(n=60)
    with pytest.raises(ValueError, match="solver='smo'"):
        SVC(solver="gd", shard="cascade").fit(x, y)
    with pytest.raises(ValueError, match="cascade_shards"):
        SVC(shard="cascade", cascade_shards=0).fit(x, y)
    with pytest.raises(ValueError, match="cascade_rounds"):
        SVR(shard="cascade", cascade_rounds=0).fit(x, y.astype(float))
    with pytest.raises(ValueError, match="shard mode"):
        SVC(shard="waterfall")
