"""Hypothesis property-based tests on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import kernels as K, ovo, smo
from repro.kernels import ops, ref
from repro.models import layers as L

SET = dict(max_examples=25, deadline=None)


# -------------------------------------------------------------- SVM core

@st.composite
def dataset(draw, max_n=60, max_d=8):
    n = draw(st.integers(8, max_n))
    d = draw(st.integers(1, max_d))
    x = draw(hnp.arrays(np.float32, (n, d),
                        elements=st.floats(-5, 5, width=32)))
    y = draw(hnp.arrays(np.int8, (n,), elements=st.sampled_from([0, 1])))
    # ensure both classes present
    y = np.asarray(y, np.int8)
    y[0], y[1] = 0, 1
    return x, np.where(y == 0, 1.0, -1.0).astype(np.float32)


@given(dataset())
@settings(**SET)
def test_smo_invariants(data):
    """For ANY dataset: solver terminates with 0 <= alpha <= C,
    sum(alpha*y) ~ 0, and alphas of duplicated-at-bounds stay in box."""
    x, y = data
    kp = K.KernelParams(gamma=0.5)
    r = smo.binary_smo(jnp.asarray(x), jnp.asarray(y),
                       cfg=smo.SMOConfig(C=1.0, max_iter=20_000),
                       kernel=kp)
    alpha = np.asarray(r.alpha)
    assert np.all(alpha >= 0.0) and np.all(alpha <= 1.0 + 1e-6)
    assert abs(float(np.sum(alpha * y))) < 1e-3
    assert np.all(np.isfinite(np.asarray(r.b)))


@given(dataset(max_n=40))
@settings(**SET)
def test_gram_psd_and_symmetric(data):
    """RBF Gram must be symmetric with diag 1 and be PSD (+eps)."""
    x, _ = data
    g = np.asarray(ref.rbf_gram(jnp.asarray(x), jnp.asarray(x), 0.3))
    np.testing.assert_allclose(g, g.T, atol=1e-5)
    np.testing.assert_allclose(np.diag(g), 1.0, atol=1e-5)
    w = np.linalg.eigvalsh(g + 1e-4 * np.eye(len(g)))
    assert w.min() > -1e-3


@given(dataset(max_n=48, max_d=6))
@settings(**SET)
def test_pallas_gram_matches_oracle(data):
    x, _ = data
    got = np.asarray(ops.rbf_gram(jnp.asarray(x), jnp.asarray(x),
                                  gamma=0.7))
    want = np.asarray(ref.rbf_gram(jnp.asarray(x), jnp.asarray(x), 0.7))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


@given(st.integers(2, 7), st.integers(3, 25))
@settings(**SET)
def test_ovo_task_count_and_coverage(m, n_per):
    """C = m(m-1)/2 tasks; every sample appears in exactly m-1 tasks."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(m * n_per, 3)).astype(np.float32)
    y = np.repeat(np.arange(m), n_per)
    tasks = ovo.build_tasks(x, y)
    assert tasks.x.shape[0] == m * (m - 1) // 2
    assert int(tasks.mask.sum()) == (m - 1) * m * n_per


# ------------------------------------------------------------ model layers

@given(st.integers(1, 8), st.integers(1, 3))
@settings(**SET)
def test_rope_preserves_norm(s, b):
    """Rotary embedding is an isometry per 2-plane."""
    rng = np.random.default_rng(s)
    x = rng.normal(size=(b, s, 2, 16)).astype(np.float32)
    pos = np.tile(np.arange(s)[None], (b, 1))
    out = L.rope(jnp.asarray(x), jnp.asarray(pos), 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(out), axis=-1),
                               np.linalg.norm(x, axis=-1), rtol=2e-4)


@given(st.integers(0, 10_000))
@settings(**SET)
def test_rmsnorm_scale_invariant_direction(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(2, 3, 8)).astype(np.float32) + 0.1
    w = np.zeros(8, np.float32)
    a = np.asarray(L.rmsnorm(jnp.asarray(x), jnp.asarray(w)))
    b = np.asarray(L.rmsnorm(jnp.asarray(3.7 * x), jnp.asarray(w)))
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
    # unit RMS out
    rms = np.sqrt((a ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, rtol=2e-2)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_softmax_attention_rows_sum_to_one_effect(seed):
    """full_attention of constant V returns that constant (weights sum 1)."""
    rng = np.random.default_rng(seed)
    b, s, h, d = 1, 6, 2, 8
    q = rng.normal(size=(b, s, h, d)).astype(np.float32)
    k = rng.normal(size=(b, s, h, d)).astype(np.float32)
    v = np.ones((b, s, h, d), np.float32) * 0.7
    out = np.asarray(L.full_attention(jnp.asarray(q), jnp.asarray(k),
                                      jnp.asarray(v)))
    np.testing.assert_allclose(out, 0.7, rtol=2e-3)


def test_moe_combine_conserves_weights():
    """Routing all-ones through identity-ish experts: the combine weights
    per token must sum to ~1 (dropless within capacity)."""
    from repro.configs.base import get_config, reduced
    from repro.models import moe as MOE
    cfg = reduced(get_config("qwen2_moe_a2p7b"))
    p, _ = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(b, s, cfg.d_model)).astype(np.float32))
    out, aux = MOE.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
