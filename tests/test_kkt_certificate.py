"""KKT-certificate harness: solver-independent optimality checks.

Every solver in the repo reports its own convergence flag from its own
bookkeeping (the f-cache it maintained during the solve). This harness
trusts none of that: it recomputes the optimality vector
``f = K @ (y * alpha) + y * p`` from scratch (dense reference Gram, the
model's stored multipliers) and asserts that ``smo.kkt_violation`` — the
smallest achievable max per-sample KKT violation over all choices of the
equality multiplier — is within the solver's tolerance. A solve that
terminated at duality gap <= 2*tol certifies at <= tol.

Covered: SVC (binary) and SVR across the full engine matrix
{dense, chunked, pallas, sharded} through the public class API.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import kernels as K, smo
from repro.core.svm import SVC, SVR
from repro.data import make_blobs, make_synth_regression, normalize
from repro.launch.mesh import make_shard_mesh

ENGINES = [
    "dense",
    "chunked",
    "pallas",
    pytest.param("sharded", marks=pytest.mark.requires_devices(4)),
]


def _svc_violation(clf: SVC, x, y) -> float:
    """Recompute f for the classification spec (p = -1, box [0, C]) and
    certify the stored alpha."""
    # sklearn orientation (PR 5): fit encodes classes_[1] as +1
    yy = np.where(y == clf.classes_[1], 1.0, -1.0).astype(np.float32)
    g = np.asarray(K.make_gram_fn(clf.kernel_params)(
        jnp.asarray(x), jnp.asarray(x)), np.float64)
    alpha = np.asarray(clf.alpha_, np.float64)
    f = g @ (alpha * yy) - yy           # y * p == -y at p = -1
    return float(smo.kkt_violation(alpha, yy, f, 0.0, clf.smo_cfg.C))


def _svr_violation(reg: SVR, x, y) -> float:
    """Recompute f for the doubled epsilon-SVR spec and certify the
    stored raw [alpha; alpha*] multipliers."""
    n = x.shape[0]
    g = np.asarray(K.make_gram_fn(reg.kernel_params)(
        jnp.asarray(x), jnp.asarray(x)), np.float64)
    g2 = np.tile(g, (2, 2))             # Gram of [x; x]
    s = np.r_[np.ones(n), -np.ones(n)]
    p = np.r_[reg.epsilon - y, reg.epsilon + y].astype(np.float64)
    a2 = np.asarray(reg.alpha_raw_, np.float64)
    f = g2 @ (a2 * s) + s * p
    return float(smo.kkt_violation(a2, s, f, 0.0, reg.smo_cfg.C))


def _engine_kwargs(backend):
    if backend == "sharded":
        return dict(mesh=make_shard_mesh(4), worker_axes=("shards",),
                    shard="data")
    return dict(engine=backend)


@pytest.mark.parametrize("backend", ENGINES)
def test_svc_kkt_certificate(backend):
    x, yc = make_blobs(90, 2, 6, sep=1.2, seed=4)
    x = normalize(x)
    clf = SVC(kernel="rbf", C=1.0, **_engine_kwargs(backend)).fit(x, yc)
    assert clf.converged_
    viol = _svc_violation(clf, x, yc)
    assert viol <= clf.smo_cfg.tol, (
        f"engine={backend}: max KKT violation {viol:.2e} exceeds "
        f"tol={clf.smo_cfg.tol}")


@pytest.mark.parametrize("backend", ENGINES)
def test_svr_kkt_certificate(backend):
    x, y = make_synth_regression(120, 4, kind="sinc", noise=0.05, seed=2)
    reg = SVR(kernel="rbf", C=1.0, epsilon=0.1,
              **_engine_kwargs(backend)).fit(x, y)
    assert reg.converged_
    viol = _svr_violation(reg, x, y)
    assert viol <= reg.smo_cfg.tol, (
        f"engine={backend}: max KKT violation {viol:.2e} exceeds "
        f"tol={reg.smo_cfg.tol}")


@pytest.mark.parametrize("shrink_every", [0, 2])
def test_certificate_with_shrinking(shrink_every):
    """Adaptive shrinking must not weaken the certificate: the un-shrunk
    re-check inside the solver is what the harness independently
    verifies here."""
    x, y = make_synth_regression(150, 3, kind="sinc", noise=0.05, seed=5)
    reg = SVR(kernel="rbf", epsilon=0.1, engine="chunked",
              shrink_every=shrink_every).fit(x, y)
    assert _svr_violation(reg, x, y) <= reg.smo_cfg.tol


def test_violation_detects_nonoptimal_points():
    """The certificate is not vacuous: a perturbed or zero alpha on a
    non-trivial problem must show a violation well above tol."""
    x, y = make_synth_regression(80, 3, kind="sinc", noise=0.05, seed=6)
    reg = SVR(kernel="rbf", epsilon=0.05).fit(x, y)
    n = x.shape[0]
    g2 = np.tile(np.asarray(K.make_gram_fn(reg.kernel_params)(
        jnp.asarray(x), jnp.asarray(x)), np.float64), (2, 2))
    s = np.r_[np.ones(n), -np.ones(n)]
    p = np.r_[reg.epsilon - y, reg.epsilon + y].astype(np.float64)
    a0 = np.zeros(2 * n)                # alpha = 0 is not optimal here
    f0 = g2 @ (a0 * s) + s * p
    assert float(smo.kkt_violation(a0, s, f0, 0.0, 1.0)) > 10 * reg.smo_cfg.tol
