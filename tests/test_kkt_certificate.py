"""KKT-certificate harness: solver-independent optimality checks.

Every solver in the repo reports its own convergence flag from its own
bookkeeping (the f-cache it maintained during the solve). This harness
trusts none of that: it recomputes the optimality vector
``f = K @ (y * alpha) + y * p`` from scratch (dense reference Gram, the
model's stored multipliers) and asserts that ``smo.kkt_violation`` — the
smallest achievable max per-sample KKT violation over all choices of the
equality multiplier — is within the solver's tolerance. A solve that
terminated at duality gap <= 2*tol certifies at <= tol.

Covered: SVC (binary) and SVR across the full engine matrix
{dense, chunked, pallas, sharded} through the public class API, plus
the low-rank tier ({nystrom, rff}): there the certificate is computed
against the APPROXIMATE Gram ``K-tilde = PhiBar PhiBar^T`` (PhiBar is
the feature matrix with the augmented bias column) with the equality
multiplier pinned at ``r = 0`` — the augmented-bias dual has no
equality constraint, so its optimum must certify at exactly r = 0
(``smo.kkt_violation``'s pinned-r mode).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import kernels as K, smo
from repro.core.svm import SVC, SVR
from repro.data import make_blobs, make_synth_regression, normalize
from repro.launch.mesh import make_shard_mesh

ENGINES = [
    "dense",
    "chunked",
    "pallas",
    pytest.param("sharded", marks=pytest.mark.requires_devices(4)),
]


def _svc_violation(clf: SVC, x, y) -> float:
    """Recompute f for the classification spec (p = -1, box [0, C]) and
    certify the stored alpha."""
    # sklearn orientation (PR 5): fit encodes classes_[1] as +1
    yy = np.where(y == clf.classes_[1], 1.0, -1.0).astype(np.float32)
    g = np.asarray(K.make_gram_fn(clf.kernel_params)(
        jnp.asarray(x), jnp.asarray(x)), np.float64)
    alpha = np.asarray(clf.alpha_, np.float64)
    f = g @ (alpha * yy) - yy           # y * p == -y at p = -1
    return float(smo.kkt_violation(alpha, yy, f, 0.0, clf.smo_cfg.C))


def _svr_violation(reg: SVR, x, y) -> float:
    """Recompute f for the doubled epsilon-SVR spec and certify the
    stored raw [alpha; alpha*] multipliers."""
    n = x.shape[0]
    g = np.asarray(K.make_gram_fn(reg.kernel_params)(
        jnp.asarray(x), jnp.asarray(x)), np.float64)
    g2 = np.tile(g, (2, 2))             # Gram of [x; x]
    s = np.r_[np.ones(n), -np.ones(n)]
    p = np.r_[reg.epsilon - y, reg.epsilon + y].astype(np.float64)
    a2 = np.asarray(reg.alpha_raw_, np.float64)
    f = g2 @ (a2 * s) + s * p
    return float(smo.kkt_violation(a2, s, f, 0.0, reg.smo_cfg.C))


LOWRANK = ["nystrom", "rff"]


def _phibar(model, x) -> np.ndarray:
    """Feature matrix with the augmented bias column — the linear DCD's
    effective kernel is ``PhiBar PhiBar^T``."""
    phi = np.asarray(model._feature_map.transform(jnp.asarray(x)),
                     np.float64)
    bias = np.full((phi.shape[0], 1), model.dcd_cfg.bias, np.float64)
    return np.concatenate([phi, bias], axis=1)


def _svc_violation_lowrank(clf: SVC, x, y) -> float:
    """Certify the DCD alpha against the approximate Gram, multiplier
    pinned at r = 0 (no equality constraint in the augmented dual)."""
    yy = np.where(y == clf.classes_[1], 1.0, -1.0).astype(np.float64)
    phib = _phibar(clf, x)
    alpha = np.asarray(clf.alpha_, np.float64)
    f = phib @ (phib.T @ (alpha * yy)) - yy   # y * p == -y at p = -1
    return float(smo.kkt_violation(alpha, yy, f, 0.0, clf.smo_cfg.C,
                                   r=0.0))


def _svr_violation_lowrank(reg: SVR, x, y) -> float:
    """Doubled epsilon-SVR spec over the approximate Gram, r pinned."""
    phib = _phibar(reg, x)
    phib2 = np.concatenate([phib, phib], axis=0)
    n = x.shape[0]
    s = np.r_[np.ones(n), -np.ones(n)]
    p = np.r_[reg.epsilon - y, reg.epsilon + y].astype(np.float64)
    a2 = np.asarray(reg.alpha_raw_, np.float64)
    f = phib2 @ (phib2.T @ (a2 * s)) + s * p
    return float(smo.kkt_violation(a2, s, f, 0.0, reg.smo_cfg.C, r=0.0))


def _engine_kwargs(backend):
    if backend == "sharded":
        return dict(mesh=make_shard_mesh(4), worker_axes=("shards",),
                    shard="data")
    return dict(engine=backend)


@pytest.mark.parametrize("backend", ENGINES)
def test_svc_kkt_certificate(backend):
    x, yc = make_blobs(90, 2, 6, sep=1.2, seed=4)
    x = normalize(x)
    clf = SVC(kernel="rbf", C=1.0, **_engine_kwargs(backend)).fit(x, yc)
    assert clf.converged_
    viol = _svc_violation(clf, x, yc)
    assert viol <= clf.smo_cfg.tol, (
        f"engine={backend}: max KKT violation {viol:.2e} exceeds "
        f"tol={clf.smo_cfg.tol}")


@pytest.mark.parametrize("backend", ENGINES)
def test_svr_kkt_certificate(backend):
    x, y = make_synth_regression(120, 4, kind="sinc", noise=0.05, seed=2)
    reg = SVR(kernel="rbf", C=1.0, epsilon=0.1,
              **_engine_kwargs(backend)).fit(x, y)
    assert reg.converged_
    viol = _svr_violation(reg, x, y)
    assert viol <= reg.smo_cfg.tol, (
        f"engine={backend}: max KKT violation {viol:.2e} exceeds "
        f"tol={reg.smo_cfg.tol}")


@pytest.mark.parametrize("backend", LOWRANK)
def test_svc_kkt_certificate_lowrank(backend):
    x, yc = make_blobs(90, 2, 6, sep=1.2, seed=4)
    x = normalize(x)
    clf = SVC(kernel="rbf", C=1.0, engine=backend, rank=48).fit(x, yc)
    assert clf.converged_
    viol = _svc_violation_lowrank(clf, x, yc)
    assert viol <= clf.smo_cfg.tol, (
        f"engine={backend}: low-rank KKT violation {viol:.2e} exceeds "
        f"tol={clf.smo_cfg.tol}")


@pytest.mark.parametrize("backend", LOWRANK)
def test_svr_kkt_certificate_lowrank(backend):
    x, y = make_synth_regression(120, 4, kind="sinc", noise=0.05, seed=2)
    reg = SVR(kernel="rbf", C=1.0, epsilon=0.1, engine=backend,
              rank=48).fit(x, y)
    assert reg.converged_
    viol = _svr_violation_lowrank(reg, x, y)
    assert viol <= reg.smo_cfg.tol, (
        f"engine={backend}: low-rank KKT violation {viol:.2e} exceeds "
        f"tol={reg.smo_cfg.tol}")


def test_lowrank_certificate_not_vacuous():
    """Zeroed multipliers on a non-trivial low-rank problem must show a
    violation far above tol — the r=0 pinned check has teeth."""
    x, yc = make_blobs(60, 2, 6, sep=1.2, seed=9)
    x = normalize(x)
    clf = SVC(kernel="rbf", engine="nystrom", rank=32).fit(x, yc)
    yy = np.where(yc == clf.classes_[1], 1.0, -1.0).astype(np.float64)
    phib = _phibar(clf, x)
    a0 = np.zeros(len(yy))
    f0 = phib @ (phib.T @ (a0 * yy)) - yy
    assert float(smo.kkt_violation(a0, yy, f0, 0.0, 1.0,
                                   r=0.0)) > 10 * clf.smo_cfg.tol


@pytest.mark.parametrize("shrink_every", [0, 2])
def test_certificate_with_shrinking(shrink_every):
    """Adaptive shrinking must not weaken the certificate: the un-shrunk
    re-check inside the solver is what the harness independently
    verifies here."""
    x, y = make_synth_regression(150, 3, kind="sinc", noise=0.05, seed=5)
    reg = SVR(kernel="rbf", epsilon=0.1, engine="chunked",
              shrink_every=shrink_every).fit(x, y)
    assert _svr_violation(reg, x, y) <= reg.smo_cfg.tol


def test_violation_detects_nonoptimal_points():
    """The certificate is not vacuous: a perturbed or zero alpha on a
    non-trivial problem must show a violation well above tol."""
    x, y = make_synth_regression(80, 3, kind="sinc", noise=0.05, seed=6)
    reg = SVR(kernel="rbf", epsilon=0.05).fit(x, y)
    n = x.shape[0]
    g2 = np.tile(np.asarray(K.make_gram_fn(reg.kernel_params)(
        jnp.asarray(x), jnp.asarray(x)), np.float64), (2, 2))
    s = np.r_[np.ones(n), -np.ones(n)]
    p = np.r_[reg.epsilon - y, reg.epsilon + y].astype(np.float64)
    a0 = np.zeros(2 * n)                # alpha = 0 is not optimal here
    f0 = g2 @ (a0 * s) + s * p
    assert float(smo.kkt_violation(a0, s, f0, 0.0, 1.0)) > 10 * reg.smo_cfg.tol
