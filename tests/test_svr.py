"""epsilon-SVR correctness: analytic fixtures, tube-membership KKT
structure, SMO-vs-GD dual agreement, interior-point invariance (seeded +
hypothesis), and sharded-vs-unsharded equivalence."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gd, kernels as K, smo
from repro.core.svm import SVR
from repro.data import make_synth_regression
from repro.launch.mesh import make_shard_mesh

SV_EPS = 1e-6


def _predict(x_train, beta, b, z, kp):
    ones = jnp.ones(np.asarray(x_train).shape[0], jnp.float32)
    return np.asarray(smo.decision_function(
        jnp.asarray(x_train), ones, jnp.asarray(beta), b,
        jnp.asarray(z), kernel=kp))


class TestAnalytic:
    def test_two_point_linear_exact(self):
        """x = [0, 1], y = [0, 2], eps = 0.5, linear kernel, large C:
        the flattest tube function is f(z) = z + 0.5 (both points sit ON
        the tube boundary), with the unique dual beta = [-1, +1]."""
        x = np.array([[0.0], [1.0]], np.float32)
        y = np.array([0.0, 2.0], np.float32)
        r = smo.svr_smo(jnp.asarray(x), jnp.asarray(y), epsilon=0.5,
                        cfg=smo.SMOConfig(C=10.0),
                        kernel=K.KernelParams(name="linear"))
        assert bool(r.converged)
        np.testing.assert_allclose(np.asarray(r.beta), [-1.0, 1.0],
                                   atol=5e-3)
        assert abs(float(r.b) - 0.5) <= 5e-3
        pred = _predict(x, r.beta, r.b, np.array([[0.0], [0.5], [1.0]],
                                                 np.float32),
                        K.KernelParams(name="linear"))
        np.testing.assert_allclose(pred, [0.5, 1.0, 1.5], atol=1e-2)

    def test_all_inside_tube_degenerate(self):
        """When every target fits inside one 2*eps tube the dual optimum
        is beta = 0 and the midpoint bias (max(y)+min(y))/2 — the SVR
        analog of a constant classifier."""
        x = np.array([[0.0], [0.3], [0.6], [1.0]], np.float32)
        y = np.array([0.0, 0.05, -0.05, 0.02], np.float32)
        reg = SVR(kernel="rbf", gamma=0.5, epsilon=0.2).fit(x, y)
        assert reg.n_support_ == 0
        assert abs(reg.b_ - 0.0) <= 1e-3     # (max + min) / 2
        np.testing.assert_allclose(reg.predict(x),
                                   np.full(4, reg.b_), atol=1e-6)

    def test_tube_membership_structure(self):
        """KKT structure of the fit: strict tube-interior points carry
        beta = 0; free multipliers sit ON the tube boundary; residuals
        beyond the tube force |beta| = C."""
        x, y = make_synth_regression(150, 2, kind="sinc", noise=0.1,
                                     seed=3)
        eps, c = 0.15, 1.0
        reg = SVR(kernel="rbf", C=c, epsilon=eps).fit(x, y)
        assert reg.converged_
        resid = np.abs(np.asarray(y, np.float64)
                       - np.asarray(reg.predict(x), np.float64))
        beta = np.asarray(reg.beta_, np.float64)
        tol = 5e-2
        interior = resid < eps - tol
        assert np.all(np.abs(beta[interior]) <= 1e-5)
        free = (np.abs(beta) > 1e-5) & (np.abs(beta) < c - 1e-5)
        if free.any():
            np.testing.assert_allclose(resid[free], eps, atol=tol)
        outside = resid > eps + tol
        assert np.all(np.abs(beta[outside]) >= c - 1e-5)


class TestAgainstGD:
    def test_same_dual_objective_as_gd(self):
        """SMO (explicit) and projected GD (the TF-baseline analog)
        optimize the same epsilon-insensitive dual; GD's soft equality
        penalty may leave it slightly above/below the hard-constrained
        optimum."""
        x, y = make_synth_regression(120, 3, kind="sinc", noise=0.05,
                                     seed=1)
        eps = 0.1
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        n = x.shape[0]
        g2 = np.tile(np.asarray(K.make_gram_fn(kp)(
            jnp.asarray(x), jnp.asarray(x)), np.float64), (2, 2))
        s = np.r_[np.ones(n), -np.ones(n)].astype(np.float32)
        p = np.r_[eps - y, eps + y].astype(np.float32)

        rs = smo.svr_smo(jnp.asarray(x), jnp.asarray(y), epsilon=eps,
                         kernel=kp)
        rg = gd.svr_gd(jnp.asarray(x), jnp.asarray(y), epsilon=eps,
                       cfg=gd.GDConfig(lr=0.01, steps=4000), kernel=kp)
        o_smo = float(smo.qp_objective(np.asarray(rs.alpha), s, p, g2))
        o_gd = float(smo.qp_objective(np.asarray(rg.alpha), s, p, g2))
        eq_violation = abs(float(jnp.sum(rg.alpha * jnp.asarray(s))))
        assert o_gd <= o_smo + max(0.05 * abs(o_smo),
                                   2 * eq_violation + 0.02)
        assert o_gd >= 0.8 * o_smo - 0.02

    def test_gd_predictions_track_smo(self):
        x, y = make_synth_regression(150, 2, kind="sinc", noise=0.05,
                                     seed=2)
        r_smo = SVR(epsilon=0.1, solver="smo").fit(x, y)
        r_gd = SVR(epsilon=0.1, solver="gd", gd_steps=3000,
                   gd_lr=0.01).fit(x, y)
        zt = x[::5]
        np.testing.assert_allclose(r_gd.predict(zt), r_smo.predict(zt),
                                   atol=0.1)


def _interior_doubling_case(x, y, eps, seed):
    """Property: duplicating strict eps-tube-interior points (zero dual
    weight at the optimum) must not change the learned function."""
    reg = SVR(kernel="rbf", epsilon=eps).fit(x, y)
    resid = np.abs(np.asarray(y, np.float64)
                   - np.asarray(reg.predict(x), np.float64))
    interior = resid < 0.7 * eps
    if not interior.any():
        return          # nothing to duplicate — property is vacuous
    x2 = np.concatenate([x, x[interior]], axis=0)
    y2 = np.concatenate([y, y[interior]])
    reg2 = SVR(kernel="rbf", gamma=reg.kernel_params.gamma,
               epsilon=eps).fit(x2, y2)
    rng = np.random.default_rng(seed)
    zt = x + rng.normal(scale=0.05, size=x.shape).astype(np.float32)
    np.testing.assert_allclose(reg2.predict(zt), reg.predict(zt),
                               atol=2e-2)
    # the duplicates stay out of the support set
    dup_beta = np.asarray(reg2.beta_)[x.shape[0]:]
    assert np.all(np.abs(dup_beta) <= 1e-5)


class TestInteriorPointInvariance:
    def test_doubling_interior_points_seeded(self):
        for seed in range(4):
            x, y = make_synth_regression(90, 2, kind="sinc", noise=0.05,
                                         seed=seed)
            _interior_doubling_case(x, y, eps=0.2, seed=seed)


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 10_000),
           n=st.integers(30, 80),
           d=st.integers(1, 3),
           eps=st.floats(0.1, 0.4))
    @settings(max_examples=12, deadline=None)
    def test_doubling_interior_points_hypothesis(seed, n, d, eps):
        x, y = make_synth_regression(n, d, kind="sinc", noise=0.03,
                                     seed=seed)
        _interior_doubling_case(x, y, eps=eps, seed=seed)


# ------------------------------------------------------------------ sharded
@pytest.mark.requires_devices(4)
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_sharded_svr_matches_unsharded(n_shards):
    """ISSUE acceptance: sharded (shard="data") SVR produces the
    identical support set and predictions as the unsharded solve."""
    x, y = make_synth_regression(200, 3, kind="sinc", noise=0.05, seed=7)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    cfg = smo.SMOConfig()
    ref = smo.svr_smo(jnp.asarray(x), jnp.asarray(y), epsilon=0.1,
                      cfg=cfg, kernel=kp)
    got = smo.sharded_svr_smo(x, y, epsilon=0.1,
                              mesh=make_shard_mesh(n_shards), cfg=cfg,
                              kernel=kp)
    assert bool(got.converged)
    b_ref, b_got = np.asarray(ref.beta), np.asarray(got.beta)
    # same support set — modulo multipliers below the duality-gap
    # resolution (cf. tests/test_sharded_smo.py)
    borderline = np.maximum(np.abs(b_ref), np.abs(b_got)) < 5e-3
    assert bool(((np.abs(b_ref) > SV_EPS)
                 == (np.abs(b_got) > SV_EPS))[~borderline].all())
    np.testing.assert_allclose(b_got, b_ref, rtol=5e-3, atol=5e-3)
    assert abs(float(ref.b) - float(got.b)) <= 1e-2
    rng = np.random.default_rng(0)
    zt = x[:64] + rng.normal(scale=0.05, size=x[:64].shape).astype(
        np.float32)
    np.testing.assert_allclose(_predict(x, got.beta, got.b, zt, kp),
                               _predict(x, ref.beta, ref.b, zt, kp),
                               atol=5e-3)


@pytest.mark.requires_devices(4)
def test_sharded_svr_class_non_divisible_n(sample_count=137):
    # 2 * 137 = 274 ≡ 2 (mod 4): the doubled axis needs padding
    x, y = make_synth_regression(sample_count, 2, kind="sinc",
                                 noise=0.05, seed=9)
    ref = SVR(epsilon=0.15).fit(x, y)
    sh = SVR(epsilon=0.15, mesh=make_shard_mesh(4),
             worker_axes=("shards",), shard="data").fit(x, y)
    assert np.array_equal(ref.support_, sh.support_)
    np.testing.assert_allclose(sh.predict(x), ref.predict(x), atol=5e-3)
