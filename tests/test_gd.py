"""GD dual baseline (core/gd.py): optimization-trajectory properties.

The TF-baseline solvers were previously only tested through endpoint
agreement with SMO; these tests pin down the trajectory itself — the
loss curve must descend monotonically once past the warmup transient,
and the projection must hold the box constraint at EVERY step (checked
by re-running to increasing step counts: step k's final state IS the
trajectory point k of a deterministic fixed-step loop)."""
import numpy as np
import jax.numpy as jnp

from repro.core import gd, kernels as K
from repro.data import load_iris, make_synth_regression, normalize

WARMUP = 50


def _binary_iris():
    x, y = load_iris()
    x = normalize(x)
    sel = y != 2
    return x[sel], np.where(y[sel] == 0, 1.0, -1.0).astype(np.float32)


def test_binary_loss_monotone_after_warmup():
    x, y = _binary_iris()
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    r = gd.binary_gd(jnp.asarray(x), jnp.asarray(y),
                     cfg=gd.GDConfig(lr=0.01, steps=400), kernel=kp)
    losses = np.asarray(r.loss_curve, np.float64)
    diffs = np.diff(losses[WARMUP:])
    # descent on a convex quadratic with a stable lr: no step may
    # increase the loss beyond f32 noise
    assert np.all(diffs <= 1e-5), f"max increase {diffs.max():.2e}"
    assert losses[-1] < losses[WARMUP]


def test_svr_loss_monotone_after_warmup():
    x, y = make_synth_regression(100, 3, kind="sinc", noise=0.05, seed=0)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    r = gd.svr_gd(jnp.asarray(x), jnp.asarray(y), epsilon=0.1,
                  cfg=gd.GDConfig(lr=0.01, steps=400), kernel=kp)
    losses = np.asarray(r.loss_curve, np.float64)
    diffs = np.diff(losses[WARMUP:])
    assert np.all(diffs <= 1e-5), f"max increase {diffs.max():.2e}"
    assert losses[-1] < losses[WARMUP]


def test_binary_projection_invariant_every_step():
    """0 <= alpha <= C after every step of the projected loop. The loop
    is deterministic with a static step count, so the state after k
    steps equals trajectory point k — sampling k covers the trajectory
    without instrumenting the scan."""
    x, y = _binary_iris()
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    c = 0.7
    for steps in (1, 2, 5, 13, 40, 150):
        r = gd.binary_gd(jnp.asarray(x), jnp.asarray(y),
                         cfg=gd.GDConfig(C=c, lr=0.05, steps=steps),
                         kernel=kp)
        a = np.asarray(r.alpha)
        assert a.min() >= 0.0 and a.max() <= c, f"step {steps}"


def test_svr_projection_invariant_every_step():
    x, y = make_synth_regression(80, 2, kind="sinc", noise=0.05, seed=1)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    c = 0.5
    for steps in (1, 2, 5, 13, 40, 150):
        r = gd.svr_gd(jnp.asarray(x), jnp.asarray(y), epsilon=0.1,
                      cfg=gd.GDConfig(C=c, lr=0.05, steps=steps),
                      kernel=kp)
        a = np.asarray(r.alpha)           # (2n,) doubled multipliers
        assert a.min() >= 0.0 and a.max() <= c, f"step {steps}"
        np.testing.assert_allclose(np.asarray(r.beta),
                                   a[:80] - a[80:], atol=0.0)


def test_svr_gd_masked_samples_inert():
    """Masked samples (both doubled halves) keep alpha = 0 and do not
    move the fit."""
    x, y = make_synth_regression(60, 2, kind="sinc", noise=0.05, seed=2)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    cfg = gd.GDConfig(lr=0.01, steps=200)
    r0 = gd.svr_gd(jnp.asarray(x[:50]), jnp.asarray(y[:50]), epsilon=0.1,
                   cfg=cfg, kernel=kp)
    mask = np.r_[np.ones(50, bool), np.zeros(10, bool)]
    r1 = gd.svr_gd(jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask),
                   epsilon=0.1, cfg=cfg, kernel=kp)
    a1 = np.asarray(r1.alpha).reshape(2, 60)
    assert np.all(a1[:, 50:] == 0.0)
    np.testing.assert_allclose(np.asarray(r1.beta[:50]),
                               np.asarray(r0.beta), rtol=1e-4, atol=1e-5)
