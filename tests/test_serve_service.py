"""Service-layer contracts: dynamic batching, the model registry, SV-bank
quantization, and predictor thread-safety.

What PR 9 pins down:

* ``ServingService`` answers are EXACTLY what the underlying predictor
  would serve for the same rows — batching merges requests into one
  fused decide, and the scatter-back never mixes rows up, for any mix
  of ops, models and row counts;
* ``ModelRegistry`` eviction drops device residency but never changes
  served values: evict + re-admit is bit-identical (same pack, same
  programs);
* quantized packs (``sv_dtype="fp16"|"bf16"``) roundtrip through the
  v3 schema, stay within the accuracy gate (decision delta <= 3e-2
  against the fp32 pack) and keep label parity — while v1/v2 artifacts
  keep loading;
* concurrent ``decision_values`` callers on ONE predictor get exactly
  the values a serial caller gets, and the served-row counter stays
  exact.
"""
import io
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro import serve
from repro.core.svm import SVC, SVR
from repro.data.synth import (make_blobs, make_imbalanced_blobs,
                              make_synth_regression)

QUANT_GATE = 3e-2        # max decision-value delta vs the fp32 pack


@pytest.fixture(scope="module")
def binary_problem():
    x, y = make_blobs(30, 2, 4, sep=3.0, seed=0)
    return x, y, SVC(solver="smo", gamma=0.5).fit(x, y)


@pytest.fixture(scope="module")
def ovo_problem():
    x, y = make_imbalanced_blobs([40, 25, 12, 9], 4, sep=3.0, seed=1)
    return x, y, SVC(solver="smo", gamma=0.5).fit(x, y)


@pytest.fixture(scope="module")
def svr_problem():
    x, y = make_synth_regression(60, 5, seed=2)
    return x, y, SVR(solver="smo", gamma=0.5, epsilon=0.05).fit(x, y)


# ---------------------------------------------------------------- service
def test_service_matches_predictor_outputs(ovo_problem):
    x, _, model = ovo_problem
    packed = serve.pack(model)
    pred = serve.Predictor(packed, engine="chunked").warmup((1, 8, 32))
    with serve.ServingService(packed, engine="chunked",
                              window_ms=5.0) as svc:
        futs = [(svc.submit(x[i:i + 3], op="predict"), "predict", i, 3)
                for i in range(0, 24, 3)]
        futs += [(svc.submit(x[i], op="decision_function"),
                  "decision_function", i, 1) for i in range(24, 30)]
        futs += [(svc.submit(x[i:i + 2], op="values"), "values", i, 2)
                 for i in range(30, 40, 2)]
        for fut, op, i, n in futs:
            got = fut.result(timeout=30)
            want = pred.decode(pred.decision_values(x[i:i + n]), op)
            if op == "predict":
                np.testing.assert_array_equal(got, want)
            else:
                # the merged batch pads to a different bucket than the
                # per-slice reference: multi-task chunked values may
                # move a few ulp (documented in tests/test_serving.py)
                np.testing.assert_array_almost_equal_nulp(got, want,
                                                          nulp=8)


def test_service_batches_a_burst(binary_problem):
    x, _, model = binary_problem
    svc = serve.ServingService(serve.pack(model), engine="chunked",
                               window_ms=50.0)
    try:
        svc.predict(x[:1])                       # warm the programs
        futs = [svc.submit(x[i]) for i in range(20)]
        for f in futs:
            f.result(timeout=30)
        s = svc.stats
        assert s["n_requests"] == 21 and s["n_rows"] == 21
        # the burst of 20 coalesced into far fewer fused decides
        assert s["n_batches"] <= 1 + 4
        assert s["max_batch_rows"] >= 8
    finally:
        svc.close()


def test_service_flushes_when_bucket_fills(binary_problem):
    """A full max_batch window must dispatch immediately, not wait out
    the (long) batching window."""
    x, _, model = binary_problem
    svc = serve.ServingService(serve.pack(model), engine="chunked",
                               window_ms=10_000.0, max_batch=8)
    try:
        svc.predict(x[:8])                       # warm
        t0 = time.perf_counter()
        futs = [svc.submit(x[i]) for i in range(8)]
        for f in futs:
            f.result(timeout=30)
        assert time.perf_counter() - t0 < 5.0    # not the 10s window
        assert svc.stats["n_full_flushes"] >= 1
    finally:
        svc.close()


def test_service_multi_model_routing(binary_problem, svr_problem):
    xc, _, clf = binary_problem
    xr, _, reg_model = svr_problem
    models = {"clf": serve.pack(clf), "reg": serve.pack(reg_model)}
    with serve.ServingService(models, engine="chunked",
                              window_ms=5.0) as svc:
        fc = [svc.submit(xc[i], model="clf") for i in range(8)]
        fr = [svc.submit(xr[i], model="reg") for i in range(8)]
        got_c = np.concatenate([f.result(timeout=30) for f in fc])
        got_r = np.concatenate([f.result(timeout=30) for f in fr])
    np.testing.assert_array_equal(got_c, clf.predict(xc[:8]))
    np.testing.assert_array_equal(got_r, reg_model.predict(xr[:8]))


def test_service_submit_validation(binary_problem):
    x, _, model = binary_problem
    with serve.ServingService(serve.pack(model), engine="chunked",
                              window_ms=0.0) as svc:
        with pytest.raises(KeyError, match="unknown model"):
            svc.submit(x[:2], model="nope")
        with pytest.raises(ValueError, match="op"):
            svc.submit(x[:2], op="proba")
        with pytest.raises(ValueError, match="request"):
            svc.submit(np.zeros((2, 9), np.float32))
        with pytest.raises(ValueError, match="request"):
            svc.submit(np.zeros((0, x.shape[1]), np.float32))
        with pytest.raises(ValueError, match="window_ms"):
            serve.ServingService(serve.pack(model), window_ms=-1)


def test_service_close_flushes_and_rejects(binary_problem):
    x, _, model = binary_problem
    svc = serve.ServingService(serve.pack(model), engine="chunked",
                               window_ms=200.0)
    futs = [svc.submit(x[i]) for i in range(5)]
    svc.close()                      # mid-window: must flush, not drop
    got = np.concatenate([f.result(timeout=30) for f in futs])
    np.testing.assert_array_equal(got, model.predict(x[:5]))
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(x[:1])
    svc.close()                      # idempotent


def test_service_over_existing_predictor(binary_problem):
    x, _, model = binary_problem
    pred = serve.Predictor(serve.pack(model), engine="chunked")
    with serve.ServingService(pred, window_ms=1.0) as svc:
        np.testing.assert_array_equal(svc.predict(x[:7]),
                                      model.predict(x[:7]))
    assert pred.n_requests >= 7      # served through the shared predictor


def test_service_concurrent_submitters(ovo_problem):
    """Many submitter threads, one batcher: every future resolves to
    exactly its own rows' outputs."""
    x, _, model = ovo_problem
    want = model.predict(x)
    with serve.ServingService(serve.pack(model), engine="chunked",
                              window_ms=2.0) as svc:
        def one(i):
            j = i % (len(x) - 4)
            return j, svc.submit(x[j:j + 4]).result(timeout=60)

        with ThreadPoolExecutor(max_workers=8) as ex:
            for j, got in ex.map(one, range(64)):
                np.testing.assert_array_equal(got, want[j:j + 4])


# --------------------------------------------------------------- registry
def test_registry_lru_eviction_and_readmission(binary_problem,
                                               ovo_problem):
    xa, _, ma = binary_problem
    xb, _, mb = ovo_problem
    reg = serve.ModelRegistry(max_resident=2, engine="chunked",
                              warmup_sizes=(4,))
    reg.register("a", serve.pack(ma))
    reg.register("b", serve.pack(mb))
    reg.register("c", serve.pack(ma, sv_dtype="fp16"))
    va = reg.get("a").decision_values(xa[:4])
    reg.get("b")
    assert reg.resident == ("a", "b")
    reg.get("a")                              # refresh recency
    assert reg.resident == ("b", "a")
    reg.get("c")                              # evicts b (LRU), not a
    assert reg.resident == ("a", "c")
    assert reg.stats == {"hits": 1, "admissions": 3, "evictions": 1}
    # the satellite contract: evict + re-admit serves bit-identical
    # values (host pack unchanged, same programs)
    reg.get("b")                              # evicts a
    assert "a" not in reg.resident
    va2 = reg.get("a").decision_values(xa[:4])
    np.testing.assert_array_equal(va, va2)


def test_registry_explicit_evict_and_unregister(binary_problem):
    _, _, model = binary_problem
    reg = serve.ModelRegistry(max_resident=2, engine="chunked")
    reg.register("m", serve.pack(model))
    assert reg.evict("m") is False            # never admitted
    reg.get("m")
    assert reg.evict("m") is True and reg.resident == ()
    assert "m" in reg and len(reg) == 1       # host arrays survive
    reg.unregister("m")
    assert "m" not in reg
    with pytest.raises(KeyError, match="not registered"):
        reg.get("m")
    with pytest.raises(ValueError, match="max_resident"):
        serve.ModelRegistry(max_resident=0)


def test_registry_register_replace_and_path(binary_problem, tmp_path):
    x, y, model = binary_problem
    path = tmp_path / "m.npz"
    serve.save(path, serve.pack(model))
    reg = serve.ModelRegistry(engine="chunked")
    reg.register("m", path)                   # path form loads
    first = reg.get("m")
    with pytest.raises(ValueError, match="already registered"):
        reg.register("m", serve.pack(model))
    reg.register("m", serve.pack(model), replace=True)
    assert reg.resident == ()                 # replace evicts residency
    assert reg.get("m") is not first


def test_registry_thread_safe_admission(binary_problem):
    x, _, model = binary_problem
    reg = serve.ModelRegistry(max_resident=1, engine="chunked",
                              warmup_sizes=())
    reg.register("m", serve.pack(model))
    preds = []
    with ThreadPoolExecutor(max_workers=8) as ex:
        preds = list(ex.map(lambda _: reg.get("m"), range(32)))
    assert all(p is preds[0] for p in preds)  # admitted exactly once
    assert reg.stats["admissions"] == 1


# ----------------------------------------------------------- quantization
@pytest.mark.parametrize("sv_dtype", ["fp16", "bf16"])
@pytest.mark.parametrize("prob", ["binary_problem", "ovo_problem",
                                  "svr_problem"])
def test_quantized_pack_accuracy_gate(sv_dtype, prob, request):
    x, _, model = request.getfixturevalue(prob)
    full = serve.Predictor(serve.pack(model), engine="chunked")
    quant = serve.Predictor(serve.pack(model, sv_dtype=sv_dtype),
                            engine="chunked")
    df_full = full.decision_values(x)
    df_quant = quant.decision_values(x)
    assert np.max(np.abs(df_quant - df_full)) <= QUANT_GATE
    if isinstance(model, SVR):
        assert np.max(np.abs(quant.predict(x) - full.predict(x))) \
            <= QUANT_GATE
    else:
        np.testing.assert_array_equal(quant.predict(x), full.predict(x))


@pytest.mark.parametrize("sv_dtype", ["fp16", "bf16"])
def test_quantized_pack_schema_v3_roundtrip(ovo_problem, sv_dtype,
                                            tmp_path):
    x, _, model = ovo_problem
    packed = serve.pack(model, sv_dtype=sv_dtype)
    assert packed.sv_dtype == sv_dtype
    want_dt = serve.SV_DTYPES[sv_dtype]
    assert all(g.sv_x.dtype == want_dt and g.sv_coef.dtype == want_dt
               for g in packed.buckets)
    path = tmp_path / "q.npz"
    serve.save(path, packed)
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"]))
    assert meta["version"] == serve.SCHEMA_VERSION_QUANT == 3
    assert meta["sv_dtype"] == sv_dtype
    loaded = serve.load(path)
    assert loaded.sv_dtype == sv_dtype
    for got, ref in zip(loaded.buckets, packed.buckets):
        assert got.sv_x.dtype == want_dt
        np.testing.assert_array_equal(
            np.asarray(got.sv_x, np.float32),
            np.asarray(ref.sv_x, np.float32))
        np.testing.assert_array_equal(got.b, ref.b)      # bias stays f32
        assert got.b.dtype == np.float32
    # served values identical pre/post roundtrip
    np.testing.assert_array_equal(
        serve.Predictor(loaded, engine="chunked").decision_values(x[:16]),
        serve.Predictor(packed, engine="chunked").decision_values(x[:16]))


def test_quantized_pack_serves_on_pallas(binary_problem):
    x, _, model = binary_problem
    full = serve.Predictor(serve.pack(model), engine="pallas")
    quant = serve.Predictor(serve.pack(model, sv_dtype="bf16"),
                            engine="pallas")
    delta = np.max(np.abs(quant.decision_values(x[:32])
                          - full.decision_values(x[:32])))
    assert delta <= QUANT_GATE
    np.testing.assert_array_equal(quant.predict(x[:32]),
                                  full.predict(x[:32]))


def test_fp32_pack_still_writes_v1(binary_problem):
    """Quantization must not bump unquantized writers: fp32 SV-bank
    packs keep schema v1 (old readers), low-rank keeps v2."""
    _, _, model = binary_problem
    buf = io.BytesIO()
    serve.save(buf, serve.pack(model))
    buf.seek(0)
    with np.load(buf, allow_pickle=False) as z:
        meta = json.loads(str(z["meta"]))
    assert meta["version"] == 1 and "sv_dtype" not in meta
    buf.seek(0)
    assert serve.load(buf).sv_dtype == "fp32"


def test_lowrank_pack_rejects_quantization():
    x, y = make_blobs(40, 2, 6, sep=3.0, seed=7)
    clf = SVC(engine="rff", rank=32, gamma=0.5).fit(x, y)
    with pytest.raises(ValueError, match="low-rank"):
        serve.pack(clf, sv_dtype="fp16")
    # and the v2 low-rank schema still roundtrips
    buf = io.BytesIO()
    serve.save(buf, serve.pack(clf))
    buf.seek(0)
    loaded = serve.load(buf)
    assert loaded.feature_map is not None and loaded.sv_dtype == "fp32"


def test_quantize_helper_and_validation(binary_problem):
    _, _, model = binary_problem
    packed = serve.pack(model)
    q = serve.quantize(packed, "fp16")
    assert q.sv_dtype == "fp16" and packed.sv_dtype == "fp32"
    assert serve.quantize(q, "fp16") is q            # no-op re-quantize
    with pytest.raises(ValueError, match="sv_dtype"):
        serve.quantize(packed, "int8")
    with pytest.raises(ValueError, match="sv_dtype"):
        serve.pack(model, sv_dtype="fp64")


# ---------------------------------------------------------- thread safety
def test_predictor_concurrent_decision_values(ovo_problem):
    """Concurrent callers must not corrupt n_requests nor interleave
    partially-written outputs: every thread's values match the serial
    reference exactly, and the served-row counter is the exact total."""
    x, _, model = ovo_problem
    pred = serve.Predictor(serve.pack(model), engine="chunked")
    pred.warmup(batch_sizes=(4, 16))
    slices = [(i % 40, 4 + (i % 3) * 12) for i in range(48)]
    want = {(s, n): pred.decision_values(x[s:s + n]) for s, n in
            set(slices)}
    served0 = pred.n_requests
    barrier = threading.Barrier(8)
    errors = []

    def worker(idx):
        try:
            barrier.wait(timeout=30)
            for k in range(idx, len(slices), 8):
                s, n = slices[k]
                np.testing.assert_array_equal(
                    pred.decision_values(x[s:s + n]), want[(s, n)])
        except Exception as e:                       # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    assert pred.n_requests == served0 + sum(n for _, n in slices)


def test_predictor_decode_validates_op(binary_problem):
    _, _, model = binary_problem
    pred = serve.Predictor(serve.pack(model), engine="chunked")
    df = pred.decision_values(np.zeros((2, 4), np.float32))
    with pytest.raises(ValueError, match="op"):
        pred.decode(df, "proba")


# ----------------------------------------------- lock-discipline regressions
# pinned after the R004 (lock-discipline) sweep: these are the races the
# static rule flagged in serve/, fixed by putting the shared state under
# the declared locks. Each test fails on the pre-fix code.
def test_registry_stats_is_a_snapshot(binary_problem):
    """`stats` used to be the live dict the admission path mutates on
    other threads; it is now a copy taken under the registry lock."""
    _, _, model = binary_problem
    reg = serve.ModelRegistry(engine="chunked", warmup_sizes=())
    reg.register("m", serve.pack(model))
    reg.get("m")
    s = reg.stats
    s["admissions"] = 999                    # caller scribbles on copy
    s["bogus"] = 1
    assert reg.stats == {"hits": 0, "admissions": 1, "evictions": 0}
    assert reg.stats is not reg.stats        # fresh snapshot per read


def test_service_racing_closers_enqueue_one_sentinel(binary_problem):
    """Two racing close() calls used to both pass the unlocked _closed
    check and both enqueue the worker-stop sentinel; the first-closer
    election now happens under the stats lock, so exactly one does."""
    from repro.serve import service as service_mod
    _, _, model = binary_problem
    packed = serve.pack(model)
    for _ in range(4):                       # give the race some chances
        svc = serve.ServingService(packed, engine="chunked",
                                   window_ms=0.0)
        sentinels = []
        orig_put = svc._q.put

        def put(item, *a, _orig=orig_put, _log=sentinels, **k):
            if item is service_mod._SENTINEL:
                _log.append(item)
            return _orig(item, *a, **k)

        svc._q.put = put
        barrier = threading.Barrier(6)

        def closer():
            barrier.wait(timeout=30)
            svc.close()

        threads = [threading.Thread(target=closer) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert len(sentinels) == 1
        with pytest.raises(RuntimeError, match="closed"):
            svc.submit(np.zeros((1, packed.n_features), np.float32))


def test_service_submitters_racing_close_never_hang(binary_problem):
    """Futures issued around a racing close() must all terminate: a real
    result, a closed-service rejection at submit, or the fail-fast
    'closed before dispatch' error — never a silent hang."""
    x, _, model = binary_problem
    svc = serve.ServingService(serve.pack(model), engine="chunked",
                               window_ms=1.0)
    svc.predict(x[:1])                       # warm the programs
    futs: list = []
    barrier = threading.Barrier(5)

    def submitter(i):
        barrier.wait(timeout=30)
        for j in range(25):
            try:
                futs.append((svc.submit(x[(i + j) % len(x)]), i, j))
            except RuntimeError:             # service closed: expected
                return

    def closer():
        barrier.wait(timeout=30)
        time.sleep(0.005)
        svc.close()

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(4)] + [threading.Thread(target=closer)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not any(t.is_alive() for t in threads)
    for fut, i, j in futs:
        try:
            got = fut.result(timeout=30)     # resolves one way or other
            np.testing.assert_array_equal(
                got, model.predict(x[(i + j) % len(x)][None]))
        except RuntimeError as e:
            assert "closed" in str(e)


def test_warmup_concurrent_requests_keep_their_counts(binary_problem):
    """warmup() used to snapshot-and-restore n_requests, erasing the
    rows real callers served while warmup ran; it now subtracts exactly
    its own synthetic rows under the lock."""
    x, _, model = binary_problem
    pred = serve.Predictor(serve.pack(model), engine="chunked")
    pred.decision_values(x[:3])
    assert pred.n_requests == 3
    rows = [0]
    stop = threading.Event()
    started = threading.Event()

    def real_traffic():
        started.set()
        while not stop.is_set():
            pred.decision_values(x[:2])
            rows[0] += 2

    t = threading.Thread(target=real_traffic)
    t.start()
    try:
        started.wait(timeout=30)
        pred.warmup((1, 4, 16, 64))          # overlaps the live traffic
    finally:
        stop.set()
        t.join(timeout=60)
    assert pred.n_requests == 3 + rows[0]


# ------------------------------------------------------------ compile guard
def test_service_replay_stays_within_compile_budget(ovo_problem,
                                                    compile_guard):
    """Open-loop replay with mixed request sizes through the service
    must reuse the warm bucketed programs: after warmup at the covering
    buckets, a burst of odd-sized requests compiles NOTHING new."""
    x, _, model = ovo_problem
    packed = serve.pack(model)
    with serve.ServingService(packed, engine="chunked",
                              window_ms=2.0) as svc:
        # warm every bucket the burst below can land in — merged
        # windows reach ~120 rows, the 128 bucket — plus the decode path
        for t in (1, 2, 4, 8, 16, 32, 64, len(x)):
            svc.predict(x[:t])
        with compile_guard(budget=0, note="mixed-size replay") as g:
            futs = [svc.submit(x[i % 30:i % 30 + 1 + i % 5])
                    for i in range(40)]
            for f in futs:
                f.result(timeout=60)
        assert g.count == 0
