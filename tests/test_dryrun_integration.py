"""Dry-run launch-path integration: lower+compile a reduced combo on a
small mesh (the real 512-device sweep is results/dryrun_*.jsonl; this
keeps the path covered in CI). Runs in-process on the forced multi-device
host CPU that tests/conftest.py sets up before jax initializes."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding

from repro.configs.base import get_config, reduced
from repro.launch.mesh import set_mesh
from repro.models.model import Model, abstract_init
from repro.roofline.collect import collective_bytes
from repro.sharding import rules


@pytest.mark.requires_devices(8)
@pytest.mark.parametrize("arch", ["phi4_mini_3p8b", "qwen2_moe_a2p7b",
                                  "mamba2_780m"])
def test_reduced_dryrun_on_2x4_mesh(arch):
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params_shapes, logical = abstract_init(model)
    # exercises rules.spec for every parameter (raises on a bad rule)
    jax.tree.map(lambda lg: NamedSharding(mesh, rules.spec(lg, mesh)),
                 logical, is_leaf=lambda x: isinstance(x, tuple))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (4, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (4, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)

    def fwd(p, b):
        return model.forward(p, b)[0]

    with set_mesh(mesh):
        lowered = jax.jit(fwd).lower(params_shapes, batch)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    coll = collective_bytes(compiled.as_text())
    assert coll["total_bytes"] >= 0
