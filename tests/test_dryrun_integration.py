"""Dry-run launch-path integration: lower+compile a reduced combo on a
small forced-device mesh in a subprocess (the real 512-device sweep is
results/dryrun_*.jsonl; this keeps the path covered in CI)."""
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config, reduced, INPUT_SHAPES
    from repro.launch.mesh import set_mesh
    from repro.models.model import Model, abstract_init
    from repro.sharding import rules
    from repro.roofline.collect import collective_bytes

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = reduced(get_config("%s"))
    model = Model(cfg)
    params_shapes, logical = abstract_init(model)
    shardings = jax.tree.map(
        lambda lg: NamedSharding(mesh, rules.spec(lg, mesh)),
        logical, is_leaf=lambda x: isinstance(x, tuple))
    import jax.numpy as jnp
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (4, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (4, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)

    def fwd(p, b):
        return model.forward(p, b)[0]

    with set_mesh(mesh):
        lowered = jax.jit(fwd).lower(params_shapes, batch)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    coll = collective_bytes(compiled.as_text())
    print("DRYRUN_OK", coll["total_bytes"])
""")


@pytest.mark.parametrize("arch", ["phi4_mini_3p8b", "qwen2_moe_a2p7b",
                                  "mamba2_780m"])
def test_reduced_dryrun_on_2x4_mesh(arch):
    r = subprocess.run([sys.executable, "-c", _SCRIPT % arch],
                       capture_output=True, text=True, cwd=".",
                       timeout=600)
    assert "DRYRUN_OK" in r.stdout, r.stdout[-500:] + r.stderr[-2000:]
