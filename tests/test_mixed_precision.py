"""bf16 Gram parity gates: ``EngineConfig.gram_dtype = "bf16"`` trades
operand precision for HBM bandwidth; these tests pin exactly how much
accuracy that trade costs, via the solver-independent KKT certificate
(``smo.kkt_violation`` on an fp64 reference Gram) and served-decision
deltas.

Documented tolerances (empirical values carry ~4x margin):

* Gram entries:     |K_bf16 - K_fp64| <= 2e-2; the RBF diagonal stays
  within f32 rounding (1e-5) of 1, NOT bf16 epsilon — the norms are
  computed from the SAME bf16-rounded operands as the dot
* KKT certificate:  fp32 fit <= 5e-3, bf16 fit <= 2e-2 across
  {binary SVC, ovo SVC, epsilon-SVR}
* decisions:        |f_bf16 - f_fp32| <= 3e-2 on trained models and on
  the serving path (same packed fp32 model served at both precisions)
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import kernels as K, smo
from repro.core.kernel_engine import EngineConfig, make_engine
from repro.core.svm import SVC, SVR
from repro.data import make_blobs, make_synth_regression
from repro.kernels import ops
from repro.serve import pack
from repro.serve.predictor import Predictor

GRAM_TOL = 2e-2
KKT_TOL = {"fp32": 5e-3, "bf16": 2e-2}
DECISION_TOL = 3e-2

BACKENDS = ["chunked", "pallas"]


def _rbf_ref64(a, b, gamma):
    d2 = ((a[:, None, :].astype(np.float64)
           - b[None, :, :].astype(np.float64)) ** 2).sum(-1)
    return np.exp(-gamma * d2)


def _svc_violation(clf: SVC, x, y) -> float:
    """fp64-reference certificate for a fitted binary SVC (independent
    of whatever Gram precision the solver used)."""
    yy = np.where(y == clf.classes_[1], 1.0, -1.0)
    g = _rbf_ref64(x, x, clf.kernel_params.gamma)
    alpha = np.asarray(clf.alpha_, np.float64)
    f = g @ (alpha * yy) - yy
    return float(smo.kkt_violation(alpha, yy, f, 0.0, clf.smo_cfg.C))


def _svr_violation(reg: SVR, x, y) -> float:
    n = x.shape[0]
    g = _rbf_ref64(x, x, reg.kernel_params.gamma)
    g2 = np.tile(g, (2, 2))
    s = np.r_[np.ones(n), -np.ones(n)]
    p = np.r_[reg.epsilon - y, reg.epsilon + y].astype(np.float64)
    a2 = np.asarray(reg.alpha_raw_, np.float64)
    f = g2 @ (a2 * s) + s * p
    return float(smo.kkt_violation(a2, s, f, 0.0, reg.smo_cfg.C))


def _ovo_max_violation(clf: SVC) -> float:
    """Certify every one-vs-one subproblem of a multiclass fit."""
    worst = 0.0
    for t, task in enumerate(clf._taskset.tasks):
        g = _rbf_ref64(task.x, task.x, clf.kernel_params.gamma)
        yy = np.asarray(task.y, np.float64)
        alpha = np.asarray(clf._fit.alpha[t, :task.size], np.float64)
        f = g @ (alpha * yy) - yy
        worst = max(worst, float(smo.kkt_violation(
            alpha, yy, f, 0.0, clf.smo_cfg.C)))
    return worst


# -------------------------------------------------------------- Gram level
def test_core_gram_bf16_close_with_exact_diagonal():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(64, 12)).astype(np.float32)
    fn32 = K.make_gram_fn(K.KernelParams(name="rbf", gamma=0.3))
    fn16 = K.make_gram_fn(K.KernelParams(name="rbf", gamma=0.3),
                          compute_dtype="bf16")
    ref = _rbf_ref64(a, a, 0.3)
    aj = jnp.asarray(a)
    assert np.abs(np.asarray(fn16(aj, aj)) - ref).max() <= GRAM_TOL
    # same-rounded-operand norms: diag within f32 rounding of 1, far
    # tighter than the ~4e-3 a naive bf16 norm path would give
    np.testing.assert_allclose(np.diag(np.asarray(fn16(aj, aj))), 1.0,
                               rtol=0, atol=1e-5)
    assert np.abs(np.asarray(fn32(aj, aj)) - ref).max() <= 1e-5


def test_core_gram_bf16_all_kernel_modes():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(24, 8)).astype(np.float32))
    for name in ("linear", "poly", "sigmoid", "rbf"):
        params = K.KernelParams(name=name, gamma=0.2, degree=2, coef0=0.5)
        g32 = np.asarray(K.make_gram_fn(params)(a, b), np.float64)
        g16 = np.asarray(K.make_gram_fn(params, compute_dtype="bf16")(a, b),
                         np.float64)
        scale = max(1.0, np.abs(g32).max())
        assert np.abs(g16 - g32).max() / scale <= GRAM_TOL, name


def test_pallas_gram_bf16_close_with_exact_diagonal():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(130, 17)).astype(np.float32))
    ref = _rbf_ref64(np.asarray(a), np.asarray(a), 0.4)
    g16 = np.asarray(ops.rbf_gram(a, a, gamma=0.4, compute_dtype="bf16"))
    assert np.abs(g16 - ref).max() <= GRAM_TOL
    np.testing.assert_allclose(np.diag(g16), 1.0, rtol=0, atol=1e-5)


def test_pallas_decision_kernels_bf16():
    rng = np.random.default_rng(3)
    z = jnp.asarray(rng.normal(size=(33, 9)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(70, 9)).astype(np.float32))
    coef = jnp.asarray(rng.normal(size=(70,)).astype(np.float32))
    f32 = np.asarray(ops.decision(z, x, coef, 0.5, gamma=0.2))
    f16 = np.asarray(ops.decision(z, x, coef, 0.5, gamma=0.2,
                                  compute_dtype="bf16"))
    assert np.abs(f16 - f32).max() <= DECISION_TOL

    sv = jnp.asarray(rng.normal(size=(3, 40, 9)).astype(np.float32))
    cf = jnp.asarray(rng.normal(size=(3, 40)).astype(np.float32))
    bb = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    m32 = np.asarray(ops.multitask_decision(z, sv, cf, bb, gamma=0.2))
    m16 = np.asarray(ops.multitask_decision(z, sv, cf, bb, gamma=0.2,
                                            compute_dtype="bf16"))
    assert np.abs(m16 - m32).max() <= DECISION_TOL


def test_invalid_gram_dtype_rejected():
    with pytest.raises(ValueError, match="compute_dtype"):
        ops.rbf_gram(jnp.ones((8, 4)), jnp.ones((8, 4)),
                     compute_dtype="fp16")
    with pytest.raises(ValueError, match="compute_dtype"):
        K.make_gram_fn(K.KernelParams(name="rbf", gamma=1.0),
                       compute_dtype="fp64")(jnp.ones((4, 2)),
                                             jnp.ones((4, 2)))


# ----------------------------------------------------- engine-level parity
@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_gram_respects_gram_dtype(backend):
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(140, 6)).astype(np.float32))
    params = K.KernelParams(name="rbf", gamma=0.5)
    eng32 = make_engine(x, params, EngineConfig(backend=backend))
    eng16 = make_engine(x, params,
                        EngineConfig(backend=backend, gram_dtype="bf16"))
    z = x[:12]
    c32 = np.asarray(eng32.cross(z))
    c16 = np.asarray(eng16.cross(z))
    assert np.abs(c16 - c32).max() <= GRAM_TOL
    assert np.abs(c16 - c32).max() > 0      # bf16 actually engaged
    d32 = np.asarray(eng32.decide(z, jnp.ones(x.shape[0]), 0.1))
    d16 = np.asarray(eng16.decide(z, jnp.ones(x.shape[0]), 0.1))
    assert np.abs(d16 - d32).max() <= x.shape[0] * GRAM_TOL


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("gram_dtype", ["fp32", "bf16"])
def test_binary_svc_kkt_certificate(backend, gram_dtype):
    x, y = make_blobs(45, 2, 6, sep=1.2, seed=4)
    cfg = EngineConfig(backend=backend, gram_dtype=gram_dtype)
    clf = SVC(C=1.0, gamma=0.5, engine=cfg).fit(x, y)
    assert clf.converged_
    assert _svc_violation(clf, x, y) <= KKT_TOL[gram_dtype]


@pytest.mark.parametrize("backend", BACKENDS)
def test_binary_svc_bf16_decision_delta(backend):
    x, y = make_blobs(45, 2, 6, sep=1.2, seed=4)
    dfs = {}
    for gd in ("fp32", "bf16"):
        cfg = EngineConfig(backend=backend, gram_dtype=gd)
        clf = SVC(C=1.0, gamma=0.5, engine=cfg).fit(x, y)
        dfs[gd] = clf.decision_function(x)
        assert clf.score(x, y) >= 0.95
    assert np.abs(dfs["bf16"] - dfs["fp32"]).max() <= DECISION_TOL


@pytest.mark.parametrize("gram_dtype", ["fp32", "bf16"])
def test_ovo_svc_kkt_certificate(gram_dtype):
    x, y = make_blobs(30, 3, 6, sep=1.4, seed=7)
    cfg = EngineConfig(backend="pallas", gram_dtype=gram_dtype)
    clf = SVC(C=1.0, gamma=0.3, engine=cfg).fit(x, y)
    assert _ovo_max_violation(clf) <= KKT_TOL[gram_dtype]
    assert clf.score(x, y) >= 0.9


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("gram_dtype", ["fp32", "bf16"])
def test_svr_kkt_certificate(backend, gram_dtype):
    x, y = make_synth_regression(120, 4, kind="sinc", noise=0.05, seed=2)
    cfg = EngineConfig(backend=backend, gram_dtype=gram_dtype)
    reg = SVR(C=1.0, gamma=0.5, epsilon=0.1, engine=cfg).fit(x, y)
    assert _svr_violation(reg, x, y) <= KKT_TOL[gram_dtype]


def test_svr_bf16_prediction_delta():
    x, y = make_synth_regression(120, 4, kind="sinc", noise=0.05, seed=2)
    preds = {}
    for gd in ("fp32", "bf16"):
        cfg = EngineConfig(backend="chunked", gram_dtype=gd)
        preds[gd] = SVR(C=1.0, gamma=0.5, epsilon=0.1,
                        engine=cfg).fit(x, y).predict(x)
    assert np.abs(preds["bf16"] - preds["fp32"]).max() <= DECISION_TOL


# ------------------------------------------------------------ serving path
@pytest.mark.parametrize("backend", BACKENDS)
def test_serving_bf16_parity_same_packed_model(backend):
    """One fp32-fit model served at both precisions: the bf16 server
    stays within DECISION_TOL of the fp32 server, and labels match."""
    x, y = make_blobs(30, 3, 6, sep=1.4, seed=7)
    clf = SVC(C=1.0, gamma=0.3).fit(x, y)
    packed = pack(clf)
    p32 = Predictor(packed, engine=EngineConfig(backend=backend))
    p16 = Predictor(packed, engine=EngineConfig(backend=backend,
                                                gram_dtype="bf16"))
    xt = x[:40]
    d32 = p32.decision_values(xt)
    d16 = p16.decision_values(xt)
    assert np.abs(d16 - d32).max() <= DECISION_TOL
    assert (p16.predict(xt) == p32.predict(xt)).mean() >= 0.97
