"""Sharded single-problem SMO: data-parallel solves must reproduce the
single-device solution.

Three layers of evidence, cheapest first:

* the cross-shard working-set-selection reduction (``combine_selection``,
  the correctness-critical collective) equals the unsharded reduction
  BIT-FOR-BIT on random shards — hypothesis property, no mesh needed;
* the ``ShardedKernelEngine`` primitives (row / matvec / decide) match
  the dense engine through a real shard_map;
* the full equivalence matrix: {rbf, linear} x reference backend
  {dense, chunked} x shard count {1, 2, 4}, plus a non-divisible n
  (padding edge), a shrinking-enabled solve, and the n>=4096 acceptance
  problem — same support set, |b| within tol, identical predictions.

Device counts are forced by tests/conftest.py before jax initializes.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import dist, kernel_engine as KE, kernels as K, smo
from repro.core.svm import SVC
from repro.data import make_blobs, normalize
from repro.launch.mesh import make_shard_mesh

SV_EPS = 1e-6


def _binary_problem(n, d=6, sep=2.0, seed=11):
    x, yc = make_blobs(n // 2 + n % 2, 2, d, sep=sep, seed=seed)
    x, yc = x[:n], yc[:n]
    yy = np.where(yc == 0, 1.0, -1.0).astype(np.float32)
    return normalize(x), yy


def _grid(x, n_test=64, seed=3):
    rng = np.random.default_rng(seed)
    idx = rng.choice(x.shape[0], size=min(n_test, x.shape[0]),
                     replace=False)
    return x[idx] + rng.normal(scale=0.05, size=x[idx].shape).astype(
        np.float32)


def _assert_equivalent(ref, got, *, x, yy, kp, b_tol=1e-2):
    """ISSUE acceptance criteria, solution-level: same support set, |b|
    within tol, identical predictions. (The TRAJECTORY is bit-identical
    only when the reference engine computes rows the same way — the SPMD
    partitioner may contract dots differently, so a cross-backend cell
    can take a slightly different path to the same optimum.)"""
    a_ref, a_got = np.asarray(ref.alpha), np.asarray(got.alpha)
    assert bool(got.converged)
    # same support set — modulo multipliers below the duality-gap
    # resolution (a tol-terminated solve does not pin down borderline
    # alphas of magnitude ~C*tol; they contribute nothing detectable to
    # the decision function)
    borderline = np.maximum(a_ref, a_got) < 5e-3
    assert bool(((a_ref > SV_EPS) == (a_got > SV_EPS))[~borderline].all())
    np.testing.assert_allclose(a_got, a_ref, rtol=5e-3, atol=5e-3)
    assert abs(float(ref.b) - float(got.b)) <= b_tol
    # identical predictions
    zt = _grid(x)
    df_ref = smo.decision_function(jnp.asarray(x), jnp.asarray(yy),
                                   ref.alpha, ref.b, jnp.asarray(zt),
                                   kernel=kp)
    df_got = smo.decision_function(jnp.asarray(x), jnp.asarray(yy),
                                   got.alpha, got.b, jnp.asarray(zt),
                                   kernel=kp)
    np.testing.assert_array_equal(np.sign(np.asarray(df_ref)),
                                  np.sign(np.asarray(df_got)))


# ------------------------------------------------------------------ matrix
@pytest.mark.requires_devices(4)
@pytest.mark.parametrize("kernel_name", ["rbf", "linear"])
@pytest.mark.parametrize("ref_backend", ["dense", "chunked"])
@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_equivalence_matrix(kernel_name, ref_backend, n_shards):
    x, yy = _binary_problem(384)
    kp = K.resolve_gamma(K.KernelParams(name=kernel_name), jnp.asarray(x))
    cfg = smo.SMOConfig()
    ref = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), cfg=cfg,
                         kernel=kp,
                         engine=KE.EngineConfig(backend=ref_backend,
                                                chunk=128))
    mesh = make_shard_mesh(n_shards)
    got = smo.sharded_binary_smo(x, yy, mesh=mesh, cfg=cfg, kernel=kp)
    _assert_equivalent(ref, got, x=x, yy=yy, kp=kp)


@pytest.mark.requires_devices(4)
def test_non_divisible_n_padding_edge():
    # 519 % 4 == 3: the sample axis is zero-padded to 520 and the pad
    # rows must stay masked with alpha identically 0
    x, yy = _binary_problem(519)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    ref = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), kernel=kp)
    got = smo.sharded_binary_smo(x, yy, mesh=make_shard_mesh(4), kernel=kp)
    assert got.alpha.shape == (519,)
    _assert_equivalent(ref, got, x=x, yy=yy, kp=kp)


@pytest.mark.requires_devices(4)
def test_shrinking_enabled_single_problem():
    # shrinking is a scalar-jit feature: the sharded path is per-problem
    # (not vmapped), so it must work — including the collective un-shrunk
    # KKT re-check (sharded matvec + selection on the full mask)
    x, yy = _binary_problem(600)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    cfg = smo.SMOConfig(shrink_every=2)
    ref = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), cfg=cfg,
                         kernel=kp)
    got = smo.sharded_binary_smo(x, yy, mesh=make_shard_mesh(4), cfg=cfg,
                                 kernel=kp)
    assert int(got.n_active) <= 600
    _assert_equivalent(ref, got, x=x, yy=yy, kp=kp)


@pytest.mark.slow
@pytest.mark.requires_devices(4)
def test_acceptance_n4096_rbf_4shards():
    """The ISSUE acceptance problem: n >= 4096 RBF on 4 forced host
    devices reproduces the single-device solution."""
    x, yy = _binary_problem(4096, d=8, sep=4.0, seed=7)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    cfg = smo.SMOConfig(max_iter=40_000)
    ref = smo.binary_smo(jnp.asarray(x), jnp.asarray(yy), cfg=cfg,
                         kernel=kp)
    got = smo.sharded_binary_smo(x, yy, mesh=make_shard_mesh(4), cfg=cfg,
                                 kernel=kp)
    assert bool(ref.converged) and bool(got.converged)
    _assert_equivalent(ref, got, x=x, yy=yy, kp=kp)


# ----------------------------------------------- collective WSS reduction
def _split_selection(f, alpha, y, mask, c, n_shards):
    """Reference implementation of the sharded reduction on the host:
    per-shard local ``_selection`` (+ global index conversion), then the
    same ``combine_selection`` every shard would run on the all-gathered
    pairs."""
    n_local = f.shape[0] // n_shards
    ups, iups, lows, ilows = [], [], [], []
    for p in range(n_shards):
        sl = slice(p * n_local, (p + 1) * n_local)
        b_up, i_up, b_low, i_low = smo._selection(
            f[sl], alpha[sl], y[sl], mask[sl], 0.0, c)
        ups.append(b_up)
        iups.append(p * n_local + i_up)
        lows.append(b_low)
        ilows.append(p * n_local + i_low)
    return smo.combine_selection(jnp.stack(ups), jnp.stack(iups),
                                 jnp.stack(lows), jnp.stack(ilows))


def test_wss_reduction_matches_unsharded_seeded():
    """Seeded version of the hypothesis property below — runs even where
    hypothesis (optional dev dep) is absent, so the correctness-critical
    collective is never untested."""
    rng = np.random.default_rng(42)
    for case in range(40):
        n_shards = int(rng.choice([1, 2, 4, 8]))
        n_local = int(rng.integers(1, 25))
        n = n_shards * n_local
        f = rng.uniform(-4, 4, n).astype(np.float32)
        if case % 2:  # coarse grid -> duplicate extrema, exercising the
            f = np.round(f)  # first-occurrence tie-breaking
        f = jnp.asarray(f)
        alpha = jnp.asarray(rng.choice(
            [0.0, 1.0, 0.5, 1e-8, 1.0 - 1e-8], size=n), jnp.float32)
        y = jnp.asarray(rng.choice([-1.0, 1.0], size=n), jnp.float32)
        mask = jnp.asarray(rng.random(n) < 0.8)
        want = smo._selection(f, alpha, y, mask, 0.0, 1.0)
        got = _split_selection(f, alpha, y, mask, 1.0, n_shards)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=f"case {case}")


def test_wss_reduction_all_masked_shard():
    # one shard fully outside the index sets must never win the reduction
    n_shards, n_local = 4, 8
    n = n_shards * n_local
    f = jnp.asarray(np.linspace(-1, 1, n), jnp.float32)
    y = jnp.asarray(np.resize([1.0, -1.0], n), jnp.float32)
    alpha = jnp.zeros(n, jnp.float32)
    mask = jnp.asarray(np.r_[np.zeros(n_local, bool), np.ones(n - n_local,
                                                              bool)])
    want = smo._selection(f, alpha, y, mask, 0.0, 1.0)
    got = _split_selection(f, alpha, y, mask, 1.0, n_shards)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


try:
    from hypothesis import given, settings, strategies as st
    import hypothesis.extra.numpy as hnp
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @st.composite
    def wss_shards(draw):
        n_shards = draw(st.sampled_from([1, 2, 4, 8]))
        n_local = draw(st.integers(1, 24))
        n = n_shards * n_local
        # mix a coarse grid into f so cross-shard ties (the first-
        # occurrence tie-break) actually occur
        f = draw(hnp.arrays(np.float32, (n,),
                            elements=st.one_of(
                                st.floats(-4, 4, width=32),
                                st.sampled_from([-1.0, 0.0, 1.0]))))
        # alphas hit the bounds exactly with decent probability — the
        # index-set membership eps is where selection bugs hide
        alpha = draw(hnp.arrays(np.float32, (n,),
                                elements=st.sampled_from(
                                    [0.0, 1.0, 0.5, 1e-8, 1.0 - 1e-8])))
        y = draw(hnp.arrays(np.int8, (n,),
                            elements=st.sampled_from([-1, 1])))
        mask = draw(hnp.arrays(np.bool_, (n,)))
        return (n_shards, f, alpha,
                np.asarray(y, np.float32), mask)

    @given(wss_shards())
    @settings(max_examples=60, deadline=None)
    def test_wss_reduction_matches_unsharded_bit_for_bit(case):
        """For ANY f/alpha/mask sharding: the cross-shard b_up/b_low/
        argpair reduction equals the unsharded ``_selection`` exactly —
        values AND indices (first-occurrence tie semantics)."""
        n_shards, f, alpha, y, mask = case
        f, alpha = jnp.asarray(f), jnp.asarray(alpha)
        y, mask = jnp.asarray(y), jnp.asarray(mask)
        want = smo._selection(f, alpha, y, mask, 0.0, 1.0)
        got = _split_selection(f, alpha, y, mask, 1.0, n_shards)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


# ------------------------------------------------------- engine primitives
@pytest.mark.requires_devices(4)
def test_sharded_engine_row_matvec_decide_match_dense():
    rng = np.random.default_rng(0)
    n, d, t = 64, 5, 9
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    coef = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    kp = K.KernelParams(gamma=0.4)
    dense = KE.make_engine(x, kp, "dense")

    mesh = make_shard_mesh(4, axis="s")
    ecfg = KE.EngineConfig(backend="sharded", shard_axis="s", chunk=16)

    def body(x_l, v_l, coef_l):
        eng = KE.ShardedKernelEngine(x_l, kp, ecfg)
        row, _ = eng.row(jnp.asarray(37), None)
        return eng.matvec(v_l), eng.decide(z, coef_l, 0.25), row

    spec = P("s")
    fn = jax.jit(KE.shard_map_compat(body, mesh, (spec, spec, spec),
                                     (spec, P(), spec)))
    mv, dec, row = fn(x, v, coef)
    np.testing.assert_allclose(np.asarray(mv),
                               np.asarray(dense.matvec(v)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(dense.decide(z, coef, 0.25)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(row),
                               np.asarray(dense.full()[37]),
                               rtol=1e-5, atol=1e-6)


def test_sharded_engine_requires_axis():
    x = jnp.zeros((8, 2), jnp.float32)
    with pytest.raises(ValueError, match="shard_axis"):
        KE.ShardedKernelEngine(x, K.KernelParams(), KE.EngineConfig())
    with pytest.raises(ValueError, match="bound engine"):
        smo._resolve_sharded_cfg(KE.make_engine(x, K.KernelParams(),
                                                "dense"), "s")


def test_make_shard_mesh_validates():
    with pytest.raises(ValueError, match="devices"):
        make_shard_mesh(10_000)


# ----------------------------------------------- dist / SVC integration
@pytest.mark.requires_devices(4)
def test_fit_taskset_data_parallel_matches_task_parallel():
    rng = np.random.default_rng(5)
    x = np.concatenate([rng.normal(loc=m, size=(80, 4))
                        for m in (-2.0, 0.0, 2.0)]).astype(np.float32)
    y = np.repeat(np.arange(3), 80)
    x = normalize(x)
    from repro.core import multiclass as MC
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    taskset = MC.get_strategy("ovo").build_taskset(x, y)
    mesh = jax.make_mesh((4,), ("workers",))
    ref = dist.fit_taskset(taskset, kernel=kp)  # local vmapped
    got = dist.fit_taskset(taskset, mesh=mesh, kernel=kp, shard="data")
    np.testing.assert_allclose(got.alpha, ref.alpha, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got.b, ref.b, atol=1e-2)
    assert got.converged.all()
    # auto with a low width threshold routes every bucket data-parallel
    # (3 tasks < 4 workers); result must not change
    auto = dist.fit_taskset(taskset, mesh=mesh, kernel=kp, shard="auto",
                            data_min_width=64)
    np.testing.assert_allclose(auto.alpha, ref.alpha, rtol=1e-4, atol=1e-5)


@pytest.mark.requires_devices(4)
def test_fit_taskset_data_parallel_validates():
    rng = np.random.default_rng(5)
    x = normalize(rng.normal(size=(60, 3)).astype(np.float32))
    y = np.repeat(np.arange(3), 20)
    from repro.core import multiclass as MC
    taskset = MC.get_strategy("ovo").build_taskset(x, y)
    mesh = jax.make_mesh((4,), ("workers",))
    with pytest.raises(ValueError, match="solver='smo'"):
        dist.fit_taskset(taskset, mesh=mesh, solver="gd", shard="data")
    with pytest.raises(ValueError, match="shard mode"):
        dist.fit_taskset(taskset, mesh=mesh, shard="bogus")


@pytest.mark.requires_devices(4)
def test_svc_shard_data_binary_and_multiclass():
    # binary: explicit data sharding must match the local fit
    x, yy = _binary_problem(300)
    yb = (yy > 0).astype(np.int64)
    mesh = make_shard_mesh(4, axis="workers")
    local = SVC(solver="smo").fit(x, yb)
    shard = SVC(solver="smo", mesh=mesh, shard="data").fit(x, yb)
    assert shard.converged_
    np.testing.assert_array_equal(local.predict(x), shard.predict(x))
    np.testing.assert_allclose(shard.alpha_, local.alpha_, rtol=1e-4,
                               atol=1e-5)

    # multiclass: hybrid auto must agree with the plain fit
    rng = np.random.default_rng(1)
    xm = np.concatenate([rng.normal(loc=m, size=(60, 4))
                         for m in (-2.0, 0.0, 2.0)]).astype(np.float32)
    ym = np.repeat(np.arange(3), 60)
    xm = normalize(xm)
    ref = SVC(solver="smo").fit(xm, ym)
    got = SVC(solver="smo", mesh=mesh, shard="auto").fit(xm, ym)
    np.testing.assert_array_equal(ref.predict(xm), got.predict(xm))
    assert got.score(xm, ym) >= 0.95


def test_svc_shard_validates():
    with pytest.raises(ValueError, match="shard mode"):
        SVC(shard="bogus")
    # explicit data sharding without a mesh must raise, not silently
    # fit on a single device
    x, yy = _binary_problem(40)
    yb = (yy > 0).astype(np.int64)
    with pytest.raises(ValueError, match="mesh"):
        SVC(shard="data").fit(x, yb)


@pytest.mark.requires_devices(2)
def test_svc_shard_data_axis_mismatch_raises():
    # make_shard_mesh defaults to a "shards" axis; SVC defaults to
    # worker_axes=("workers",) — the validator must catch the mismatch
    # instead of KeyError-ing deep inside the solver
    x, yy = _binary_problem(40)
    yb = (yy > 0).astype(np.int64)
    with pytest.raises(ValueError, match="axis"):
        SVC(mesh=make_shard_mesh(2), shard="data").fit(x, yb)


@pytest.mark.requires_devices(2)
def test_fit_taskset_data_without_mesh_raises():
    rng = np.random.default_rng(5)
    x = normalize(rng.normal(size=(60, 3)).astype(np.float32))
    y = np.repeat(np.arange(3), 20)
    from repro.core import multiclass as MC
    taskset = MC.get_strategy("ovo").build_taskset(x, y)
    with pytest.raises(ValueError, match="mesh"):
        dist.fit_taskset(taskset, shard="data")


@pytest.mark.requires_devices(2)
def test_shard_auto_axis_mismatch_raises_friendly():
    """Regression: shard="auto" (and "task" multiclass) on a mesh whose
    axis names don't match ``worker_axes`` used to crash with a raw
    ``KeyError`` from ``mesh.shape[axis]``; ``resolve_worker_count``
    now validates up front and names the mesh axes."""
    from repro.core.svm import SVR
    x, yy = _binary_problem(48)
    yb = (yy > 0).astype(np.int64)
    mesh = make_shard_mesh(2)   # axis "shards" vs default ("workers",)
    with pytest.raises(ValueError, match=r"mesh axes.*shards"):
        SVC(mesh=mesh, shard="auto").fit(x, yb)
    with pytest.raises(ValueError, match=r"mesh axes.*shards"):
        SVR(mesh=mesh, shard="auto").fit(x, yy.astype(np.float32))
    y3 = np.arange(len(yy)) % 3
    with pytest.raises(ValueError, match=r"mesh axes.*shards"):
        SVC(mesh=mesh, shard="task").fit(x, y3)


@pytest.mark.requires_devices(2)
def test_resolve_worker_count():
    assert dist.resolve_worker_count(None, ("workers",)) == 1
    mesh = make_shard_mesh(2)
    assert dist.resolve_worker_count(mesh, ("shards",)) == 2
    with pytest.raises(ValueError, match="worker axes"):
        dist.resolve_worker_count(mesh, ("workers",))
