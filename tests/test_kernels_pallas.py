"""Per-kernel Pallas validation: shape/dtype sweeps vs the ref.py oracle
(interpret mode executes the kernel body on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n,m,d", [
    (64, 64, 4), (100, 80, 32), (128, 256, 102), (37, 129, 7),
    (256, 256, 130), (800, 800, 102), (1, 1, 1),
])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_rbf_gram_sweep(n, m, d, dtype):
    a = RNG.normal(size=(n, d)).astype(dtype)
    b = RNG.normal(size=(m, d)).astype(dtype)
    gamma = 0.37
    got = ops.rbf_gram(jnp.asarray(a, jnp.float32),
                       jnp.asarray(b, jnp.float32), gamma=gamma)
    want = ref.rbf_gram(jnp.asarray(a, jnp.float32),
                        jnp.asarray(b, jnp.float32), gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("n,m,d", [(64, 64, 16), (200, 100, 102)])
def test_linear_gram_sweep(n, m, d):
    a = RNG.normal(size=(n, d)).astype(np.float32)
    b = RNG.normal(size=(m, d)).astype(np.float32)
    got = ops.rbf_gram(jnp.asarray(a), jnp.asarray(b), gamma=1.0,
                       mode="linear")
    np.testing.assert_allclose(np.asarray(got), a @ b.T, rtol=2e-5,
                               atol=1e-4)


@pytest.mark.parametrize("block", [128, 256])
@pytest.mark.parametrize("n", [64, 500, 1024, 4096])
def test_kkt_select_sweep(n, block):
    f = RNG.normal(size=(n,)).astype(np.float32)
    alpha = RNG.uniform(0, 1, size=(n,)).astype(np.float32)
    alpha[RNG.random(n) < 0.3] = 0.0
    alpha[RNG.random(n) < 0.2] = 1.0
    y = np.where(RNG.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
    mask = RNG.random(n) < 0.9
    got = ops.kkt_select(jnp.asarray(f), jnp.asarray(alpha),
                         jnp.asarray(y), jnp.asarray(mask), c=1.0,
                         block=block)
    want = ref.kkt_select(jnp.asarray(f), jnp.asarray(alpha),
                          jnp.asarray(y), jnp.asarray(mask), 1.0)
    assert float(got[0]) == pytest.approx(float(want[0]), abs=1e-6)
    assert float(got[2]) == pytest.approx(float(want[2]), abs=1e-6)
    assert int(got[1]) == int(want[1])
    assert int(got[3]) == int(want[3])


def test_kkt_select_all_masked():
    n = 256
    got = ops.kkt_select(jnp.zeros(n), jnp.zeros(n), jnp.ones(n),
                         jnp.zeros(n, bool), c=1.0)
    assert np.isinf(float(got[0])) and np.isinf(float(got[2]))


@pytest.mark.parametrize("nt,n,d", [(64, 64, 4), (200, 333, 102),
                                    (13, 1000, 32)])
def test_decision_sweep(nt, n, d):
    xt = RNG.normal(size=(nt, d)).astype(np.float32)
    xr = RNG.normal(size=(n, d)).astype(np.float32)
    coef = RNG.normal(size=(n,)).astype(np.float32)
    b = 0.73
    got = ops.decision(jnp.asarray(xt), jnp.asarray(xr), jnp.asarray(coef),
                       b, gamma=0.21)
    want = ref.decision(jnp.asarray(xt), jnp.asarray(xr),
                        jnp.asarray(coef), b, 0.21)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("n_tasks,w,nt,d", [
    (1, 40, 50, 7), (3, 11, 37, 4), (4, 128, 128, 32), (2, 200, 1, 102),
])
@pytest.mark.parametrize("mode", ["rbf", "linear"])
def test_multitask_decision_sweep(n_tasks, w, nt, d, mode):
    xt = RNG.normal(size=(nt, d)).astype(np.float32)
    sv = RNG.normal(size=(n_tasks, w, d)).astype(np.float32)
    coef = RNG.normal(size=(n_tasks, w)).astype(np.float32)
    b = RNG.normal(size=(n_tasks,)).astype(np.float32)
    got = ops.multitask_decision(jnp.asarray(xt), jnp.asarray(sv),
                                 jnp.asarray(coef), jnp.asarray(b),
                                 gamma=0.21, mode=mode)
    want = np.stack([
        np.asarray(ref.rbf_gram(jnp.asarray(xt), jnp.asarray(sv[t]), 0.21)
                   if mode == "rbf" else xt @ sv[t].T) @ coef[t] + b[t]
        for t in range(n_tasks)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4,
                               atol=2e-5)
    assert got.shape == (n_tasks, nt)


def test_multitask_decision_matches_per_task_kernel():
    # the fused grid must be BIT-identical to the single-task decision
    # kernel per stacked row (same block sizes, same (i, k) order)
    xt = RNG.normal(size=(37, 6)).astype(np.float32)
    sv = RNG.normal(size=(3, 50, 6)).astype(np.float32)
    coef = RNG.normal(size=(3, 50)).astype(np.float32)
    b = RNG.normal(size=(3,)).astype(np.float32)
    got = np.asarray(ops.multitask_decision(
        jnp.asarray(xt), jnp.asarray(sv), jnp.asarray(coef),
        jnp.asarray(b), gamma=0.37))
    want = np.stack([
        np.asarray(ops.decision(jnp.asarray(xt), jnp.asarray(sv[t]),
                                jnp.asarray(coef[t]), b[t], gamma=0.37))
        for t in range(3)])
    np.testing.assert_array_equal(got, want)


def test_multitask_decision_rejects_unknown_mode():
    z = jnp.zeros((4, 3), jnp.float32)
    sv = jnp.zeros((1, 8, 3), jnp.float32)
    cf = jnp.zeros((1, 8), jnp.float32)
    with pytest.raises(ValueError, match="mode"):
        ops.multitask_decision(z, sv, cf, gamma=1.0, mode="poly")


def test_gram_row_fn_matches_full():
    x = RNG.normal(size=(300, 32)).astype(np.float32)
    row = ops.gram_row_fn(gamma=0.5)(jnp.asarray(x), jnp.asarray(x[7]))
    full = ref.rbf_gram(jnp.asarray(x), jnp.asarray(x), 0.5)
    np.testing.assert_allclose(np.asarray(row), np.asarray(full[:, 7]),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("b,s,h,hkv,d", [
    (2, 256, 4, 4, 64), (1, 512, 4, 2, 64), (2, 300, 2, 2, 32),
    (1, 128, 8, 1, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, s, h, hkv, d, causal):
    q = RNG.normal(size=(b, s, h, d)).astype(np.float32)
    k = RNG.normal(size=(b, s, hkv, d)).astype(np.float32)
    v = RNG.normal(size=(b, s, hkv, d)).astype(np.float32)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                              jnp.asarray(v), causal=causal)
    kk = np.repeat(k, h // hkv, axis=2)
    vv = np.repeat(v, h // hkv, axis=2)
    want = np.asarray(ref.flash_attention(
        jnp.asarray(q.transpose(0, 2, 1, 3).reshape(b * h, s, d)),
        jnp.asarray(kk.transpose(0, 2, 1, 3).reshape(b * h, s, d)),
        jnp.asarray(vv.transpose(0, 2, 1, 3).reshape(b * h, s, d)),
        causal)).reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4,
                               atol=2e-5)


def test_flash_attention_matches_model_layer():
    """The Pallas kernel must agree with the model's XLA attention path
    (full_attention) — same math, different memory schedule."""
    from repro.models import layers as L
    b, s, h, d = 1, 128, 4, 32
    q = RNG.normal(size=(b, s, h, d)).astype(np.float32)
    k = RNG.normal(size=(b, s, h, d)).astype(np.float32)
    v = RNG.normal(size=(b, s, h, d)).astype(np.float32)
    got = np.asarray(ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                                         jnp.asarray(v), causal=True))
    want = np.asarray(L.full_attention(jnp.asarray(q), jnp.asarray(k),
                                       jnp.asarray(v), causal=True))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("bc,h,q,n,p", [
    (2, 3, 32, 16, 8), (1, 4, 64, 32, 16), (3, 2, 128, 16, 32),
])
def test_ssd_diag_sweep(bc, h, q, n, p):
    from repro.kernels import ssd_diag as _sd
    cmat = RNG.normal(size=(bc, q, n)).astype(np.float32)
    bmat = RNG.normal(size=(bc, q, n)).astype(np.float32)
    x = RNG.normal(size=(bc, h, q, p)).astype(np.float32)
    dt = RNG.uniform(0.001, 0.1, size=(bc, h, q)).astype(np.float32)
    a = -RNG.uniform(1, 8, size=(h,)).astype(np.float32)
    cs = np.cumsum(dt * a[None, :, None], axis=2).astype(np.float32)
    got = _sd.ssd_diag_pallas(jnp.asarray(cmat), jnp.asarray(bmat),
                              jnp.asarray(x), jnp.asarray(dt),
                              jnp.asarray(cs))
    want = ref.ssd_diag(jnp.asarray(cmat), jnp.asarray(bmat),
                        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(cs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_ssd_diag_matches_model_chunked_path():
    """Kernel output == the y_diag stage inside mamba2.ssd_chunked
    (zero initial state, single chunk -> y == y_diag)."""
    from repro.kernels import ssd_diag as _sd
    from repro.models.mamba2 import ssd_chunked
    b, s, h, p, n = 1, 64, 2, 8, 16
    x = RNG.normal(size=(b, s, h, p)).astype(np.float32)
    dt = RNG.uniform(0.001, 0.1, size=(b, s, h)).astype(np.float32)
    a = -RNG.uniform(1, 8, size=(h,)).astype(np.float32)
    bm = RNG.normal(size=(b, s, 1, n)).astype(np.float32)
    cm = RNG.normal(size=(b, s, 1, n)).astype(np.float32)
    y, _ = ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                       jnp.asarray(bm), jnp.asarray(cm), chunk=s)
    cs = np.cumsum(dt * a[None, None, :], axis=1)          # (B,S,H)
    got = _sd.ssd_diag_pallas(
        jnp.asarray(cm[:, :, 0, :]), jnp.asarray(bm[:, :, 0, :]),
        jnp.asarray(x.transpose(0, 2, 1, 3)),
        jnp.asarray(dt.transpose(0, 2, 1)),
        jnp.asarray(cs.transpose(0, 2, 1)))
    want = np.asarray(y).transpose(0, 2, 1, 3)             # (B,H,S,P)
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-3,
                               atol=5e-4)


def test_blockspec_vmem_budget():
    """Default tiles must fit the ~16 MiB/core VMEM budget with double
    buffering (structural check on the BlockSpec sizes)."""
    bn = bm = bd = 128
    working_set = (bn * bd + bm * bd + bn * bm + bn + bm) * 4  # f32 bytes
    assert 2 * working_set < 16 * 2**20
