"""OvO multiclass + the distributed (shard_map) MPI layer."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dist, kernels as K, ovo
from repro.core.svm import SVC
from repro.data import load_iris, load_pavia_like, normalize


def test_vote_matches_majority():
    # 3 classes, task decisions crafted so votes are unambiguous
    classes = np.array([0, 1, 2])
    pairs = np.array([[0, 1], [0, 2], [1, 2]])
    # sample 0: always favors first of pair -> class 0 wins
    dec = jnp.asarray(np.array([[+1.0], [+1.0], [+1.0]]))
    idx = ovo.vote(dec, pairs, classes, 3)
    assert int(idx[0]) == 0
    dec = jnp.asarray(np.array([[-1.0], [-5.0], [-1.0]]))  # favors 1,2,2
    idx = ovo.vote(dec, pairs, classes, 3)
    assert int(idx[0]) == 2


def test_sequential_vs_vmapped_same_result():
    x, y = load_iris()
    x = normalize(x)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    tasks = ovo.build_tasks(x, y)
    seq = dist.sequential_ovo_fit(tasks, solver="smo", kernel=kp)
    vm = dist.vmapped_ovo_fit(tasks, solver="smo", kernel=kp)
    np.testing.assert_allclose(np.asarray(seq.alpha), np.asarray(vm.alpha),
                               rtol=1e-4, atol=1e-5)


def test_svc_multiclass_accuracy():
    x, y = load_iris()
    x = normalize(x)
    clf = SVC(solver="smo").fit(x, y)
    assert clf.score(x, y) >= 0.96
    clf_gd = SVC(solver="gd", gd_steps=2000).fit(x, y)
    assert clf_gd.score(x, y) >= 0.90


def test_svc_binary_gd_and_smo_agree():
    x, y = load_iris()
    x = normalize(x)
    sel = y != 2
    a = SVC(solver="smo").fit(x[sel], y[sel])
    b = SVC(solver="gd", gd_steps=3000).fit(x[sel], y[sel])
    assert a.score(x[sel], y[sel]) == 1.0
    assert b.score(x[sel], y[sel]) == 1.0


@pytest.mark.requires_devices(4)
def test_distributed_equals_local_4workers():
    """The MPI layer (shard_map over 4 forced host devices) must produce
    bit-compatible results with the single-device vmapped fit. Runs
    in-process: conftest.py forces the multi-device host before jax
    initializes (the old subprocess respawn is gone)."""
    x, y = load_pavia_like(n_per_class=24, n_classes=5)
    x = normalize(x)
    kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    mesh = jax.make_mesh((4,), ("workers",))
    tasks = ovo.build_tasks(x, y, pad_tasks_to=4)
    fit = dist.distributed_ovo_fit(tasks, mesh, ("workers",),
                                   solver="smo", kernel=kp)
    ref = dist.vmapped_ovo_fit(tasks, solver="smo", kernel=kp)
    np.testing.assert_allclose(np.asarray(fit.alpha),
                               np.asarray(ref.alpha), rtol=1e-4,
                               atol=1e-5)
    c = ovo.n_binary_tasks(5)
    assert bool(np.asarray(fit.converged)[:c].all())


def test_task_padding_for_worker_divisibility():
    x, y = load_iris()
    tasks = ovo.build_tasks(normalize(x), y, pad_tasks_to=4)
    assert tasks.x.shape[0] % 4 == 0
    assert tasks.x.shape[0] >= ovo.n_binary_tasks(3)
    # padded tasks fully masked
    for t in range(ovo.n_binary_tasks(3), tasks.x.shape[0]):
        assert not tasks.mask[t].any()
