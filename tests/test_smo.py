"""Parallel SMO solver: correctness + KKT optimality properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import gd, kernels as K, smo
from repro.data import load_iris, make_blobs, normalize


def _fit(x, y, c=1.0, kernel=None, **kw):
    kernel = kernel or K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
    cfg = smo.SMOConfig(C=c, **kw)
    r = smo.binary_smo(jnp.asarray(x), jnp.asarray(y), cfg=cfg,
                       kernel=kernel)
    return r, kernel


def _binary_iris():
    x, y = load_iris()
    x = normalize(x)
    sel = y != 2
    return x[sel], np.where(y[sel] == 0, 1.0, -1.0).astype(np.float32)


class TestConvergence:
    def test_separable_converges_and_classifies(self):
        x, y = _binary_iris()
        r, kp = _fit(x, y)
        assert bool(r.converged)
        df = smo.decision_function(jnp.asarray(x), jnp.asarray(y), r.alpha,
                                   r.b, jnp.asarray(x), kernel=kp)
        assert float(np.mean(np.sign(np.asarray(df)) == y)) == 1.0

    def test_overlapping_classes_converge(self):
        x, y = make_blobs(150, 2, 10, sep=0.8, seed=3)
        yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
        r, _ = _fit(normalize(x), yy)
        assert bool(r.converged)
        assert float(r.gap) <= 2.1e-3

    def test_linear_kernel(self):
        x, y = make_blobs(100, 2, 5, sep=4.0, seed=1)
        yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
        kp = K.KernelParams(name="linear")
        r, _ = _fit(normalize(x), yy, kernel=kp)
        assert bool(r.converged)

    def test_poly_kernel(self):
        x, y = make_blobs(80, 2, 5, sep=4.0, seed=2)
        yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
        kp = K.KernelParams(name="poly", gamma=0.5, degree=3, coef0=1.0)
        r, _ = _fit(normalize(x), yy, kernel=kp)
        assert bool(r.converged)


class TestKKT:
    """At the optimum: alpha_i = 0 -> y_i f(x_i) >= 1 - tol;
    0 < alpha_i < C -> y_i f(x_i) ~= 1; alpha_i = C -> <= 1 + tol."""

    def test_kkt_conditions(self):
        x, y = _binary_iris()
        r, kp = _fit(x, y, c=1.0)
        alpha = np.asarray(r.alpha)
        df = np.asarray(smo.decision_function(
            jnp.asarray(x), jnp.asarray(y), r.alpha, r.b, jnp.asarray(x),
            kernel=kp))
        margin = y * df
        tol = 5e-2
        free = (alpha > 1e-5) & (alpha < 1.0 - 1e-5)
        at_zero = alpha <= 1e-5
        at_c = alpha >= 1.0 - 1e-5
        assert np.all(margin[at_zero] >= 1.0 - tol)
        if free.any():
            np.testing.assert_allclose(margin[free], 1.0, atol=tol)
        assert np.all(margin[at_c] <= 1.0 + tol)

    def test_equality_constraint(self):
        x, y = _binary_iris()
        r, _ = _fit(x, y)
        assert abs(float(jnp.sum(r.alpha * jnp.asarray(y)))) < 1e-4

    def test_box_constraint(self):
        x, y = make_blobs(120, 2, 8, sep=1.0, seed=5)
        yy = np.where(y == 0, 1.0, -1.0).astype(np.float32)
        c = 0.7
        r, _ = _fit(normalize(x), yy, c=c)
        alpha = np.asarray(r.alpha)
        assert alpha.min() >= 0.0 and alpha.max() <= c + 1e-6


class TestAgainstGD:
    def test_same_objective_as_gd(self):
        """SMO (explicit) and GD (the TF baseline) optimize the same dual:
        objectives must agree; SMO is the reference optimum."""
        x, y = _binary_iris()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        gram = K.make_gram_fn(kp)(jnp.asarray(x), jnp.asarray(x))
        r, _ = _fit(x, y, kernel=kp)
        obj_smo = float(smo.dual_objective(jnp.asarray(y), r.alpha, gram))
        g = gd.binary_gd(jnp.asarray(x), jnp.asarray(y),
                         cfg=gd.GDConfig(lr=0.01, steps=4000), kernel=kp)
        obj_gd = float(smo.dual_objective(jnp.asarray(y), g.alpha, gram))
        # GD solves the SOFT-penalized dual: its objective may exceed the
        # hard-constrained optimum by the constraint slack; both must
        # agree to a few percent
        eq_violation = abs(float(jnp.sum(g.alpha * jnp.asarray(y))))
        assert obj_gd <= obj_smo + max(0.05 * obj_smo, 2 * eq_violation
                                       + 0.02)
        assert obj_gd >= 0.8 * obj_smo

    def test_iteration_count_gap(self):
        """The paper's speedup mechanism: SMO needs ~2 orders of magnitude
        fewer iterations than fixed-step GD to reach the optimum."""
        x, y = _binary_iris()
        r, _ = _fit(x, y)
        assert int(r.n_iter) < 1000  # GD baseline runs >= 2000 steps


class TestSecondOrderSelection:
    """WSS2 (beyond-paper): same optimum, substantially fewer iterations."""

    def test_same_solution_fewer_iterations(self):
        x, y = _binary_iris()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        r1, _ = _fit(x, y, kernel=kp)
        r2 = smo.binary_smo(jnp.asarray(x), jnp.asarray(y),
                            cfg=smo.SMOConfig(selection="second"),
                            kernel=kp)
        assert bool(r2.converged)
        assert int(r2.n_iter) < int(r1.n_iter)
        gram = K.make_gram_fn(kp)(jnp.asarray(x), jnp.asarray(x))
        o1 = float(smo.dual_objective(jnp.asarray(y), r1.alpha, gram))
        o2 = float(smo.dual_objective(jnp.asarray(y), r2.alpha, gram))
        assert abs(o1 - o2) < 0.02 * abs(o1) + 1e-3

    def test_second_order_row_mode(self):
        x, y = _binary_iris()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        r = smo.binary_smo(jnp.asarray(x), jnp.asarray(y),
                           cfg=smo.SMOConfig(selection="second",
                                             precompute_gram=False),
                           kernel=kp)
        assert bool(r.converged)


class TestVariantsMatchDenseFirstOrder:
    """Solver-variant coverage: WSS2 (selection="second") and the
    on-the-fly row mode (precompute_gram=False) must reach the SAME
    solution as the dense first-order reference on iris."""

    def _reference(self):
        x, y = _binary_iris()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        r0, _ = _fit(x, y, kernel=kp)
        gram = K.make_gram_fn(kp)(jnp.asarray(x), jnp.asarray(x))
        return x, y, kp, r0, gram

    def _assert_same_solution(self, x, y, kp, r0, gram, r1):
        assert bool(r1.converged)
        assert abs(float(r0.b) - float(r1.b)) < 1e-2
        o0 = float(smo.dual_objective(jnp.asarray(y), r0.alpha, gram))
        o1 = float(smo.dual_objective(jnp.asarray(y), r1.alpha, gram))
        assert abs(o0 - o1) < 0.02 * abs(o0) + 1e-3
        d0 = np.sign(np.asarray(smo.decision_function(
            jnp.asarray(x), jnp.asarray(y), r0.alpha, r0.b,
            jnp.asarray(x), kernel=kp)))
        d1 = np.sign(np.asarray(smo.decision_function(
            jnp.asarray(x), jnp.asarray(y), r1.alpha, r1.b,
            jnp.asarray(x), kernel=kp)))
        assert (d0 == d1).all()

    def test_wss2_matches_dense_first_order(self):
        x, y, kp, r0, gram = self._reference()
        r1 = smo.binary_smo(jnp.asarray(x), jnp.asarray(y),
                            cfg=smo.SMOConfig(selection="second"),
                            kernel=kp)
        self._assert_same_solution(x, y, kp, r0, gram, r1)

    def test_on_the_fly_matches_dense_first_order(self):
        x, y, kp, r0, gram = self._reference()
        r1 = smo.binary_smo(jnp.asarray(x), jnp.asarray(y),
                            cfg=smo.SMOConfig(precompute_gram=False),
                            kernel=kp)
        self._assert_same_solution(x, y, kp, r0, gram, r1)
        # on-the-fly first-order tracks the dense trajectory exactly
        np.testing.assert_allclose(np.asarray(r0.alpha),
                                   np.asarray(r1.alpha), rtol=1e-4,
                                   atol=1e-5)

    def test_wss2_on_the_fly_combination(self):
        x, y, kp, r0, gram = self._reference()
        r1 = smo.binary_smo(jnp.asarray(x), jnp.asarray(y),
                            cfg=smo.SMOConfig(selection="second",
                                              precompute_gram=False),
                            kernel=kp)
        self._assert_same_solution(x, y, kp, r0, gram, r1)


class TestMaskPadding:
    def test_padded_samples_inert(self):
        x, y = _binary_iris()
        n = len(y)
        pad = 37
        xp = np.concatenate([x, np.zeros((pad, x.shape[1]), np.float32)])
        yp = np.concatenate([y, np.zeros(pad, np.float32)])
        mask = np.concatenate([np.ones(n, bool), np.zeros(pad, bool)])
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        r0, _ = _fit(x, y, kernel=kp)
        r1 = smo.binary_smo(jnp.asarray(xp), jnp.asarray(yp),
                            jnp.asarray(mask), cfg=smo.SMOConfig(),
                            kernel=kp)
        np.testing.assert_allclose(np.asarray(r1.alpha[:n]),
                                   np.asarray(r0.alpha), rtol=1e-4,
                                   atol=1e-5)
        assert np.all(np.asarray(r1.alpha[n:]) == 0.0)


class TestPallasPath:
    def test_pallas_matches_jnp(self):
        x, y = _binary_iris()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        r0, _ = _fit(x, y, kernel=kp)
        r1 = smo.binary_smo(jnp.asarray(x), jnp.asarray(y),
                            cfg=smo.SMOConfig(use_pallas=True), kernel=kp)
        np.testing.assert_allclose(np.asarray(r0.alpha),
                                   np.asarray(r1.alpha), rtol=1e-4,
                                   atol=1e-5)

    def test_row_mode_matches_gram_mode(self):
        """On-the-fly kernel rows (O(nd) memory) == precomputed Gram."""
        x, y = _binary_iris()
        kp = K.resolve_gamma(K.KernelParams(), jnp.asarray(x))
        r0, _ = _fit(x, y, kernel=kp)
        r1 = smo.binary_smo(
            jnp.asarray(x), jnp.asarray(y),
            cfg=smo.SMOConfig(precompute_gram=False), kernel=kp)
        np.testing.assert_allclose(np.asarray(r0.alpha),
                                   np.asarray(r1.alpha), rtol=1e-4,
                                   atol=1e-5)
