"""Determinism regression: same data + config ==> bit-identical
multipliers and bias across repeated fits AND across a jit cache clear.

Guards two things: (1) nothing in the fit path depends on ambient state
(RNG, dict ordering, warm caches); (2) the hoisted static-config trace
caches (``dist._fit_many`` from PR 2, ``smo._sharded_smo_program``)
return programs whose recompilation reproduces the same bits — a cleared
cache must not change results."""
import numpy as np
import jax

from repro.core.svm import SVC, SVR
from repro.data import make_blobs, make_synth_regression, normalize


def _binary_data():
    x, y = make_blobs(60, 2, 5, sep=1.5, seed=11)
    return normalize(x), y


def _multiclass_data():
    x, y = make_blobs(40, 3, 5, sep=2.0, seed=12)
    return normalize(x), y


def _fit_svc_binary():
    x, y = _binary_data()
    clf = SVC(kernel="rbf", C=1.0).fit(x, y)
    return clf.alpha_.copy(), clf.b_, clf.n_iter_


def _fit_svc_multiclass():
    x, y = _multiclass_data()
    clf = SVC(kernel="rbf", C=1.0).fit(x, y)
    return (np.asarray(clf._fit.alpha).copy(),
            np.asarray(clf._fit.b).copy())


def _fit_svr():
    x, y = make_synth_regression(70, 3, kind="sinc", noise=0.05, seed=13)
    reg = SVR(kernel="rbf", epsilon=0.1).fit(x, y)
    return reg.beta_.copy(), reg.b_, reg.alpha_raw_.copy()


def _assert_runs_identical(fit_fn):
    first = fit_fn()
    again = fit_fn()                 # warm jit caches
    for a, b in zip(first, again):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    jax.clear_caches()               # force full retrace + recompile
    cold = fit_fn()
    for a, b in zip(first, cold):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_svc_binary_bit_identical():
    _assert_runs_identical(_fit_svc_binary)


def test_svc_multiclass_bit_identical():
    # exercises the lru-cached _fit_many program across the cache clear
    _assert_runs_identical(_fit_svc_multiclass)


def test_svr_bit_identical():
    _assert_runs_identical(_fit_svr)
