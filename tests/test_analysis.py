"""Golden-fixture suite for the static-analysis pass + compile guard.

Each rule gets one known-bad and one known-clean snippet, linted
through the real CLI driver (``lint.main``) against a tmp tree — the
same code path CI runs. Plus: suppression honored, unexplained
suppressions reported (R000), the JSON schema pinned, exit codes, the
baseline waiver path, and the runtime compile-guard demonstrably
tripping when the pow2 padding ladder is bypassed.
"""
import json

import pytest

from repro.analysis import lint as lint_cli
from repro.analysis.compile_guard import CompileBudgetExceeded, CompileGuard


def run_lint(tmp_path, files, *args):
    """Write {relpath: source} under tmp_path, lint it, return
    (exit_code, parsed JSON report)."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    import io
    from contextlib import redirect_stdout
    buf = io.StringIO()
    with redirect_stdout(buf):
        code = lint_cli.main([str(tmp_path), "--format", "json", *args])
    return code, json.loads(buf.getvalue())


def rules_hit(report):
    return {f["rule"] for f in report["findings"]}


# ----------------------------------------------------------------- R001
R001_BAD = """\
import jax
import jax.numpy as jnp

def handle(request_rows):
    f = jax.jit(lambda z: z + 1)
    return jnp.sum(request_rows)
"""

R001_CLEAN = """\
import jax.numpy as jnp

def handle(request_rows):
    bucket = 1 << (len(request_rows) - 1).bit_length()
    padded = list(request_rows) + [0.0] * (bucket - len(request_rows))
    return jnp.sum(jnp.asarray(padded))
"""


def test_r001_bad_serving_path(tmp_path):
    code, report = run_lint(tmp_path, {"serve/handler.py": R001_BAD})
    assert code == 1
    assert rules_hit(report) == {"R001"}
    msgs = " ".join(f["message"] for f in report["findings"])
    assert "jax.jit" in msgs and "request_rows" in msgs


def test_r001_clean_with_pow2_ladder(tmp_path):
    code, report = run_lint(tmp_path, {"serve/handler.py": R001_CLEAN})
    assert code == 0 and report["findings"] == []


def test_r001_out_of_scope_path_not_flagged(tmp_path):
    # same bad code OUTSIDE serve//dist.py is not a serving hot path
    code, report = run_lint(tmp_path, {"training/handler.py": R001_BAD})
    assert code == 0


# ----------------------------------------------------------------- R002
R002_BAD = """\
import numpy as np
import jax.numpy as jnp
from jax import lax

def loss(x):
    return np.asarray(x, np.float64).sum()

def gram_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = lax.dot_general(a_ref[...], b_ref[...],
                                 (((1,), (1,)), ((), ())))
"""

R002_CLEAN = """\
import numpy as np
import jax.numpy as jnp
from jax import lax

def kkt_violation(f, alpha):
    return np.asarray(f, np.float64).max() + alpha.sum()

def gram_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = lax.dot_general(a_ref[...], b_ref[...],
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
"""


def test_r002_bad_f64_and_unpinned_matmul(tmp_path):
    code, report = run_lint(tmp_path, {"core/thing.py": R002_BAD})
    assert code == 1
    assert rules_hit(report) == {"R002"}
    msgs = [f["message"] for f in report["findings"]]
    assert any("float64" in m for m in msgs)
    assert any("preferred_element_type" in m for m in msgs)


def test_r002_clean_certified_sites(tmp_path):
    code, report = run_lint(tmp_path, {"core/thing.py": R002_CLEAN})
    assert code == 0
    # the cascade certificate module is allowlisted wholesale
    code, _ = run_lint(tmp_path, {"core/cascade.py": (
        "import numpy as np\n"
        "def certify(f):\n"
        "    return np.asarray(f, np.float64).max()\n")})
    assert code == 0


# ----------------------------------------------------------------- R003
R003_BAD = """\
import jax
from jax.experimental import pallas as pl

def tiled(x, block_n: int = 128):
    n, = x.shape
    return pl.pallas_call(
        lambda x_ref, o_ref: None,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
    )(x)
"""

R003_VMEM_BAD = """\
import jax
from jax.experimental import pallas as pl
from repro.kernels.rbf_gram import check_block_divisibility

def tiled(x, block_n: int = 4096, block_m: int = 4096):
    n, m = x.shape
    check_block_divisibility("tiled", n=(n, block_n), m=(m, block_m))
    return pl.pallas_call(
        lambda x_ref, o_ref: None,
        grid=(n // block_n, m // block_m),
        in_specs=[pl.BlockSpec((block_n, block_m), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
    )(x)
"""

R003_CLEAN = R003_BAD.replace(
    "    n, = x.shape\n",
    "    n, = x.shape\n"
    "    check_block_divisibility('tiled', n=(n, block_n))\n").replace(
    "from jax.experimental import pallas as pl",
    "from jax.experimental import pallas as pl\n"
    "from repro.kernels.rbf_gram import check_block_divisibility")


def test_r003_missing_divisibility_check(tmp_path):
    code, report = run_lint(tmp_path, {"kernels/k.py": R003_BAD})
    assert code == 1
    assert rules_hit(report) == {"R003"}
    assert "check_block_divisibility" in report["findings"][0]["message"]


def test_r003_vmem_budget_exceeded(tmp_path):
    # 2 * 2 blocks * 4096^2 * 4B = 256 MiB >> the 16 MiB budget
    code, report = run_lint(tmp_path, {"kernels/k.py": R003_VMEM_BAD})
    assert code == 1
    assert any("VMEM" in f["message"] for f in report["findings"])


def test_r003_clean(tmp_path):
    code, report = run_lint(tmp_path, {"kernels/k.py": R003_CLEAN})
    assert code == 0, report["findings"]


# ----------------------------------------------------------------- R004
R004_BAD = """\
import threading

class Service:
    _GUARDED_BY = {"_stats": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._stats = {"n": 0}

    def submit(self):
        self._stats["n"] += 1

    def worker(self):
        def loop():
            return self._stats["n"]
        return loop
"""

R004_CLEAN = """\
import threading

class Service:
    _GUARDED_BY = {"_stats": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._stats = {"n": 0}

    def submit(self):
        with self._lock:
            self._stats["n"] += 1

    def _bump(self):  # repro: holds[_lock]
        self._stats["n"] += 1
"""


def test_r004_unlocked_access_and_closure(tmp_path):
    code, report = run_lint(tmp_path, {"serve/s.py": R004_BAD})
    assert code == 1
    assert rules_hit(report) == {"R004"}
    # the nested worker closure does NOT inherit an enclosing with
    assert any("worker.loop" in f["message"]
               for f in report["findings"])


def test_r004_with_block_and_holds_annotation(tmp_path):
    code, report = run_lint(tmp_path, {"serve/s.py": R004_CLEAN})
    assert code == 0, report["findings"]


# ----------------------------------------------------------------- R005
R005_BAD = """\
class Solver:
    def __init__(self, C=1.0, max_iter=100):
        self.C = C
        self.max_iter = max_iter

    def fit(self):
        return self.C
"""

R005_CLEAN = R005_BAD.replace("return self.C",
                              "return self.C * self.max_iter")


def test_r005_shelved_kwarg(tmp_path):
    code, report = run_lint(tmp_path, {"core/s.py": R005_BAD})
    assert code == 1
    assert rules_hit(report) == {"R005"}
    assert "max_iter" in report["findings"][0]["message"]


def test_r005_consumed_cross_file(tmp_path):
    # consumption in ANOTHER analyzed file counts (project-wide index)
    code, _ = run_lint(tmp_path, {
        "core/s.py": R005_BAD,
        "core/user.py": "def run(s):\n    return s.max_iter\n"})
    assert code == 0
    code, _ = run_lint(tmp_path, {"core/s.py": R005_CLEAN})
    assert code == 0


def test_r005_unused_public_function_param(tmp_path):
    code, report = run_lint(tmp_path, {"core/f.py": (
        "def tune(budget, iters):\n    return budget\n")})
    assert code == 1
    assert "iters" in report["findings"][0]["message"]
    # underscore prefix documents intentionally-unused
    code, _ = run_lint(tmp_path, {"core/f.py": (
        "def tune(budget, _iters):\n    return budget\n")})
    assert code == 0


# ----------------------------------------- suppressions / R000 / schema
def test_noqa_with_reason_suppresses(tmp_path):
    src = R005_BAD.replace(
        "        self.max_iter = max_iter",
        "        self.max_iter = max_iter  "
        "# repro: noqa[R005] -- kept for pickle back-compat")
    code, report = run_lint(tmp_path, {"core/s.py": src})
    assert code == 0
    assert report["counts"]["suppressed"] == 1
    assert report["suppressed"][0]["reason"] == "kept for pickle back-compat"


def test_unexplained_noqa_is_r000(tmp_path):
    src = R005_BAD.replace(
        "        self.max_iter = max_iter",
        "        self.max_iter = max_iter  # repro: noqa[R005]")
    code, report = run_lint(tmp_path, {"core/s.py": src})
    assert code == 1
    assert rules_hit(report) == {"R000"}
    assert "unexplained" in report["findings"][0]["message"]


def test_noqa_unknown_rule_is_r000(tmp_path):
    code, report = run_lint(tmp_path, {"core/s.py": (
        "X = 1  # repro: noqa[R999] -- no such rule\n")})
    assert code == 1
    assert rules_hit(report) == {"R000"}


def test_json_schema_pinned(tmp_path):
    code, report = run_lint(tmp_path, {"core/s.py": R005_BAD})
    assert report["schema"] == 1
    assert set(report) == {"schema", "findings", "suppressed",
                           "baseline_waived", "counts"}
    assert set(report["counts"]) == {"findings", "suppressed",
                                     "baseline_waived", "files"}
    f = report["findings"][0]
    assert set(f) == {"rule", "path", "line", "col", "message"}


def test_cli_exit_codes(tmp_path, capsys):
    (tmp_path / "clean.py").write_text("x = 1\n")
    assert lint_cli.main([str(tmp_path / "clean.py")]) == 0
    assert lint_cli.main([str(tmp_path / "missing.py")]) == 2
    bad = tmp_path / "bad.py"
    bad.write_text("def f(:\n")  # unparseable -> cannot certify clean
    assert lint_cli.main([str(bad)]) == 2
    capsys.readouterr()


def test_rules_subset_flag(tmp_path):
    # R005-bad code linted with only R001 selected is clean
    code, report = run_lint(tmp_path, {"core/s.py": R005_BAD},
                            "--rules", "R001")
    assert code == 0 and report["findings"] == []


def test_baseline_waives_without_hiding(tmp_path):
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"waive": [{"rule": "R005"}]}))
    code, report = run_lint(tmp_path, {"core/s.py": R005_BAD},
                            "--baseline", str(base))
    assert code == 0
    assert report["counts"]["findings"] == 0
    assert report["counts"]["baseline_waived"] == 1
    assert report["baseline_waived"][0]["rule"] == "R005"


def test_shipped_tree_is_lint_clean():
    """The acceptance gate: the shipped src/ exits 0 with the shipped
    baseline, and every suppression in the tree carries a reason."""
    import os
    root = os.path.join(os.path.dirname(__file__), "..")
    import io
    from contextlib import redirect_stdout
    buf = io.StringIO()
    with redirect_stdout(buf):
        code = lint_cli.main([os.path.join(root, "src"), "--format",
                              "json", "--baseline",
                              os.path.join(root,
                                           "analysis-baseline.json")])
    report = json.loads(buf.getvalue())
    assert code == 0, report["findings"]
    assert all(s["reason"] for s in report["suppressed"])


# ------------------------------------------------------- compile guard
def test_compile_guard_counts_and_passes_within_budget():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: jnp.sum(x * 2.0))
    # pow2 ladder: widths 5..8 all pad to bucket 8 -> one program.
    # Inputs built OUTSIDE the guard (eager zeros compiles too).
    xs = {w: jnp.zeros((1 << max(w - 1, 0).bit_length(),), jnp.float32)
          for w in (5, 6, 7, 8)}
    with CompileGuard(budget=1, note="padded widths") as g:
        for w in (5, 6, 7, 8):
            f(xs[w])
    assert g.count == 1
    assert "<lambda>" in g.compiled[0]
    # cache hits after exit stay free (flag restored, handler removed)
    f(jnp.zeros((8,), jnp.float32))


def test_compile_guard_trips_when_pow2_ladder_bypassed():
    """The PR 9 leak, reproduced: dispatching at RAW request widths
    compiles one program per distinct width and blows the budget the
    padded path satisfies."""
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: jnp.sum(x * 3.0))
    with pytest.raises(CompileBudgetExceeded, match="compile budget"):
        with CompileGuard(budget=2, note="raw widths"):
            for w in (3, 5, 7, 9, 11):   # no padding: 5 distinct shapes
                f(jnp.zeros((w,), jnp.float32))


def test_compile_guard_budget_zero_rejects_any_compile():
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda x: x + 1.0)
    f(jnp.zeros((4,), jnp.float32))      # warm outside the guard
    with CompileGuard(budget=0):
        f(jnp.zeros((4,), jnp.float32))  # cache hit: fine
    with pytest.raises(CompileBudgetExceeded):
        with CompileGuard(budget=0):
            f(jnp.zeros((16,), jnp.float32))  # fresh shape


def test_compile_guard_validates_budget():
    with pytest.raises(ValueError):
        CompileGuard(budget=-1)
