"""sklearn-compatibility bugfix sweep (PR 5).

Pins the four behaviors the bugfix satellites fixed:

1. binary label orientation — ``fit`` encodes ``classes_[1]`` as +1, so
   a POSITIVE ``decision_function`` margin predicts ``classes_[1]``
   (sklearn's convention; it used to be inverted), parity-tested
   against ``sklearn.svm.SVC`` on a fixture;
2. single-class ``y`` raises a clear ``ValueError`` instead of falling
   through to a degenerate OvO task set;
3. the support threshold is RELATIVE to C — small-C fits keep their
   support vectors instead of collapsing to a constant-bias predictor;
4. ``gamma="scale"`` on constant / near-constant features falls back to
   ``gamma = 1.0`` (sklearn) instead of the 1e12 of the old variance
   clamp.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import kernels as K
from repro.core.svm import SVC, SVR
from repro.data.synth import make_blobs

sklearn_svm = pytest.importorskip("sklearn.svm")


@pytest.fixture(scope="module")
def binary_fixture():
    x, y = make_blobs(25, 2, 4, sep=2.0, seed=7)
    return x, y


# ------------------------------------------------- 1. label orientation
class TestBinaryOrientation:
    def test_decision_sign_parity_with_sklearn(self, binary_fixture):
        x, y = binary_fixture
        ours = SVC(kernel="rbf", C=1.0, gamma=0.5).fit(x, y)
        ref = sklearn_svm.SVC(kernel="rbf", C=1.0, gamma=0.5).fit(x, y)
        df_ours = ours.decision_function(x)
        df_ref = ref.decision_function(x)
        np.testing.assert_array_equal(ours.classes_, ref.classes_)
        np.testing.assert_array_equal(ours.predict(x), ref.predict(x))
        # same QP, same convention: margins agree in sign AND value
        confident = np.abs(df_ref) > 1e-3
        assert confident.all()
        np.testing.assert_array_equal(np.sign(df_ours), np.sign(df_ref))
        np.testing.assert_allclose(df_ours, df_ref, rtol=1e-2, atol=1e-2)

    def test_positive_margin_predicts_second_class(self, binary_fixture):
        x, y = binary_fixture
        clf = SVC(kernel="rbf", C=1.0, gamma=0.5).fit(x, y)
        df = clf.decision_function(x)
        pred = clf.predict(x)
        assert (df != 0).all()
        np.testing.assert_array_equal(
            pred, np.where(df > 0, clf.classes_[1], clf.classes_[0]))

    def test_orientation_holds_for_gd_solver(self, binary_fixture):
        x, y = binary_fixture
        clf = SVC(solver="gd", gd_steps=2000, gamma=0.5).fit(x, y)
        ref = sklearn_svm.SVC(kernel="rbf", C=1.0, gamma=0.5).fit(x, y)
        agree = np.mean(clf.predict(x) == ref.predict(x))
        assert agree >= 0.95  # GD is approximate; orientation must hold


# --------------------------------------------------- 2. single-class y
class TestSingleClass:
    def test_single_class_fit_raises(self):
        x = np.random.default_rng(0).normal(size=(10, 3)).astype(np.float32)
        with pytest.raises(ValueError, match="2 classes"):
            SVC().fit(x, np.zeros(10))

    def test_sklearn_also_rejects_single_class(self):
        x = np.ones((6, 2), np.float32)
        with pytest.raises(ValueError):
            sklearn_svm.SVC().fit(x, np.zeros(6))


# ------------------------------------------- 3. relative SV threshold
class TestSmallCSupportThreshold:
    def test_small_c_binary_keeps_support_vectors(self, binary_fixture):
        x, y = binary_fixture
        clf = SVC(kernel="rbf", C=1e-6, gamma=0.5).fit(x, y)
        assert clf.n_support_ > 0          # used to drop EVERY SV
        df = clf.decision_function(x)
        assert np.std(df) > 0              # not the constant-b predictor
        assert clf.score(x, y) >= 0.9      # tiny-C margins still rank

    def test_small_c_multiclass_keeps_support_vectors(self):
        x, y = make_blobs(15, 3, 4, sep=4.0, seed=8)
        clf = SVC(kernel="rbf", C=1e-6, gamma=0.5).fit(x, y)
        assert np.all(clf.n_support_ > 0)  # per-task compaction too
        assert clf.score(x, y) >= 0.9

    def test_small_c_svr_keeps_support_vectors(self):
        rng = np.random.default_rng(9)
        x = rng.uniform(-1, 1, size=(50, 3)).astype(np.float32)
        yv = x[:, 0] + 0.1 * rng.normal(size=50)
        reg = SVR(C=1e-6, epsilon=0.01, gamma=0.5).fit(x, yv)
        assert reg.n_support_ > 0
        assert np.std(reg.predict(x)) > 0

    def test_large_c_compaction_still_drops_non_svs(self, binary_fixture):
        x, y = binary_fixture
        clf = SVC(kernel="rbf", C=10.0, gamma=0.5).fit(x, y)
        assert 0 < clf.n_support_ < len(y)


# ------------------------------------------------- 4. gamma="scale"
class TestGammaScaleFallback:
    def test_constant_features_fall_back_to_one(self):
        x = np.full((12, 5), 3.25, np.float32)
        kp = K.resolve_gamma(K.KernelParams(gamma=-1.0), x)
        assert kp.gamma == 1.0

    def test_near_constant_features_fall_back_to_one(self):
        x = np.full((12, 5), 3.25, np.float32)
        x[0, 0] += 1e-7                    # var ~ 1e-16: below the floor
        kp = K.resolve_gamma(K.KernelParams(gamma=-1.0), x)
        assert kp.gamma == 1.0

    def test_matches_sklearn_scale_on_regular_data(self):
        x, _ = make_blobs(20, 2, 6, seed=10)
        kp = K.resolve_gamma(K.KernelParams(gamma=-1.0), x)
        want = 1.0 / (x.shape[1] * x.var())
        np.testing.assert_allclose(kp.gamma, want, rtol=1e-5)

    def test_fit_on_constant_features_is_not_degenerate(self):
        # constant features + two classes: the old gamma ~ 1e12 made the
        # Gram the identity; gamma = 1.0 keeps it well-conditioned
        rng = np.random.default_rng(11)
        x = np.full((20, 4), 2.0, np.float32)
        y = np.r_[np.zeros(10), np.ones(10)]
        x[y == 1, 0] += 1e-9               # numerically constant
        clf = SVC(kernel="rbf").fit(x, y)
        assert clf.kernel_params.gamma == 1.0

    def test_explicit_gamma_untouched(self):
        x = np.full((8, 3), 1.0, np.float32)
        kp = K.resolve_gamma(K.KernelParams(gamma=0.7), x)
        assert kp.gamma == 0.7
        assert dataclasses.replace(kp).gamma == 0.7

    def test_refit_re_resolves_gamma_from_new_data(self):
        # sklearn recomputes 'scale' on every fit; resolving into the
        # stored params once and reusing it would serve the second fit
        # with the FIRST dataset's gamma
        x1, y1 = make_blobs(15, 2, 4, sep=2.0, seed=12, cov_scale=1.0)
        x2, y2 = make_blobs(15, 2, 4, sep=20.0, seed=13, cov_scale=10.0)
        clf = SVC(kernel="rbf").fit(x1, y1)
        g1 = clf.kernel_params.gamma
        clf.fit(x2, y2)
        g2 = clf.kernel_params.gamma
        fresh = SVC(kernel="rbf").fit(x2, y2)
        assert g2 == fresh.kernel_params.gamma
        assert g1 != g2
