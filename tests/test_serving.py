"""Serving-path equivalence: ``serve.Predictor`` vs the legacy engine path.

The contract PR 5 pins down: predictions served through the packed
artifact + Predictor are BIT-IDENTICAL to the pre-predictor
engine-backed path (``SVC._decision_function_engine`` /
``SVR._predict_engine``) across engines x model kinds, including
empty-SV degenerate models and non-bucket-aligned batch sizes.

Decision VALUES are bit-identical everywhere except one documented
case: multi-task (T >= 2) serving buckets on the chunked backend with a
non-bucket-aligned batch, where XLA's batched matmul reassociates the
f32 accumulation once the batch is zero-padded to its bucket — there
the values are bounded at a few ulp and the predicted labels still
match exactly. T = 1 banks (binary SVC, SVR) and the pallas fused
kernel (fixed 128-row blocks in both paths) are bit-identical at every
batch size.
"""
import io

import numpy as np
import pytest

from repro import serve
from repro.core import kernels as K
from repro.core.svm import SVC, SVR
from repro.data.synth import make_blobs, make_imbalanced_blobs, \
    make_synth_regression

ENGINES = ["dense", "chunked", "pallas"]


def _aligned(n: int) -> bool:
    return n == 1 << (n - 1).bit_length()


@pytest.fixture(scope="module")
def binary_problem():
    x, y = make_blobs(30, 2, 4, sep=3.0, seed=0)
    return x, y, SVC(solver="smo", gamma=0.5).fit(x, y)


@pytest.fixture(scope="module")
def ovo_problem():
    x, y = make_imbalanced_blobs([40, 25, 12, 9, 6], 4, sep=4.0, seed=1)
    return x, y, SVC(solver="smo", gamma=0.5).fit(x, y)


@pytest.fixture(scope="module")
def ovr_problem():
    x, y = make_blobs(20, 3, 4, sep=4.0, seed=2)
    return x, y, SVC(solver="smo", strategy="ovr", gamma=0.5).fit(x, y)


@pytest.fixture(scope="module")
def svr_problem():
    x, y = make_synth_regression(70, 5, seed=3)
    return x, y, SVR(solver="smo", gamma=0.5, epsilon=0.05).fit(x, y)


def _legacy_predict(model, xt):
    """Predictions recomputed from the legacy engine path (predict()
    itself routes through the predictor now)."""
    if isinstance(model, SVR):
        return model._predict_engine(xt)
    df = model._decision_function_engine(xt)
    if model._binary:
        return np.where(df > 0, model.classes_[1], model.classes_[0])
    idx = model.strategy.decide(df, model._taskset, model.decision)
    return model.classes_[np.asarray(idx)]


def _reconfigure(model, engine):
    import dataclasses
    model.engine_cfg = dataclasses.replace(model.engine_cfg,
                                           backend=engine)
    return model


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("prob", ["binary_problem", "ovo_problem",
                                  "ovr_problem", "svr_problem"])
@pytest.mark.parametrize("nt", [1, 7, 32, 37])
def test_serve_matches_legacy_engine_path(engine, prob, nt, request):
    x, y, model = request.getfixturevalue(prob)
    model = _reconfigure(model, engine)
    xt = x[:nt]
    if isinstance(model, SVR):
        got = model.predictor().predict(xt)
        want = model._predict_engine(xt)
        np.testing.assert_array_equal(got, want)  # T=1: bitwise, any nt
        return
    got_df = model.decision_function(xt)
    want_df = model._decision_function_engine(xt)
    serving_backend = model.predictor().engine_cfg.backend
    multi_task = (not model._binary
                  and any(len(g.task_ids) > 1
                          for g in model._serving_buckets))
    if serving_backend == "pallas" or not multi_task or _aligned(nt):
        np.testing.assert_array_equal(got_df, want_df)
    else:
        # chunked multi-task bucket + padded batch: XLA batched-matmul
        # reassociation, bounded at a few ulp (module docstring)
        np.testing.assert_array_almost_equal_nulp(got_df, want_df,
                                                  nulp=4)
    np.testing.assert_array_equal(model.predict(xt),
                                  _legacy_predict(model, xt))


@pytest.mark.parametrize("prob", ["binary_problem", "ovo_problem",
                                  "svr_problem"])
def test_micro_batch_slicing_matches_single_shot(prob, request):
    """max_batch streaming (many padded slices) serves the same values
    as one big batch through the default predictor."""
    x, y, model = request.getfixturevalue(prob)
    model = _reconfigure(model, "chunked")
    sliced = serve.Predictor(serve.pack(model), engine="chunked",
                             max_batch=8)
    whole = serve.Predictor(serve.pack(model), engine="chunked")
    xt = x[:30]
    np.testing.assert_array_equal(sliced.predict(xt), whole.predict(xt))
    np.testing.assert_array_almost_equal_nulp(
        sliced.decision_values(xt), whole.decision_values(xt), nulp=4)


# ------------------------------------------------------------- artifacts
def test_artifact_roundtrip_multiclass(ovo_problem, tmp_path):
    x, y, model = ovo_problem
    packed = serve.pack(model)
    path = tmp_path / "model.npz"
    serve.save(path, packed)
    loaded = serve.load(path)
    assert loaded.kind == "svc" and loaded.strategy == "ovo"
    assert loaded.n_tasks == packed.n_tasks
    assert loaded.kernel == packed.kernel
    np.testing.assert_array_equal(loaded.classes, packed.classes)
    np.testing.assert_array_equal(loaded.pairs, packed.pairs)
    assert len(loaded.buckets) == len(packed.buckets)
    for got, want in zip(loaded.buckets, packed.buckets):
        for f in got._fields:
            np.testing.assert_array_equal(getattr(got, f),
                                          getattr(want, f))
    pred = serve.Predictor(loaded, engine="chunked")
    np.testing.assert_array_equal(pred.predict(x[:32]),
                                  model.predict(x[:32]))


def test_artifact_roundtrip_string_labels(tmp_path):
    x, y_int = make_blobs(15, 2, 3, sep=3.0, seed=4)
    y = np.where(y_int == 0, "neg", "pos")
    clf = SVC(solver="smo", gamma=0.5).fit(x, y)
    path = tmp_path / "m.npz"
    serve.save(path, serve.pack(clf))
    pred = serve.Predictor(serve.load(path))
    got = pred.predict(x[:9])
    assert set(np.unique(got)) <= {"neg", "pos"}
    np.testing.assert_array_equal(got, clf.predict(x[:9]))


def test_save_load_roundtrip_without_npz_extension(binary_problem,
                                                   tmp_path):
    """save() must write the path VERBATIM (bare np.savez appends
    '.npz' to extension-less paths, breaking load(path))."""
    _, _, model = binary_problem
    path = tmp_path / "model-artifact"      # no extension
    serve.save(path, serve.pack(model))
    assert path.exists()
    assert serve.load(path).n_tasks == 1


def test_n_requests_counts_served_rows_not_warmup(binary_problem):
    x, _, model = binary_problem
    pred = serve.Predictor(serve.pack(model), engine="chunked")
    pred.warmup(batch_sizes=(1, 32))
    assert pred.n_requests == 0             # synthetic rows excluded
    pred.predict(x[:13])
    pred.decision_values(x[:7])
    assert pred.n_requests == 20


def test_artifact_rejects_unknown_schema_and_version(binary_problem,
                                                     tmp_path):
    _, _, model = binary_problem
    packed = serve.pack(model)
    buf = io.BytesIO()
    serve.save(buf, packed)
    buf.seek(0)
    ok = serve.load(buf)
    assert ok.n_tasks == 1

    import json
    path = tmp_path / "bad.npz"
    with np.load(io.BytesIO(buf.getvalue())) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(str(arrays["meta"]))
    meta["version"] = 999
    arrays["meta"] = np.array(json.dumps(meta))
    np.savez(path, **arrays)
    with pytest.raises(ValueError, match="version"):
        serve.load(path)

    meta["schema"] = "other.format"
    arrays["meta"] = np.array(json.dumps(meta))
    np.savez(path, **arrays)
    with pytest.raises(ValueError, match="schema"):
        serve.load(path)


def test_pack_requires_fitted_model():
    with pytest.raises(ValueError, match="fitted"):
        serve.pack(SVC())


def test_packed_model_validates_task_cover(binary_problem):
    _, _, model = binary_problem
    packed = serve.pack(model)
    with pytest.raises(ValueError, match="task ids"):
        serve.PackedModel(
            kind="svc", kernel=packed.kernel, n_features=4, n_tasks=2,
            buckets=packed.buckets, classes=packed.classes,
            pairs=packed.pairs)


# ----------------------------------------------------------- degenerates
def test_empty_sv_svr_serves_constant_bias():
    x, y = make_synth_regression(40, 4, noise=0.0, seed=5)
    reg = SVR(epsilon=50.0).fit(x, y)   # tube swallows every sample
    assert reg.n_support_ == 0
    got = reg.predict(x[:11])
    want = reg._predict_engine(x[:11])
    np.testing.assert_array_equal(got, want)
    assert np.all(got == got[0])        # the constant-bias predictor
    # and it survives the artifact roundtrip
    buf = io.BytesIO()
    serve.save(buf, serve.pack(reg))
    buf.seek(0)
    pred = serve.Predictor(serve.load(buf))
    np.testing.assert_array_equal(pred.predict(x[:11]), want)


@pytest.mark.parametrize("engine", ["chunked", "pallas"])
def test_empty_sv_bank_serves_bias_on_every_backend(engine):
    bank = serve.TaskBucket(task_ids=np.array([0]),
                            sv_x=np.zeros((1, 0, 3), np.float32),
                            sv_coef=np.zeros((1, 0), np.float32),
                            b=np.array([-0.75], np.float32),
                            sv_counts=np.array([0]))
    packed = serve.PackedModel(
        kind="svc", kernel=K.KernelParams(name="rbf", gamma=1.0),
        n_features=3, n_tasks=1, buckets=(bank,),
        classes=np.array([0, 1]), pairs=np.array([[1, 0]]))
    pred = serve.Predictor(packed, engine=engine)
    df = pred.decision_function(np.ones((5, 3), np.float32))
    np.testing.assert_array_equal(df, np.full(5, -0.75, np.float32))
    np.testing.assert_array_equal(
        pred.predict(np.ones((5, 3), np.float32)), np.zeros(5))


# ------------------------------------------------------------- jit cache
def test_predictor_program_cache_is_batch_bucketed(ovo_problem):
    x, _, model = ovo_problem
    pred = serve.Predictor(serve.pack(model), engine="chunked")
    pred.warmup(batch_sizes=(32,))
    n0 = pred.n_programs
    assert n0 == len(model._serving_buckets)
    # every batch size in (16, 32] hits the warm 32-bucket programs
    for nt in (17, 25, 32):
        pred.decision_values(x[:nt])
    assert pred.n_programs == n0
    # a new batch bucket compiles exactly one more program per SV bucket
    pred.decision_values(x[:4])
    assert pred.n_programs == n0 + len(model._serving_buckets)


def test_predictor_replay_within_compile_budget(ovo_problem,
                                                compile_guard):
    """Runtime backstop for the pow2 padding ladder (analysis R001):
    after warmup at a bucket, every request size inside that bucket
    replays through the warm programs — zero fresh XLA compiles. The
    guard fails this test the day a change starts keying programs on
    raw request shapes again."""
    x, _, model = ovo_problem
    pred = serve.Predictor(serve.pack(model), engine="chunked")
    pred.warmup(batch_sizes=(32,))
    with compile_guard(budget=0, note="warm-bucket replay") as g:
        for nt in (17, 21, 25, 29, 32):
            pred.predict(x[:nt])
    assert g.count == 0 and pred.n_programs == len(model._serving_buckets)


def test_max_batch_rounds_down_to_pow2(binary_problem):
    """An off-ladder max_batch must not mint off-ladder program shapes:
    max_batch=1000 used to pad 600-row requests to a 1000-row program
    instead of a capped pow2 — one silent extra executable per such
    size class. The cap now rounds DOWN to a pow2 at construction."""
    x, _, model = binary_problem
    packed = serve.pack(model)
    pred = serve.Predictor(packed, engine="chunked", max_batch=1000)
    assert pred.max_batch == 512
    # already-pow2 caps are untouched
    assert serve.Predictor(packed, max_batch=256).max_batch == 256
    assert serve.Predictor(packed, max_batch=1).max_batch == 1
    # a 600-row request slices at 512 then buckets the 88-row tail to
    # 128 — exactly two on-ladder programs, nothing at width 1000/600
    xt = np.tile(np.asarray(x, np.float32), (600 // len(x) + 1, 1))[:600]
    df = pred.decision_values(xt)
    assert pred.n_programs == 2
    whole = serve.Predictor(packed, engine="chunked")
    np.testing.assert_array_almost_equal_nulp(
        df, whole.decision_values(xt), nulp=4)


def test_serving_config_strips_training_only_fields():
    """A sharded-trained engine config must pack to a serving config
    that cannot reference the training mesh axis (the serving host has
    no such axis); the LRU row cache is training-side too."""
    from repro.core import kernel_engine as KE
    cfg = KE.EngineConfig(backend="sharded", shard_axis="shards",
                          cache_slots=16)
    scfg = serve.serving_config(cfg)
    assert scfg.backend == "chunked"
    assert scfg.shard_axis is None
    assert scfg.cache_slots == 0
    # explicit pallas survives, but its shard_axis is still stripped
    scfg = serve.serving_config(
        KE.EngineConfig(backend="pallas", shard_axis="w"))
    assert scfg.backend == "pallas" and scfg.shard_axis is None


def test_predictor_rejects_bad_requests(binary_problem):
    _, _, model = binary_problem
    pred = model.predictor()
    with pytest.raises(ValueError, match="request"):
        pred.decision_values(np.zeros((3, 9), np.float32))
    with pytest.raises(ValueError, match="max_batch"):
        serve.Predictor(serve.pack(model), max_batch=0)


def test_refit_invalidates_predictor_cache(binary_problem):
    x, y, _ = binary_problem
    clf = SVC(solver="smo", gamma=0.5).fit(x, y)
    first = clf.predictor()
    assert clf.predictor() is first          # cached across calls
    clf.fit(x, y)
    assert clf.predictor() is not first      # repacked on refit
