"""Autotune layer: candidate enumeration, hillclimb, on-disk cache, and
the runtime fast path ``kernels.ops`` consults.

Correctness contract under test:
* a missing / corrupted / version-mismatched cache NEVER changes
  behavior — lookups fall back to the hardcoded defaults;
* a present cache entry changes ONLY the tile configuration — the op
  results stay numerically identical to the default-tile results;
* the default config is always evaluated by ``tune``, so the tuned
  result is never worse than the default under the chosen objective;
* direct (non-``ops``) Pallas kernel calls with block-misaligned shapes
  raise a ``ValueError`` naming the offending axis, not a bare assert;
* importing the roofline CLI modules does not mutate ``XLA_FLAGS``.
"""
import importlib
import json
import os
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import autotune, ops
from repro.kernels import rbf_gram as G
from repro.kernels import decision as D
from repro.kernels import kkt_select as KS


@pytest.fixture
def isolated_cache(tmp_path):
    """Pin the runtime tuning cache to a per-test path; restore after."""
    path = str(tmp_path / "autotune.json")
    autotune.set_cache_path(path)
    yield path
    autotune.set_cache_path(None)


def _tune_tiny(kernel="rbf_gram", shape=(256, 256, 128)):
    return autotune.tune(kernel, shape, dtype="fp32", budget=4,
                         objective="roofline")


# ------------------------------------------------------------- candidates
def test_candidates_include_default_and_fit_vmem():
    for kernel, shape in [("rbf_gram", (2048, 2048, 256)),
                          ("kkt_select", (8192,)),
                          ("decision", (512, 4096, 128)),
                          ("multitask_decision", (8, 256, 1024, 128))]:
        cands = autotune.candidates(kernel, shape)
        default = autotune.clip_to_candidates(
            kernel, autotune.DEFAULTS[kernel], shape)
        assert default in cands
        for cfg in cands:
            used = autotune._vmem_bytes(kernel, cfg, shape, "fp32")
            assert 2 * used <= autotune.VMEM_BUDGET_BYTES, (cfg, used)


def test_candidates_clip_to_small_shapes():
    # a tiny problem must not propose tiles beyond its pow2-rounded shape
    for cfg in autotune.candidates("rbf_gram", (100, 100, 10)):
        assert cfg["block_n"] <= 128 and cfg["block_m"] <= 128
    assert autotune.candidates("rbf_gram", (100, 100, 10))


def test_bf16_admits_wider_tiles_than_fp32():
    # halving the operand element size must never shrink the ladder
    big = (4096, 4096, 512)
    n_fp32 = len(autotune.candidates("rbf_gram", big, "fp32"))
    n_bf16 = len(autotune.candidates("rbf_gram", big, "bf16"))
    assert n_bf16 >= n_fp32


def test_shape_bucket_and_cache_key():
    assert autotune.shape_bucket("rbf_gram", (1000, 1024, 100)) == \
        "n1024_m1024_d128"
    assert autotune.shape_bucket("kkt_select", (5000,)) == "n8192"
    key = autotune.cache_key("cpu", "rbf_gram", "bf16", (1000, 1024, 100))
    assert key == "cpu|rbf_gram|bf16|n1024_m1024_d128"
    with pytest.raises(ValueError):
        autotune.shape_bucket("rbf_gram", (10, 10))


# -------------------------------------------------------------- hillclimb
def test_tune_roofline_never_worse_than_default():
    for kernel, shape in [("rbf_gram", (1024, 1024, 128)),
                          ("decision", (256, 2048, 128))]:
        res = autotune.tune(kernel, shape, budget=6, objective="roofline")
        assert res.objective == "roofline"
        assert res.best.score <= res.default.score
        assert res.best.roofline_s <= res.default.roofline_s
        assert 1 <= len(res.trace) <= 6
        assert res.best.config in autotune.candidates(kernel, shape)


def test_tune_wall_objective_measures_and_improves():
    # tiny shape so interpret-mode timing stays cheap; the guarantee is
    # structural (default evaluated first), not a perf claim on CPU
    res = autotune.tune("rbf_gram", (128, 128, 64), budget=2,
                        objective="wall", warmup=0, iters=1)
    assert res.objective == "wall"
    assert all(ev.wall_s is not None for ev in res.trace)
    assert res.best.score <= res.default.score


def test_roofline_estimate_rewards_bigger_tiles_and_bf16():
    shape = (4096, 4096, 256)
    small = autotune.roofline_estimate("rbf_gram", shape, "fp32",
                                       {"block_n": 128, "block_m": 128,
                                        "block_d": 128})
    big = autotune.roofline_estimate("rbf_gram", shape, "fp32",
                                     {"block_n": 512, "block_m": 512,
                                      "block_d": 128})
    assert big["hbm_bytes"] < small["hbm_bytes"]
    assert big["flops"] == small["flops"]
    bf16 = autotune.roofline_estimate("rbf_gram", shape, "bf16",
                                      {"block_n": 128, "block_m": 128,
                                       "block_d": 128})
    assert bf16["hbm_bytes"] < small["hbm_bytes"]


# ------------------------------------------------------------- disk cache
def test_cache_roundtrip(isolated_cache):
    res = _tune_tiny()
    cache = autotune.TuningCache()
    key = autotune.cache_key("cpu", "rbf_gram", "fp32", (256, 256, 128))
    cache.put(key, res)
    cache.save(isolated_cache)

    loaded = autotune.TuningCache.load(isolated_cache)
    assert loaded.get(key) == res.best.config
    raw = json.load(open(isolated_cache))
    assert raw["version"] == autotune.CACHE_VERSION
    assert raw["entries"][key]["n_evaluated"] == len(res.trace)


def test_missing_cache_falls_back_to_defaults(isolated_cache):
    assert not os.path.exists(isolated_cache)
    assert autotune.lookup("rbf_gram", (256, 256, 128)) is None
    blocks = autotune.resolve_blocks(
        "rbf_gram", (256, 256, 128), "fp32",
        {"block_n": None, "block_m": None, "block_d": None})
    assert blocks == autotune.DEFAULTS["rbf_gram"]


def test_corrupted_cache_falls_back_to_defaults(isolated_cache):
    with open(isolated_cache, "w") as f:
        f.write("{not json at all")
    assert autotune.TuningCache.load(isolated_cache).entries == {}
    autotune.reset()
    assert autotune.lookup("rbf_gram", (256, 256, 128)) is None


def test_version_mismatch_falls_back_to_defaults(isolated_cache):
    key = autotune.cache_key(autotune.device_kind(), "rbf_gram", "fp32",
                             (256, 256, 128))
    stale = {"version": autotune.CACHE_VERSION + 1,
             "entries": {key: {"config": {"block_n": 512, "block_m": 512,
                                          "block_d": 128}}}}
    with open(isolated_cache, "w") as f:
        json.dump(stale, f)
    assert autotune.TuningCache.load(isolated_cache).entries == {}
    autotune.reset()
    assert autotune.lookup("rbf_gram", (256, 256, 128)) is None


def test_malformed_entries_are_dropped(isolated_cache):
    good_key = autotune.cache_key(autotune.device_kind(), "rbf_gram",
                                  "fp32", (256, 256, 128))
    raw = {"version": autotune.CACHE_VERSION,
           "entries": {good_key: {"config": {"block_n": 256,
                                             "block_m": 128,
                                             "block_d": 128}},
                       "bad1": "not a dict",
                       "bad2": {"no_config_key": 1}}}
    with open(isolated_cache, "w") as f:
        json.dump(raw, f)
    loaded = autotune.TuningCache.load(isolated_cache)
    assert set(loaded.entries) == {good_key}
    autotune.reset()
    assert autotune.lookup("rbf_gram", (256, 256, 128)) == {
        "block_n": 256, "block_m": 128, "block_d": 128}


# ------------------------------------------------------ runtime fast path
def test_ops_pick_up_tuned_entry_and_stay_correct(isolated_cache):
    """A tuned non-default tile must change only the schedule: the Gram
    values from the tuned path match the default-tile values exactly."""
    shape = (256, 200, 64)
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(shape[0], shape[2]))
                    .astype(np.float32))
    b = jnp.asarray(rng.normal(size=(shape[1], shape[2]))
                    .astype(np.float32))
    baseline = np.asarray(ops.rbf_gram(a, b, gamma=0.25))

    res = _tune_tiny("rbf_gram", shape)
    cache = autotune.TuningCache()
    cache.put(autotune.cache_key(autotune.device_kind(), "rbf_gram",
                                 "fp32", shape), res)
    # force a non-default winner so the test is meaningful either way
    cache.entries[list(cache.entries)[0]]["config"] = {
        "block_n": 256, "block_m": 256, "block_d": 128}
    cache.save(isolated_cache)
    autotune.reset()

    assert autotune.lookup("rbf_gram", shape) == {
        "block_n": 256, "block_m": 256, "block_d": 128}
    tuned = np.asarray(ops.rbf_gram(a, b, gamma=0.25))
    np.testing.assert_allclose(tuned, baseline, rtol=0, atol=1e-6)


def test_explicit_blocks_override_tuned_entry(isolated_cache):
    shape = (256, 256, 128)
    cache = autotune.TuningCache()
    cache.put(autotune.cache_key(autotune.device_kind(), "rbf_gram",
                                 "fp32", shape), _tune_tiny())
    cache.entries[list(cache.entries)[0]]["config"] = {
        "block_n": 256, "block_m": 256, "block_d": 128}
    cache.save(isolated_cache)
    autotune.reset()
    blocks = autotune.resolve_blocks(
        "rbf_gram", shape, "fp32",
        {"block_n": 64, "block_m": None, "block_d": None})
    assert blocks == {"block_n": 64, "block_m": 256, "block_d": 128}


def test_env_var_overrides_cache_location(tmp_path, monkeypatch):
    p = str(tmp_path / "alt.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", p)
    assert autotune.default_cache_path() == p


# ------------------------------------- uniform misaligned-shape ValueErrors
def test_direct_pallas_calls_raise_on_misaligned_shapes():
    z = jnp.zeros
    with pytest.raises(ValueError, match="pre-padded to block multiples"):
        G.rbf_gram_pallas(z((130, 128)), z((128, 128)), gamma=1.0,
                          interpret=True)
    with pytest.raises(ValueError, match="n=130"):
        G.rbf_gram_pallas(z((130, 128)), z((128, 128)), gamma=1.0,
                          interpret=True)
    with pytest.raises(ValueError, match="pre-padded to block multiples"):
        D.decision_pallas(z((100, 128)), z((128, 128)), z(128), gamma=1.0,
                          interpret=True)
    with pytest.raises(ValueError, match="pre-padded to block multiples"):
        D.multitask_decision_pallas(z((128, 128)), z((2, 100, 128)),
                                    z((2, 100)), gamma=1.0, interpret=True)
    with pytest.raises(ValueError, match="pre-padded to block multiples"):
        KS.kkt_select_pallas(z(100), z(100), z(100), z(100, jnp.int32),
                             c=1.0, block=128, interpret=True)
    with pytest.raises(ValueError, match="feature dims"):
        G.rbf_gram_pallas(z((128, 128)), z((128, 256)), gamma=1.0,
                          interpret=True)


def test_ops_wrappers_accept_misaligned_shapes():
    # the padding-aware public wrappers keep accepting anything
    a = jnp.ones((130, 7))
    out = ops.rbf_gram(a, jnp.ones((65, 7)), gamma=0.1)
    assert out.shape == (130, 65)


# --------------------------------------------- import-time purity (roofline)
def test_roofline_imports_do_not_mutate_xla_flags():
    before = os.environ.get("XLA_FLAGS")
    for mod in ("repro.roofline.hillclimb", "repro.roofline.differential",
                "repro.roofline.inspect_hlo", "repro.roofline.svm_tune",
                "repro.kernels.autotune"):
        sys.modules.pop(mod, None)
        importlib.import_module(mod)
    assert os.environ.get("XLA_FLAGS") == before


def test_setup_env_is_idempotent(monkeypatch):
    from repro.roofline import hillclimb
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    hillclimb.setup_env(4)
    first = os.environ["XLA_FLAGS"]
    assert "xla_force_host_platform_device_count=4" in first
    hillclimb.setup_env(4)          # second call must not stack flags
    assert os.environ["XLA_FLAGS"] == first


# ------------------------------------------------------------- CLI driver
def test_svm_tune_cli_writes_cache(tmp_path):
    from repro.roofline import svm_tune
    out = str(tmp_path / "cli.json")
    rc = svm_tune.main(["--kernel", "rbf_gram", "--shape", "256x256x128",
                        "--budget", "2", "--objective", "roofline",
                        "--out", out])
    assert rc == 0
    raw = json.load(open(out))
    assert raw["version"] == autotune.CACHE_VERSION
    assert len(raw["entries"]) == 1
    (rec,) = raw["entries"].values()
    assert set(rec["config"]) == {"block_n", "block_m", "block_d"}
    autotune.reset()  # CLI reset() left the runtime pinned to defaults


def test_svm_tune_cli_rejects_bad_shape():
    from repro.roofline import svm_tune
    with pytest.raises(ValueError, match="positive 'x'-separated"):
        svm_tune.parse_shape("rbf_gram", "256x256")
    with pytest.raises(ValueError):
        svm_tune.parse_shape("kkt_select", "0")
